#!/usr/bin/env python
"""Fail on broken intra-repo links in the markdown docs (CI docs step).

Checks every relative markdown link target ``[text](path)`` and every
backtick-quoted repo path that looks like a file reference in the given
documents.  External URLs (http/https/mailto) are ignored — CI must not
depend on network reachability.  Anchors (``path#section``) are checked
for file existence only.

Usage: python tools/check_links.py README.md docs/ARCHITECTURE.md ...
Exits nonzero listing every broken link.
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check_file(md_path: str) -> list:
    broken = []
    text = open(md_path, encoding="utf-8").read()
    base = os.path.dirname(os.path.abspath(md_path))
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            broken.append((md_path, target))
    return broken


def main(argv: list) -> int:
    docs = argv or ["README.md", "docs/ARCHITECTURE.md", "EXPERIMENTS.md",
                    "ROADMAP.md"]
    missing_docs = [d for d in docs if not os.path.exists(d)]
    broken = []
    for d in docs:
        if os.path.exists(d):
            broken.extend(check_file(d))
    for md, target in broken:
        print(f"BROKEN LINK: {md}: ({target})")
    for d in missing_docs:
        print(f"MISSING DOC: {d}")
    if broken or missing_docs:
        return 1
    print(f"check_links: OK ({len(docs)} docs)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
