"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping:
    weak_scaling   -> Fig. 3 (six graph families, boruvka vs filter)
    alltoall       -> Fig. 2 (two-level grid vs direct all-to-all)
    preprocessing  -> Fig. 4 (local contraction on/off)
    strong_scaling -> Fig. 5 (fixed graph, growing p)
    phases         -> Fig. 6 (per-phase time distribution)
    kernels_bench  -> kernel-layer microbenches (MINEDGES hot spot)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (alltoall, kernels_bench, phases, preprocessing,
                            sharded_scaling, strong_scaling, weak_scaling)
    for mod in (weak_scaling, alltoall, preprocessing, strong_scaling,
                sharded_scaling, phases, kernels_bench):
        try:
            mod.run()
        except Exception as e:  # keep the harness going; report the row
            print(f"{mod.__name__}/CRASH,0.0,"
                  f"{type(e).__name__}:{str(e)[:120]}".replace(",", ";"),
                  flush=True)
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
