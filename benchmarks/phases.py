"""Paper Fig. 6 analog: per-phase running-time distribution.

Times the dynamic Filter-Borůvka's phases on a local and a non-local
graph: pivot/partition, base-case Borůvka rounds, filtering — plus the
static engine's bucket sweep, matching the paper's observation that
communication-intense phases dominate on GNM/RMAT and local work on RGG.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.boruvka import boruvka_msf
from repro.core.filter_boruvka import _base_case, filter_boruvka_msf
from repro.core.graph import from_numpy
from repro.data import generators


def run(n: int = 1 << 13) -> None:
    for fam in ("rgg2d", "gnm"):
        u, v, w, nn = generators.generate(fam, n, avg_degree=16.0, seed=4)
        edges = from_numpy(u, v, w, nn)

        # phase: full Borůvka rounds (min-edge + contraction dominate)
        def full():
            mask, _ = boruvka_msf(edges.u, edges.v, edges.w, edges.n)
            jax.block_until_ready(mask)
        us_rounds = timeit(full, warmup=1, iters=3)
        emit(f"phases/{fam}/boruvka_rounds", us_rounds, f"m={len(u)}")

        # phase: one relabel+min-edge round (the per-round unit cost)
        from repro.core.boruvka import boruvka_round
        labels = jnp.arange(nn, dtype=jnp.int32)
        mst = jnp.zeros((len(u),), bool)
        rf = jax.jit(lambda l, m: boruvka_round(
            edges.u, edges.v, edges.w, l, m, edges.n))

        def one_round():
            l, m, _ = rf(labels, mst)
            jax.block_until_ready(l)
        us_one = timeit(one_round, warmup=1, iters=5)
        emit(f"phases/{fam}/single_round", us_one,
             f"rounds_equiv={us_rounds / max(us_one, 1):.1f}")

        # phase: filter sweep (sort + bucketed contraction)
        def filt():
            mask, _ = filter_boruvka_msf(edges.u, edges.v, edges.w,
                                         edges.n, num_buckets=8)
            jax.block_until_ready(mask)
        us_filter = timeit(filt, warmup=1, iters=3)
        emit(f"phases/{fam}/filter_sweep", us_filter,
             f"vs_plain={us_rounds / max(us_filter, 1):.2f}x")


if __name__ == "__main__":
    run()
