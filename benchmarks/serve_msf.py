"""MSF serving gateway benchmark (ISSUE 6): throughput / latency /
plan-cache behaviour under a synthetic gnm + rgg2d traffic mix, and the
batched-vs-per-request dispatch comparison.

The gateway (``serve/msf_gateway.py``) serves every request through a
plan-LRU + continuous-batching loop: same-shape requests ride one
compiled planned program vmapped over a batch axis.  This benchmark
reports, from one subprocess run on 8 virtual devices:

  * requests/s and p50/p99 request latency over the full mix,
  * plan-cache hit rate, evictions, replan + drift-refresh counts,
  * per-request wall time of one **batched** planned dispatch vs the
    same B graphs dispatched **one by one** through the single-graph
    planned program (both warm) — the vmap win the gateway banks on.

Every served forest is checked bit-identical to the Kruskal oracle
in-script (the acceptance bar), in smoke and full mode alike.  Full
mode merges a ``serve_gateway`` section into ``BENCH_sharded_comm.json``
(preserving the other sections); ``--smoke`` additionally asserts the
CI acceptance floor: cache hit rate > 0.5 on the repeated-shape mix and
a batched dispatch that beats per-request dispatch — asserted on the
deterministic per-request collective-invocation count (exactly B-fold
fewer, the alpha-cost win that survives virtual-device timing noise)
with a loose wall-clock bound alongside.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, json, time
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (execute_plan,
                                            execute_plan_batched)
from repro.launch.serve_msf import make_traffic, percentile
from repro.serve.msf_gateway import MSFGateway

SMOKE = os.environ.get("SERVE_MSF_SMOKE") == "1"
p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
out = {}

# --- the serving loop: traffic mix through the gateway ------------------
requests = 24 if SMOKE else 100
sizes = (256,) if SMOKE else (512, 1024)
gw = MSFGateway(mesh, cache_size=8, batch_slots=4, pad_margin=0.25)
reqs = make_traffic(("gnm", "rgg2d"), sizes, requests, seed=0)
for r in reqs:
    gw.submit(r)
t0 = time.perf_counter()
gw.run()
wall = time.perf_counter() - t0
assert all(r.done for r in reqs)

# acceptance: every served forest bit-identical to the Kruskal oracle
for r in reqs:
    kmask, kweight = oracle.kruskal(r.u, r.v, r.w, r.n)
    assert np.array_equal(r.edges, np.nonzero(kmask)[0]), (
        r.rid, r.family, r.n, "served forest != oracle")
    assert abs(r.weight - kweight) < 1e-3 * max(1.0, kweight), r.rid

lat = sorted(r.latency for r in reqs)
s = gw.stats
out["traffic"] = {
    "requests": len(reqs), "wall_s": wall,
    "requests_per_s": len(reqs) / wall,
    "p50_s": percentile(lat, 0.50), "p99_s": percentile(lat, 0.99),
    "batches": s.batches, "hits": s.hits, "misses": s.misses,
    "hit_rate": s.hit_rate, "evictions": s.evictions,
    "replans": s.replans, "replan_rate": s.replan_rate,
    "refreshes": s.refreshes, "oracle_checked": len(reqs),
}

# --- batched vs per-request planned dispatch (warm, same graphs) --------
# B same-shape graphs through (a) one vmapped batched dispatch and
# (b) B sequential single-graph planned dispatches; strict replay
# (replan=False) so both paths run exactly the compiled program.  The
# batch is B replicas of the graph the plan was measured on: a measured
# plan always fits its own graph (capacities AND round count), so the
# strict-mode comparison can never hit a structural misfit — a
# weight-shuffled batchmate can legitimately need more rounds than the
# measured trajectory (seen at n=512) and belongs to the replan path
# the traffic section above exercises, not this timing microbenchmark;
# dispatch cost is independent of the weight values.
# Timing is best-of-N (the standard floor estimator for dispatch
# overhead; single runs on virtual devices are +-10% noisy).  The
# deterministic metric alongside it: the vmapped program issues the
# SAME number of collective invocations as one unbatched solve, so
# per-request all-to-all invocations — the alpha term the paper's
# grid schedule attacks — drop exactly B-fold.
from repro.core.distributed_sharded import plan_sharded_msf
from repro.data import generators
B = 8
nb = 256 if SMOKE else 512
u, v, w, nb = generators.generate("gnm", nb, avg_degree=8.0, seed=3)
g0, cap = build_dist_graph(u, v, w, nb, p)
plan = plan_sharded_msf(g0, nb, mesh, axis_names=("data",)).pad(0.5)
graphs = [g0] * B

# stack once (the gateway stacks at admission, outside the hot dispatch)
from repro.core.distributed import DistGraph
import jax.numpy as jnp
stacked = DistGraph(jnp.stack([g.u for g in graphs]),
                    jnp.stack([g.v for g in graphs]),
                    jnp.stack([g.w for g in graphs]),
                    jnp.stack([g.eid for g in graphs]))

def run_batched():
    res, bad = execute_plan_batched(stacked, nb, mesh, plan,
                                    axis_names=("data",), replan=False,
                                    stack=False)
    jax.block_until_ready(res[0][0])
    return res

def run_seq():
    outs = [execute_plan(g, nb, mesh, plan, axis_names=("data",),
                         replan=False) for g in graphs]
    jax.block_until_ready(outs[-1][0])
    return outs

bres = run_batched(); sres = run_seq()      # warmup/compile
for i in range(B):                          # and bit-identity across paths
    assert np.array_equal(np.asarray(bres[i][0]), np.asarray(sres[i][0])), i
# per-request collective invocations (CommStats.calls is the program's
# invocation count: shared across the batch in the vmapped run)
calls_batched = float(np.asarray(bres[0][5].calls)) / B
calls_seq = float(np.asarray(sres[0][5].calls))
iters = 3 if SMOKE else 5

def best_of(fn):
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / B * 1e6

us_batched = best_of(run_batched)
us_seq = best_of(run_seq)
out["dispatch"] = {
    "batch": B, "n": nb,
    "us_per_request_batched": us_batched,
    "us_per_request_sequential": us_seq,
    "batched_speedup": us_seq / max(us_batched, 1e-9),
    "a2a_calls_per_request_batched": calls_batched,
    "a2a_calls_per_request_sequential": calls_seq,
    "a2a_invocation_shrink": calls_seq / max(calls_batched, 1e-9),
}

# --- recovery (ISSUE 9): checkpoint overhead, resume savings, elastic ---
from repro.comm import faults as _faults
from repro.core.distributed_sharded import (DEFAULT_CKPT_EVERY,
                                            distributed_sharded_msf)
nr = 256 if SMOKE else 512
u, v, w, nr = generators.generate("gnm", nr, avg_degree=8.0, seed=11)
gr, capr = build_dist_graph(u, v, w, nr, p)
planr = plan_sharded_msf(gr, nr, mesh, axis_names=("data",))
R = len(planr.rounds)

def best(fn):
    b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        b = min(b, time.perf_counter() - t0)
    return b

# warm both programs (plain one-program replay vs segmented), then the
# acceptance number: warm wall overhead of the certify+snapshot barrier
# at the default cadence, plus a dense-cadence (every 2 rounds) worst
# case for context
cks_warm = []
execute_plan(gr, nr, mesh, planr, replan=False)
execute_plan(gr, nr, mesh, planr, replan=False,
             ckpt_every=DEFAULT_CKPT_EVERY, ckpt_out=cks_warm)
execute_plan(gr, nr, mesh, planr, replan=False, ckpt_every=2,
             ckpt_out=[])
t_plain = best(lambda: jax.block_until_ready(
    execute_plan(gr, nr, mesh, planr, replan=False)[0]))
t_ck = best(lambda: jax.block_until_ready(
    execute_plan(gr, nr, mesh, planr, replan=False,
                 ckpt_every=DEFAULT_CKPT_EVERY, ckpt_out=[])[0]))
t_ck2 = best(lambda: jax.block_until_ready(
    execute_plan(gr, nr, mesh, planr, replan=False, ckpt_every=2,
                 ckpt_out=[])[0]))

# resume savings: abort the driver past a dense cadence, resume from
# the last certified checkpoint, compare against a from-scratch solve
base_r = distributed_sharded_msf(gr, nr, mesh)
cks = []
try:
    with _faults.inject(_faults.FaultPlan(seed=0, specs=(
            _faults.FaultSpec(kind="abort", site="minedges",
                              rounds=(3,)),))):
        distributed_sharded_msf(gr, nr, mesh, ckpt_every=2, ckpt_out=cks)
except _faults.ShardAbort:
    pass
assert cks, "no certified checkpoint before the injected abort"
ck = cks[-1]
res_r = distributed_sharded_msf(gr, nr, mesh, resume_from=ck)
assert np.array_equal(np.asarray(res_r[0]), np.asarray(base_r[0]))
t_resume = best(lambda: jax.block_until_ready(
    distributed_sharded_msf(gr, nr, mesh, resume_from=ck)[0]))
t_scratch = best(lambda: jax.block_until_ready(
    distributed_sharded_msf(gr, nr, mesh)[0]))

# elastic restore: the same checkpoint re-keyed onto a p/2 sub-mesh vs
# solving from scratch on that mesh (wall ratio < 1 means the restore
# beats a full re-run even after losing half the shards)
p2 = p // 2
mesh2 = Mesh(np.array(jax.devices()[:p2]), ("data",))
g2, cap2 = build_dist_graph(u, v, w, nr, p2)
ck2 = ck.remap(p2, cap2, np.asarray(g2.u), np.asarray(g2.v),
               np.asarray(g2.eid))
res_el = distributed_sharded_msf(g2, nr, mesh2, resume_from=ck2)
res_sc = distributed_sharded_msf(g2, nr, mesh2)
eid2 = np.asarray(g2.eid)
assert np.array_equal(np.unique(eid2[np.asarray(res_el[0])]),
                      np.unique(eid2[np.asarray(res_sc[0])]))
t_elastic = best(lambda: jax.block_until_ready(
    distributed_sharded_msf(g2, nr, mesh2, resume_from=ck2)[0]))
t_scratch2 = best(lambda: jax.block_until_ready(
    distributed_sharded_msf(g2, nr, mesh2)[0]))

out["recovery"] = {
    "n": nr, "plan_rounds": R,
    "ckpt_every_default": DEFAULT_CKPT_EVERY,
    "checkpoints_at_default_cadence": len(cks_warm),
    "t_plain_ms": t_plain * 1e3, "t_ckpt_ms": t_ck * 1e3,
    "ckpt_overhead_pct": (t_ck / max(t_plain, 1e-9) - 1.0) * 100.0,
    "ckpt_overhead_dense_pct":
        (t_ck2 / max(t_plain, 1e-9) - 1.0) * 100.0,
    "resume": {
        "rounds_total": int(base_r[5].rounds),
        "ckpt_round": ck.round_index,
        "rounds_saved": ck.round_index,
        "t_resume_ms": t_resume * 1e3,
        "t_scratch_ms": t_scratch * 1e3,
        "resume_wall_ratio": t_resume / max(t_scratch, 1e-9),
    },
    "elastic": {
        "p_from": p, "p_to": p2,
        "t_elastic_resume_ms": t_elastic * 1e3,
        "t_scratch_p2_ms": t_scratch2 * 1e3,
        "elastic_wall_ratio": t_elastic / max(t_scratch2, 1e-9),
        "oracle_identical": True,
    },
}
print(json.dumps(out))
"""


def _run_script(smoke: bool) -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    if smoke:
        env["SERVE_MSF_SMOKE"] = "1"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(smoke: bool = False) -> None:
    try:
        out = _run_script(smoke)
    except Exception as e:
        emit("serve_msf/error", 0.0, str(e)[-200:].replace(",", ";"))
        if smoke:
            raise
        return
    t = out["traffic"]
    emit("serve_msf/traffic", t["wall_s"] * 1e6,
         f"req_per_s={t['requests_per_s']:.2f};"
         f"p50_s={t['p50_s']:.3f};p99_s={t['p99_s']:.3f};"
         f"hit_rate={t['hit_rate']:.2f};replans={t['replans']};"
         f"refreshes={t['refreshes']};oracle_ok={t['oracle_checked']}")
    d = out["dispatch"]
    emit("serve_msf/dispatch", d["us_per_request_batched"],
         f"us_seq={d['us_per_request_sequential']:.0f};"
         f"batched_speedup={d['batched_speedup']:.2f}x;"
         f"a2a_shrink={d['a2a_invocation_shrink']:.1f}x;B={d['batch']}")
    r = out["recovery"]
    emit("serve_msf/recovery", r["t_ckpt_ms"] * 1e3,
         f"ckpt_overhead_pct={r['ckpt_overhead_pct']:.1f};"
         f"rounds_saved={r['resume']['rounds_saved']};"
         f"resume_ratio={r['resume']['resume_wall_ratio']:.2f};"
         f"elastic_ratio={r['elastic']['elastic_wall_ratio']:.2f}")
    if smoke:
        # CI acceptance (ISSUE 6): repeated-shape traffic must actually
        # reuse plans; the vmapped batch must beat per-request dispatch
        # on the deterministic metric (per-request collective
        # invocations shrink exactly B-fold — on one host, wall time
        # only bounds loosely because all 8 "devices" share the CPU;
        # oracle identity is asserted in-script)
        assert t["hit_rate"] > 0.5, t
        assert t["oracle_checked"] == t["requests"], t
        assert d["a2a_invocation_shrink"] >= d["batch"] * 0.999, d
        assert d["batched_speedup"] >= 0.8, d
        return
    # merge the serve_gateway section into the tracked BENCH json,
    # preserving the sections written by benchmarks/sharded_scaling.py
    path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_sharded_comm.json"))
    # acceptance (ISSUE 9): the certify+snapshot barrier at the default
    # cadence must cost < 15% of the warm plain replay
    assert out["recovery"]["ckpt_overhead_pct"] < 15.0, out["recovery"]
    bench = {}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["serve_gateway"] = {k: v for k, v in out.items()
                              if k != "recovery"}
    bench["recovery"] = out["recovery"]
    with open(path, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
    print("serve_msf: OK")
