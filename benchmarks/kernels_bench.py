"""Kernel-layer microbenchmarks: two-phase segmented min-edge vs the
naive dense scatter (the MINEDGES hot spot), fused relabel, and the
ISSUE 8 fused owner-side scatter-min (``owner_scatter_min``) vs the jnp
scatter path it replaces.

interpret=True executes the Pallas body in Python — wall times for the
pallas paths are NOT TPU projections; the derived columns carry the
structural quantities that determine the on-device win: candidates
emitted vs edges (scatter-work reduction) for the two-phase kernel, and
materialised-intermediate bytes (compiled ``memory_analysis`` temps of
the jnp path vs the fused kernel's analytic VMEM working set) for the
scatter-min.  ``--smoke`` asserts bit-for-bit parity of the fused
kernel against the sequential oracle plus the intermediate-bytes
reduction, and runs in CI next to ``sharded_scaling --smoke``; the full
run merges a ``kernels_minedge`` section into BENCH_sharded_comm.json.
"""
from __future__ import annotations

import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.boruvka import min_edge_per_component
from repro.kernels.segmin.ops import min_edges_dense
from repro.kernels.segmin.ref import (EID_SENTINEL, owner_scatter_min_ref,
                                      segmin_candidates_ref)
from repro.kernels.segmin.segmin import owner_scatter_min


@functools.partial(jax.jit, static_argnames=("size",))
def _jnp_scatter_tables(idx, w, eid, pay1, pay2, ok, size: int):
    """The pre-kernel owner-side construction (the jnp comparator):
    three full-size scatter tables plus two gather-mask passes."""
    off = jnp.where(ok, idx, size)
    wmin = jnp.full((size + 1,), jnp.inf, jnp.float32).at[off].min(
        jnp.where(ok, w, jnp.inf))
    at_min = ok & (w == wmin[off])
    emin = jnp.full((size + 1,), EID_SENTINEL, jnp.int32).at[off].min(
        jnp.where(at_min, eid, EID_SENTINEL))
    is_win = at_min & (eid == emin[off])
    p1 = jnp.full((size + 1,), -1, jnp.int32).at[off].max(
        jnp.where(is_win, pay1, -1))
    p2 = jnp.full((size + 1,), -1, jnp.int32).at[off].max(
        jnp.where(is_win, pay2, -1))
    return wmin[:size], emin[:size], p1[:size], p2[:size]


def _scatter_problem(L: int, size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, size, L).astype(np.int32))
    w = jnp.asarray(rng.integers(1, 8, L).astype(np.float32))  # ties
    eid = jnp.asarray(rng.permutation(L).astype(np.int32))
    pay1 = jnp.asarray(rng.integers(0, size, L).astype(np.int32))
    pay2 = jnp.asarray(rng.integers(0, size, L).astype(np.int32))
    ok = jnp.asarray(rng.random(L) < 0.85)
    return idx, w, eid, pay1, pay2, ok


def _temp_bytes(fn, *args) -> int | None:
    try:
        comp = jax.jit(fn).lower(*args).compile()
        return int(comp.memory_analysis().temp_size_in_bytes)
    except Exception:
        return None


def _kernel_vmem_bytes(block: int, out_block: int) -> int:
    """Analytic per-grid-step VMEM working set of the fused kernel: six
    candidate blocks (5 x 4-byte lanes + the 1-byte ok mask) and four
    4-byte output tiles that persist across the candidate sweep —
    everything the kernel ever materialises (no [size+1] scatter
    tables, no full-length at_min / is_win masks)."""
    return block * (5 * 4 + 1) + out_block * 4 * 4


def run_scatter_min(L: int, size: int, block: int, out_block: int,
                    smoke: bool) -> dict:
    """The ISSUE 8 microbench: fused kernel vs jnp scatter comparator,
    parity-checked bit-for-bit against the sequential oracle."""
    args = _scatter_problem(L, size)

    jnp_fn = jax.jit(lambda *a: _jnp_scatter_tables(*a, size))
    jax.block_until_ready(jnp_fn(*args))
    us_jnp = timeit(lambda: jax.block_until_ready(jnp_fn(*args)), iters=5)
    emit("kernels/minedge/owner_scatter_jnp", us_jnp,
         f"L={L};size={size}")

    fused = jax.jit(lambda *a: owner_scatter_min(
        *a, size, block=block, out_block=out_block, interpret=True))
    got = jax.block_until_ready(fused(*args))
    iters = 1 if smoke else 2
    us_fused = timeit(lambda: jax.block_until_ready(fused(*args)),
                      warmup=0, iters=iters)

    # bit-for-bit parity against both comparators (a wrong tie-break
    # here silently corrupts the MSF, so the benchmark re-proves it on
    # the exact shapes it measures)
    exp = owner_scatter_min_ref(*args, size)
    mirror = jnp_fn(*args)
    for g, e, m in zip(got, exp, mirror):
        assert np.array_equal(np.asarray(g), np.asarray(e)), \
            "fused kernel diverged from the sequential oracle"
        assert np.array_equal(np.asarray(g), np.asarray(m)), \
            "fused kernel diverged from the jnp scatter path"

    temp_jnp = _temp_bytes(lambda *a: _jnp_scatter_tables(*a, size), *args)
    vmem = _kernel_vmem_bytes(block, out_block)
    rec = {
        "L": L, "size": size, "block": block, "out_block": out_block,
        "us_jnp": us_jnp, "us_fused_interpret": us_fused,
        "jnp_temp_bytes": temp_jnp,
        "kernel_vmem_working_set_bytes": vmem,
        "parity": "bit-identical",
    }
    derived = f"L={L};size={size};parity=ok;vmem_bytes={vmem}"
    if temp_jnp:
        rec["intermediate_bytes_reduction"] = temp_jnp / max(vmem, 1)
        derived += (f";jnp_temp_bytes={temp_jnp}"
                    f";bytes_reduction={temp_jnp / max(vmem, 1):.1f}x")
    emit("kernels/minedge/pallas_fused", us_fused, derived)
    return rec


def run(smoke: bool = False) -> None:
    if smoke:
        m, n = 1 << 12, 1 << 8
        L, size, block, out_block = 1 << 12, 256, 1024, 128
    else:
        m, n = 1 << 16, 1 << 12
        L, size, block, out_block = 1 << 15, 512, 4096, 256
    rng = np.random.default_rng(0)
    seg = jnp.asarray(np.sort(rng.integers(0, n, m)).astype(np.int32))
    w = jnp.asarray(rng.uniform(1, 255, m).astype(np.float32))
    eid = jnp.arange(m, dtype=jnp.int32)
    alive = jnp.asarray(rng.random(m) < 0.9)

    naive = jax.jit(lambda: min_edge_per_component(seg, seg, w, n))
    jax.block_until_ready(naive())
    us_naive = timeit(lambda: jax.block_until_ready(naive()), iters=5)
    emit("kernels/minedge/naive_scatter", us_naive, f"m={m};n={n}")

    twophase = jax.jit(lambda: min_edges_dense(seg, w, eid, alive, n,
                                               use_pallas=False))
    jax.block_until_ready(twophase())
    us_two = timeit(lambda: jax.block_until_ready(twophase()), iters=5)
    cw, _ = segmin_candidates_ref(seg, w, eid, alive)
    cand = int(jnp.isfinite(cw).sum())
    emit("kernels/minedge/two_phase_jnp", us_two,
         f"candidates={cand};scatter_reduction={m / max(cand, 1):.1f}x")

    pallas = jax.jit(lambda: min_edges_dense(seg, w, eid, alive, n,
                                             use_pallas=True,
                                             interpret=True))
    jax.block_until_ready(pallas())
    us_p = timeit(lambda: jax.block_until_ready(pallas()),
                  warmup=0, iters=1 if smoke else 2)
    emit("kernels/minedge/pallas_interpret", us_p,
         "interpret-mode;not-a-TPU-projection")

    rec = run_scatter_min(L, size, block, out_block, smoke)

    if smoke:
        # CI acceptance (ISSUE 8): parity is asserted inside
        # run_scatter_min; the fused kernel's working set must
        # materialise fewer intermediate bytes than the jnp scatter
        # path's compiled temps (skip only if the backend exposes no
        # memory_analysis), and interpret-mode wall time only bounds
        # very loosely (the Python-interpreted body is not a projection)
        red = rec.get("intermediate_bytes_reduction")
        assert red is None or red > 1.0, rec
        assert rec["us_fused_interpret"] < 600e6, rec
        return
    path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_sharded_comm.json"))
    bench = {}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["kernels_minedge"] = {f"scatter/L={L}": rec}
    with open(path, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
    print("kernels_bench: OK")
