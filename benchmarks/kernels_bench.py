"""Kernel-layer microbenchmarks: two-phase segmented min-edge vs the
naive dense scatter (the MINEDGES hot spot), and fused relabel.

interpret=True executes the Pallas body in Python — wall times for the
pallas path are NOT TPU projections; the derived column carries the
structural quantities (candidates emitted vs edges = scatter-work
reduction) that determine the on-device win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.boruvka import min_edge_per_component
from repro.kernels.segmin.ops import min_edges_dense
from repro.kernels.segmin.ref import segmin_candidates_ref


def run(m: int = 1 << 16, n: int = 1 << 12) -> None:
    rng = np.random.default_rng(0)
    seg = jnp.asarray(np.sort(rng.integers(0, n, m)).astype(np.int32))
    w = jnp.asarray(rng.uniform(1, 255, m).astype(np.float32))
    eid = jnp.arange(m, dtype=jnp.int32)
    alive = jnp.asarray(rng.random(m) < 0.9)

    naive = jax.jit(lambda: min_edge_per_component(seg, seg, w, n))
    jax.block_until_ready(naive())
    us_naive = timeit(lambda: jax.block_until_ready(naive()), iters=5)
    emit("kernels/minedge/naive_scatter", us_naive, f"m={m};n={n}")

    twophase = jax.jit(lambda: min_edges_dense(seg, w, eid, alive, n,
                                               use_pallas=False))
    jax.block_until_ready(twophase())
    us_two = timeit(lambda: jax.block_until_ready(twophase()), iters=5)
    cw, _ = segmin_candidates_ref(seg, w, eid, alive)
    cand = int(jnp.isfinite(cw).sum())
    emit("kernels/minedge/two_phase_jnp", us_two,
         f"candidates={cand};scatter_reduction={m / max(cand, 1):.1f}x")

    pallas = jax.jit(lambda: min_edges_dense(seg, w, eid, alive, n,
                                             use_pallas=True,
                                             interpret=True))
    jax.block_until_ready(pallas())
    us_p = timeit(lambda: jax.block_until_ready(pallas()), iters=2)
    emit("kernels/minedge/pallas_interpret", us_p,
         "interpret-mode;not-a-TPU-projection")


if __name__ == "__main__":
    run()
