"""Replicated vs sharded vertex labels as n grows (paper Section IV).

On one physical CPU the wall time of virtual-device runs measures
overhead, not network behaviour, so the primary derived metric is the
one that actually separates the two engines at scale: **per-device label
state** — the replicated engine carries O(n) int32 labels on every
device and allReduces n-vectors each round, the sharded engine carries
O(n/p) and exchanges only routed candidates/lookups.  Wall time is
reported for completeness (the routed exchange pays many small
all-to-alls on virtual devices, so it is expected to be slower *here*;
EXPERIMENTS.md §Sharded-label engine).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json, time
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph, distributed_msf
from repro.core.distributed_sharded import (distributed_sharded_msf,
                                            vertices_per_shard)
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
out = {}
for n in (1 << 10, 1 << 12, 1 << 14):
    u, v, w, nn = generators.generate("gnm", n, avg_degree=8.0, seed=3)
    g, cap = build_dist_graph(u, v, w, nn, p)
    rec = {}
    for name, run in (
        ("replicated", lambda: distributed_msf(
            g, nn, mesh, algorithm="boruvka", axis_names=("data",))),
        ("sharded", lambda: distributed_sharded_msf(
            g, nn, mesh, algorithm="boruvka", axis_names=("data",))),
    ):
        res = run()
        jax.block_until_ready(res[0])
        t0 = time.perf_counter()
        res = run()
        jax.block_until_ready(res[0])
        us = (time.perf_counter() - t0) * 1e6
        label_ints = nn if name == "replicated" else vertices_per_shard(nn, p)
        rec[name] = {"us": us, "label_ints_per_device": label_ints,
                     "weight": float(res[1])}
    assert abs(rec["replicated"]["weight"] - rec["sharded"]["weight"]) \
        < 1e-3 * max(1.0, rec["replicated"]["weight"])
    out[n] = rec
print(json.dumps(out))
"""


def run() -> None:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        emit("sharded_scaling/error", 0.0,
             proc.stderr[-200:].replace(",", ";"))
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for n, rec in out.items():
        shrink = (rec["replicated"]["label_ints_per_device"]
                  / max(rec["sharded"]["label_ints_per_device"], 1))
        for name in ("replicated", "sharded"):
            emit(f"sharded_scaling/gnm/n={n}/{name}", rec[name]["us"],
                 f"label_ints_per_device="
                 f"{rec[name]['label_ints_per_device']};"
                 f"label_memory_shrink_vs_replicated="
                 f"{shrink if name == 'sharded' else 1.0:.1f}x")
