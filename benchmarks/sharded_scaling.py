"""Replicated vs sharded vertex labels as n grows (paper Section IV) and
the sharded engine's communication trajectory (ISSUE 2).

On one physical CPU the wall time of virtual-device runs measures
overhead, not network behaviour, so the primary derived metrics are the
ones that actually separate engine variants at scale: **per-device label
state** (replicated O(n) vs sharded O(n/p)) and the sharded engine's
**comm counters** — all-to-all invocations per Borůvka round and routed
item volume, straight from the engine's ``CommStats``.  Wall time is
reported for completeness (the routed exchange pays many small
all-to-alls on virtual devices, so it is expected to be slower *here*;
EXPERIMENTS.md §Sharded-label engine).

The PR 1 baseline (``local_preprocessing=False, coalesce=False,
src_only=False, adaptive_doubling=False, ghost_cache=False,
relabel_skip=False``) is compared against the optimized defaults on a
gnm (low locality — exercises coalescing + src-only + adaptive
doubling) and an rgg2d (high locality — additionally exercises the
sharded preprocessing) graph; both runs must be bit-identical to the
Kruskal oracle at overflow == 0.  A dedicated ghost section (ISSUE 4,
always at n = 4096) compares routed endpoint-lookup items
(``CommStats.misses + pushed``) across the PR 3 coalesced engine, the
v-sorted index alone, and the ghost cache, asserting the >= 3x
acceptance floor in smoke mode.  A ``plan_replay`` section (ISSUE 5,
also at n = 4096) measures a ``RoundPlan`` off the host-interleaved
driver, replays its serialized form as the AOT-lowerable unrolled
program, and asserts bit-identity plus the acceptance bounds: executed
buffer bytes within one ladder step (2x) of the host-driven schedule
and compiled ``memory_analysis`` temps below the flat-capacity
lowering.  The comparison is written to ``BENCH_sharded_comm.json`` so
the perf trajectory is tracked across PRs.  ``python -m
benchmarks.sharded_scaling --smoke`` runs a tiny-n config of the same
code path (the CI bitrot guard).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json, time
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph, distributed_msf
from repro.core.distributed_sharded import (distributed_sharded_msf,
                                            vertices_per_shard)
from repro.data import generators

SMOKE = os.environ.get("SHARDED_SCALING_SMOKE") == "1"
p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
out = {"memory": {}, "comm": {}}

# --- label-memory + wall-time: replicated vs sharded -------------------
for n in ((1 << 9,) if SMOKE else (1 << 10, 1 << 12, 1 << 14)):
    u, v, w, nn = generators.generate("gnm", n, avg_degree=8.0, seed=3)
    g, cap = build_dist_graph(u, v, w, nn, p)
    rec = {}
    for name, run in (
        ("replicated", lambda: distributed_msf(
            g, nn, mesh, algorithm="boruvka", axis_names=("data",))),
        ("sharded", lambda: distributed_sharded_msf(
            g, nn, mesh, algorithm="boruvka", axis_names=("data",))),
    ):
        res = run()
        jax.block_until_ready(res[0])
        t0 = time.perf_counter()
        res = run()
        jax.block_until_ready(res[0])
        us = (time.perf_counter() - t0) * 1e6
        label_ints = nn if name == "replicated" else vertices_per_shard(nn, p)
        rec[name] = {"us": us, "label_ints_per_device": label_ints,
                     "weight": float(res[1])}
    assert abs(rec["replicated"]["weight"] - rec["sharded"]["weight"]) \
        < 1e-3 * max(1.0, rec["replicated"]["weight"])
    out["memory"][n] = rec

# --- comm counters: PR 1 baseline vs flat-capacity vs shrinking --------
from repro.core.distributed_sharded import minedges_buffer_bytes

BASELINE = dict(local_preprocessing=False, coalesce=False, src_only=False,
                adaptive_doubling=False, shrink_capacities=False,
                ghost_cache=False, relabel_skip=False)
CONFIGS = (("baseline", BASELINE),
           ("flat", dict(shrink_capacities=False)),  # all levers, flat caps
           ("optimized", {}))                        # + shrinking schedule
for fam, n in (("gnm", 1 << 9), ("rgg2d", 1 << 9)) if SMOKE else \
              (("gnm", 1 << 12), ("rgg2d", 1 << 12)):
    u, v, w, nn = generators.generate(fam, n, avg_degree=8.0, seed=3)
    g, cap = build_dist_graph(u, v, w, nn, p)
    kmask, kweight = oracle.kruskal(u, v, w, nn)
    ksel = np.nonzero(kmask)[0]
    rec = {}
    for name, flags in CONFIGS:
        trace = [] if name == "optimized" else None
        mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
            g, nn, mesh, algorithm="boruvka", axis_names=("data",),
            round_trace=trace, **flags)
        jax.block_until_ready(mask)
        t0 = time.perf_counter()
        mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
            g, nn, mesh, algorithm="boruvka", axis_names=("data",), **flags)
        jax.block_until_ready(mask)
        us = (time.perf_counter() - t0) * 1e6
        # the honest-metric contract: exact results, overflow reported 0
        assert int(ovf) == 0, (fam, name, int(ovf))
        sel = np.unique(np.asarray(g.eid)[np.asarray(mask)])
        assert np.array_equal(sel, ksel), (fam, name,
                                           "MSF edge set differs from oracle")
        rounds = int(st.rounds)
        rec[name] = {"us": us, "a2a_calls": int(st.calls),
                     "rounds": rounds,
                     "a2a_per_round": int(st.calls) / max(rounds, 1),
                     "routed_items": float(st.items),
                     "buffer_mb": float(st.bytes) / 1e6,
                     "lookup_items": float(st.misses) + float(st.pushed),
                     "cache_hits": float(st.hits)}
        if trace is not None:
            rec[name]["rounds_trace"] = [
                {k: t[k] for k in ("round", "cap_edge", "cap_lookup",
                                   "cap_contract", "cap_relabel",
                                   "cap_push", "ghost",
                                   "minedges_buffer_bytes",
                                   "buffer_bytes", "routed_items",
                                   "cache_hits", "lookup_items",
                                   "pushed_items")}
                for t in trace]
    b, f, o = rec["baseline"], rec["flat"], rec["optimized"]
    rec["a2a_per_round_shrink"] = b["a2a_per_round"] / max(
        o["a2a_per_round"], 1e-9)
    rec["routed_items_shrink"] = b["routed_items"] / max(
        o["routed_items"], 1e-9)
    # MINEDGES buffer bytes: flat-capacity baseline ships edges/shard
    # sized buffers every round; the shrinking schedule's per-round
    # capacities are in the trace (ISSUE 3 acceptance: >= 2x cumulative)
    flat_minedges = f["rounds"] * minedges_buffer_bytes(p, cap, 1, True)
    shrink_minedges = sum(t["minedges_buffer_bytes"]
                          for t in o["rounds_trace"])
    rec["edge_capacity_flat"] = cap
    rec["minedges_bytes_flat"] = flat_minedges
    rec["minedges_bytes_shrink"] = shrink_minedges
    rec["minedges_cum_shrink"] = flat_minedges / max(shrink_minedges, 1)
    rec["buffer_mb_shrink"] = f["buffer_mb"] / max(o["buffer_mb"], 1e-9)
    out["comm"][f"{fam}/n={nn}"] = rec

# --- ghost-vertex cache: routed endpoint-lookup volume (ISSUE 4) -------
# rgg2d at n=4096 (the acceptance scale): the ghost cache (fills +
# dirty pushes) vs the PR 3 coalesced engine (u-run coalescing,
# slot-order v runs — `vsorted_index=False, ghost_cache=False`), with
# the v-sorted-index-only row in between for an honest decomposition of
# where the win comes from.  lookup_items = CommStats.misses +
# CommStats.pushed — the total routed items spent resolving endpoint
# labels.
out["ghost"] = {}
u, v, w, nn = generators.generate("rgg2d", 1 << 12, avg_degree=8.0, seed=3)
g, cap = build_dist_graph(u, v, w, nn, p)
kmask, kweight = oracle.kruskal(u, v, w, nn)
ksel = np.nonzero(kmask)[0]
grec = {}
for name, flags in (
        ("pr3_coalesce", dict(ghost_cache=False, vsorted_index=False)),
        ("vsorted_coalesce", dict(ghost_cache=False)),
        ("ghost", {})):
    trace = []
    mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
        g, nn, mesh, algorithm="boruvka", axis_names=("data",),
        round_trace=trace, **flags)
    assert int(ovf) == 0, (name, int(ovf))
    sel = np.unique(np.asarray(g.eid)[np.asarray(mask)])
    assert np.array_equal(sel, ksel), (name, "MSF differs from oracle")
    grec[name] = {
        "lookup_items": float(st.misses) + float(st.pushed),
        "misses": float(st.misses), "pushed": float(st.pushed),
        "cache_hits": float(st.hits), "rounds": int(st.rounds),
        "rounds_trace": [
            {k: t[k] for k in ("round", "ghost", "cap_lookup", "cap_push",
                               "cap_relabel", "cache_hits",
                               "lookup_items", "pushed_items")}
            for t in trace]}
grec["lookup_shrink"] = grec["pr3_coalesce"]["lookup_items"] / max(
    grec["ghost"]["lookup_items"], 1e-9)
grec["lookup_shrink_vs_vsorted"] = \
    grec["vsorted_coalesce"]["lookup_items"] / max(
        grec["ghost"]["lookup_items"], 1e-9)
out["ghost"][f"rgg2d/n={nn}"] = grec

# --- plan/execute split: AOT replay of the shrinking schedule (ISSUE 5) ---
# Measure a RoundPlan off the host-interleaved driver, replay it as the
# Python-unrolled AOT program, and compare (a) the executed
# capacity-padded buffer bytes against the host-driven schedule
# (acceptance: within one ladder step, i.e. a factor of 2) and (b) the
# compiled memory_analysis temps against the flat-capacity lowering of
# the same shape.  n = 4096 (the acceptance scale) even in smoke; the
# host-driven comparator is the ghost section's last run — same graph
# (rgg2d, seed 3), same default engine — so no duplicate solve.
import warnings
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed_sharded import (make_sharded_mst_step,
                                            plan_sharded_msf)
from repro.core.plan import RoundPlan
out["plan_replay"] = {}
host_mask = np.asarray(mask)   # the ("ghost", {}) run above
host_bytes = float(st.bytes)
host_rounds = int(st.rounds)
plan = plan_sharded_msf(g, nn, mesh, axis_names=("data",))
plan = RoundPlan.from_json(plan.to_json())  # replay the durable form
pres = distributed_sharded_msf(g, nn, mesh, axis_names=("data",),
                               plan=plan, replan=False)
assert int(pres[4]) == 0
assert np.array_equal(np.asarray(pres[0]), host_mask)
sel = np.unique(np.asarray(g.eid)[np.asarray(pres[0])])
assert np.array_equal(sel, ksel), "planned replay differs from oracle"

sh = NamedSharding(mesh, P("data"))
step_p, specs = make_sharded_mst_step(nn, g.cap_total, mesh, plan=plan)
comp_p = jax.jit(step_p, in_shardings=(sh,) * 4).lower(*specs).compile()
with warnings.catch_warnings():
    warnings.simplefilter("ignore")
    step_f, _ = make_sharded_mst_step(nn, g.cap_total, mesh,
                                      shrink_capacities=False)
comp_f = jax.jit(step_f, in_shardings=(sh,) * 4).lower(*specs).compile()

def temp_bytes(comp):
    try:
        return int(comp.memory_analysis().temp_size_in_bytes)
    except Exception:
        return None

plan_bytes = float(pres[5].bytes)
prec = {
    "rounds_host": host_rounds, "rounds_plan": plan.num_rounds,
    "sentinel_rounds": sum(r.sentinel for r in plan.rounds),
    "exec_buffer_bytes_host": host_bytes,
    "exec_buffer_bytes_plan": plan_bytes,
    "exec_buffer_ratio_plan_vs_host": plan_bytes / max(host_bytes, 1e-9),
    "minedges_bytes_plan": sum(
        minedges_buffer_bytes(p, r.cap_edge, 1, True)
        for r in plan.rounds),
    "minedges_bytes_flat": plan.num_rounds * minedges_buffer_bytes(
        p, cap, 1, True),
    "temp_bytes_plan_aot": temp_bytes(comp_p),
    "temp_bytes_flat_aot": temp_bytes(comp_f),
}
if prec["temp_bytes_plan_aot"] and prec["temp_bytes_flat_aot"]:
    prec["temp_shrink_plan_vs_flat"] = (
        prec["temp_bytes_flat_aot"] / max(prec["temp_bytes_plan_aot"], 1))
out["plan_replay"][f"rgg2d/n={nn}"] = prec
print(json.dumps(out))
"""


def _run_script(smoke: bool) -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    if smoke:
        env["SHARDED_SCALING_SMOKE"] = "1"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(smoke: bool = False) -> None:
    try:
        out = _run_script(smoke)
    except Exception as e:
        emit("sharded_scaling/error", 0.0, str(e)[-200:].replace(",", ";"))
        if smoke:
            raise
        return
    for n, rec in out["memory"].items():
        shrink = (rec["replicated"]["label_ints_per_device"]
                  / max(rec["sharded"]["label_ints_per_device"], 1))
        for name in ("replicated", "sharded"):
            emit(f"sharded_scaling/gnm/n={n}/{name}", rec[name]["us"],
                 f"label_ints_per_device="
                 f"{rec[name]['label_ints_per_device']};"
                 f"label_memory_shrink_vs_replicated="
                 f"{shrink if name == 'sharded' else 1.0:.1f}x")
    for key, rec in out["comm"].items():
        for name in ("baseline", "flat", "optimized"):
            r = rec[name]
            emit(f"sharded_comm/{key}/{name}", r["us"],
                 f"a2a_per_round={r['a2a_per_round']:.1f};"
                 f"routed_items={r['routed_items']:.0f};"
                 f"rounds={r['rounds']}")
        emit(f"sharded_comm/{key}/shrink", 0.0,
             f"a2a_per_round_shrink={rec['a2a_per_round_shrink']:.2f}x;"
             f"routed_items_shrink={rec['routed_items_shrink']:.2f}x;"
             f"minedges_cum_shrink={rec['minedges_cum_shrink']:.2f}x")
    for key, rec in out["ghost"].items():
        emit(f"sharded_ghost/{key}", 0.0,
             f"lookup_shrink_vs_pr3={rec['lookup_shrink']:.2f}x;"
             f"vs_vsorted={rec['lookup_shrink_vs_vsorted']:.2f}x;"
             f"lookup_items={rec['ghost']['lookup_items']:.0f};"
             f"cache_hits={rec['ghost']['cache_hits']:.0f};"
             f"pushed={rec['ghost']['pushed']:.0f}")
    for key, rec in out["plan_replay"].items():
        ts = rec.get("temp_shrink_plan_vs_flat")
        emit(f"sharded_plan/{key}", 0.0,
             f"buffer_ratio_vs_host="
             f"{rec['exec_buffer_ratio_plan_vs_host']:.3f};"
             f"rounds={rec['rounds_plan']};"
             f"minedges_plan={rec['minedges_bytes_plan']};"
             f"minedges_flat={rec['minedges_bytes_flat']};"
             f"aot_temp_shrink={'n/a' if ts is None else f'{ts:.2f}x'}")
    if smoke:
        # CI bitrot guard: the optimized engine must beat the baseline on
        # its own honest metric even at tiny n, and the shrinking
        # capacity schedule must cut the cumulative MINEDGES buffer
        # bytes vs the flat-capacity run; the tracked JSON keeps the
        # full-size numbers (do not clobber it with the tiny config)
        for key, rec in out["comm"].items():
            assert rec["a2a_per_round_shrink"] > 1.0, (key, rec)
            assert rec["routed_items_shrink"] > 1.0, (key, rec)
            assert rec["minedges_cum_shrink"] > 1.3, (key, rec)
            caps = [t["cap_edge"] for t in rec["optimized"]["rounds_trace"]]
            assert caps and max(caps) < rec["edge_capacity_flat"], (key,
                                                                   caps)
            # the ghost counters must be present in the emitted record
            # (the JSON the perf trajectory is tracked through)
            for cfg in ("baseline", "flat", "optimized"):
                assert "lookup_items" in rec[cfg], (key, cfg)
                assert "cache_hits" in rec[cfg], (key, cfg)
            for t in rec["optimized"]["rounds_trace"]:
                assert {"cache_hits", "lookup_items", "pushed_items",
                        "cap_push", "ghost"} <= set(t), t.keys()
        # ISSUE 4 acceptance (runs at n=4096 even in smoke — the ghost
        # section is cheap): the cache must cut routed endpoint-lookup
        # items >= 3x vs the coalesced-only engine on rgg2d
        for key, rec in out["ghost"].items():
            assert rec["lookup_shrink"] >= 3.0, (key, rec["lookup_shrink"])
            assert rec["ghost"]["cache_hits"] > 0, (key, rec)
        # ISSUE 5 acceptance (n=4096 even in smoke): the AOT-replayed
        # plan is bit-identical (asserted in-script) and its buffer
        # bytes land within one ladder step (2x) of the host-driven
        # schedule; the unrolled lowering must beat the flat-capacity
        # lowering on compiled temp bytes (skipped only if the backend
        # has no memory_analysis) and on analytic MINEDGES bytes always
        for key, rec in out["plan_replay"].items():
            ratio = rec["exec_buffer_ratio_plan_vs_host"]
            assert 0.5 <= ratio <= 2.0, (key, ratio)
            assert rec["minedges_bytes_plan"] < rec["minedges_bytes_flat"], (
                key, rec)
            ts = rec.get("temp_shrink_plan_vs_flat")
            assert ts is None or ts > 1.0, (key, ts)
        return
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_sharded_comm.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump({**out["comm"],
                   "ghost_lookup": out["ghost"],
                   "plan_replay": out["plan_replay"]}, f, indent=2,
                  sort_keys=True)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
    print("sharded_scaling: OK")
