"""Paper Fig. 4 analog: local preprocessing on/off on high-locality graphs.

Derived metrics: fraction of MSF edges contracted communication-free and
the number of distributed rounds that remain — the structural source of
the paper's up-to-5x speedup.  8 virtual devices in a subprocess.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json, time
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph, distributed_msf
from repro.data import generators

mesh = Mesh(np.array(jax.devices()), ("data",))
out = {}
for fam in ("grid2d", "rgg2d", "rhg", "gnm"):
    u, v, w, n = generators.generate(fam, 4096, avg_degree=8.0, seed=2)
    g, cap = build_dist_graph(u, v, w, n, 8)
    rec = {}
    for pre in (True, False):
        t0 = time.perf_counter()
        mask, wt, cnt, labels, stats = distributed_msf(
            g, n, mesh, algorithm="boruvka", axis_names=("data",),
            local_preprocessing=pre)
        jax.block_until_ready(mask)
        t1 = time.perf_counter()
        # time a second run (compiled)
        t0 = time.perf_counter()
        mask, wt, cnt, labels, stats = distributed_msf(
            g, n, mesh, algorithm="boruvka", axis_names=("data",),
            local_preprocessing=pre)
        jax.block_until_ready(mask)
        us = (time.perf_counter() - t0) * 1e6
        rec[str(pre)] = {"us": us, "mst_edges": int(cnt)}
    # contracted fraction: run preprocessing alone
    from repro.core.distributed import _local_preprocessing
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    def body(uu, vv, ww, ee):
        valid = jnp.isfinite(ww)
        labels, mst = _local_preprocessing(uu, vv, ww, ee, valid, n,
                                           ("data",))
        return jax.lax.psum(mst.sum(), ("data",))
    f = shard_map(body, mesh=mesh, in_specs=(P("data"),) * 4, out_specs=P())
    contracted = int(f(g.u, g.v, g.w, g.eid))
    rec["contracted_frac"] = contracted / max(rec["True"]["mst_edges"], 1)
    out[fam] = rec
print(json.dumps(out))
"""


def run() -> None:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        emit("preprocessing/error", 0.0, proc.stderr[-200:].replace(",", ";"))
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for fam, rec in out.items():
        on, off = rec["True"]["us"], rec["False"]["us"]
        emit(f"preprocessing/{fam}/on", on,
             f"contracted_frac={rec['contracted_frac']:.3f}")
        emit(f"preprocessing/{fam}/off", off,
             f"speedup_from_preprocessing={off / max(on, 1):.2f}x")


if __name__ == "__main__":
    run()
