"""Shared benchmark utilities. Output contract: ``name,us_per_call,derived``."""
from __future__ import annotations

import time
from typing import Callable, Optional


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
