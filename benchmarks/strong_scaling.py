"""Paper Fig. 5 analog: strong scaling — fixed graph, growing shard count.

On one physical CPU the wall time of virtual-device runs measures
*overhead*, not network speedup, so the primary derived metrics are
structural: max edges per shard (load balance) and bottleneck collective
volume per device, which are what determine scaling on real hardware.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json, time
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph, distributed_msf
from repro.data import generators

u, v, w, n = generators.generate("rmat", 8192, avg_degree=16.0, seed=3)
out = {}
for p in (1, 2, 4, 8):
    mesh = Mesh(np.array(jax.devices())[:p], ("data",))
    g, cap = build_dist_graph(u, v, w, n, p)
    mask, wt, cnt, _, _ = distributed_msf(g, n, mesh, algorithm="boruvka",
                                          axis_names=("data",))
    jax.block_until_ready(mask)
    t0 = time.perf_counter()
    mask, wt, cnt, _, _ = distributed_msf(g, n, mesh, algorithm="boruvka",
                                          axis_names=("data",))
    jax.block_until_ready(mask)
    us = (time.perf_counter() - t0) * 1e6
    out[p] = {"us": us, "cap_per_shard": cap, "mst_edges": int(cnt)}
print(json.dumps(out))
"""


def run() -> None:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        emit("strong_scaling/error", 0.0,
             proc.stderr[-200:].replace(",", ";"))
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    base_cap = out["1"]["cap_per_shard"]
    for p, rec in out.items():
        emit(f"strong_scaling/rmat/p={p}", rec["us"],
             f"edges_per_shard={rec['cap_per_shard']};"
             f"parallel_efficiency_structural="
             f"{base_cap / (int(p) * rec['cap_per_shard']):.2f}")


if __name__ == "__main__":
    run()
