"""Paper Fig. 2 analog: two-level grid all-to-all vs direct all-to-all.

The paper's win is startup cost: p-1 peers direct vs 2(sqrt(p)-1) via the
grid.  On virtual CPU devices wall time is not a network measurement, so
the primary derived metric is structural, from the compiled HLO: the
number of all-to-all ops and their replica-group sizes (= peer count per
exchange).  Runs in a subprocess with 16 virtual devices.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import textwrap

from benchmarks.common import emit

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp, numpy as np, json, time
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm.grid_alltoall import all_to_all_nd

devices = np.array(jax.devices()).reshape(4, 4)
mesh = Mesh(devices, ("row", "col"))
p = 16
x = jnp.arange(p * p * 64, dtype=jnp.float32).reshape(p * p, 64)

out = {}
for sched in ("direct", "grid"):
    f = jax.jit(shard_map(lambda t: all_to_all_nd(t, ("row", "col"), sched),
                mesh=mesh, in_specs=P(("row", "col")),
                out_specs=P(("row", "col"))))
    comp = f.lower(x).compile()
    txt = comp.as_text()
    groups = []
    for line in txt.splitlines():
        if "all-to-all" in line and "=" in line:
            m = [g for g in line.split("replica_groups=")[-1:]]
            import re as _re
            mm = _re.search(r"replica_groups=\\[(\\d+),(\\d+)\\]", line)
            if mm:
                groups.append(int(mm.group(2)))
            else:
                mm = _re.search(r"replica_groups=\\{\\{([0-9,]+)\\}", line)
                if mm:
                    groups.append(len(mm.group(1).split(",")))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        f(x).block_until_ready()
    us = (time.perf_counter() - t0) / 10 * 1e6
    out[sched] = {"n_a2a": len(groups), "peer_counts": groups, "us": us}
print(json.dumps(out))
"""


def run() -> None:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        emit("alltoall/error", 0.0, proc.stderr[-200:].replace(",", ";"))
        return
    import json
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for sched, st in out.items():
        peers = max(st["peer_counts"] or [1])
        emit(f"alltoall/{sched}", st["us"],
             f"n_a2a={st['n_a2a']};max_group={peers};"
             f"startup_proxy={st['n_a2a'] * (peers - 1)}")


if __name__ == "__main__":
    run()
