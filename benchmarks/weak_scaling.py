"""Paper Fig. 3 analog: MSF throughput (edges/s) across the six graph
families, boruvka vs filterBoruvka (dynamic engine = true compaction).

The paper scales per-core; on one CPU we scale total size and report
edges/second so the cross-family and cross-algorithm *shape* of Fig. 3
(locality helps; filtering wins on GNM/RMAT) is reproducible.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.filter_boruvka import boruvka_dynamic, filter_boruvka_dynamic
from repro.core import oracle
from repro.data import generators

FAMILIES = ["grid2d", "rgg2d", "rgg3d", "rhg", "gnm", "rmat"]


def run(n: int = 1 << 14, avg_degree: float = 16.0) -> None:
    for fam in FAMILIES:
        u, v, w, nn = generators.generate(fam, n, avg_degree, seed=1)
        m = len(u)
        _, expect = oracle.kruskal(u, v, w, nn)
        for algo, fn in (("boruvka", boruvka_dynamic),
                         ("filterBoruvka", filter_boruvka_dynamic)):
            mask, wt = fn(u, v, w, nn)
            assert abs(wt - expect) < 1e-3 * max(1.0, expect), (fam, algo)
            us = timeit(lambda: fn(u, v, w, nn), warmup=1, iters=2)
            eps = m / (us / 1e6)
            emit(f"weak_scaling/{fam}/{algo}", us,
                 f"edges={m};edges_per_s={eps:.3e}")
    # the paper's dense-GNM regime (Sec. VII: filtering wins grow with
    # density — they report up to 4x at 2^23 edges/core)
    u, v, w, nn = generators.gnm(1 << 13, (1 << 13) * 64, seed=5)
    res = {}
    for algo, fn in (("boruvka", boruvka_dynamic),
                     ("filterBoruvka", filter_boruvka_dynamic)):
        fn(u, v, w, nn)
        us = timeit(lambda: fn(u, v, w, nn), warmup=0, iters=2)
        res[algo] = us
        emit(f"weak_scaling_dense/gnm_deg128/{algo}", us,
             f"edges={len(u)}")
    emit("weak_scaling_dense/gnm_deg128/filter_speedup",
         res["boruvka"] / max(res["filterBoruvka"], 1),
         "paper_claims_up_to_4x_on_dense_gnm")


if __name__ == "__main__":
    run()
