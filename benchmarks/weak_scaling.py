"""Paper Fig. 3 analog: MSF throughput (edges/s) across the six graph
families, boruvka vs filterBoruvka (dynamic engine = true compaction).

The paper scales per-core; on one CPU we scale total size and report
edges/second so the cross-family and cross-algorithm *shape* of Fig. 3
(locality helps; filtering wins on GNM/RMAT) is reproducible.

ISSUE 10 adds the first real weak-scaling sweep over *shard count*: the
sharded engine on p = 8 / 32 / 64 virtual CPU devices (subprocess, one
XLA host-device mesh per cell) at fixed n/p = 512, rgg2d deg 8, with
the ghost cache pushed through the two-level grid multicast.  The
quantity that scales is the push fan-out: flat ships one `[L, p]` copy
matrix per dirty root (O(p) per shard, impossible past 31 shards), the
grid factors it into `[L, C]` + `[L, R]` legs (O(sqrt p)).  Each cell
records the per-round capacity curves (`cap_push` / `cap_push_col` vs
the host-exact flat-equivalent bound `cap_push_flat`), the resulting
copy-slot totals, routed/pushed item counts, and buffer bytes — flat vs
grid, bit-identical to the Kruskal oracle throughout — into
``BENCH_sharded_comm.json`` under ``grid_push``.

``python -m benchmarks.weak_scaling --smoke`` runs the CI cell: one
p = 32 (8 x 4) grid-push solve asserting oracle identity and the
copy-slot reduction vs the flat-equivalent fan-out (loose 0.5x bound;
the measured ratio tracks 2/sqrt(p)).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.filter_boruvka import boruvka_dynamic, filter_boruvka_dynamic
from repro.core import oracle
from repro.data import generators

FAMILIES = ["grid2d", "rgg2d", "rgg3d", "rhg", "gnm", "rmat"]


def run(n: int = 1 << 14, avg_degree: float = 16.0) -> None:
    for fam in FAMILIES:
        u, v, w, nn = generators.generate(fam, n, avg_degree, seed=1)
        m = len(u)
        _, expect = oracle.kruskal(u, v, w, nn)
        for algo, fn in (("boruvka", boruvka_dynamic),
                         ("filterBoruvka", filter_boruvka_dynamic)):
            mask, wt = fn(u, v, w, nn)
            assert abs(wt - expect) < 1e-3 * max(1.0, expect), (fam, algo)
            us = timeit(lambda: fn(u, v, w, nn), warmup=1, iters=2)
            eps = m / (us / 1e6)
            emit(f"weak_scaling/{fam}/{algo}", us,
                 f"edges={m};edges_per_s={eps:.3e}")
    # the paper's dense-GNM regime (Sec. VII: filtering wins grow with
    # density — they report up to 4x at 2^23 edges/core)
    u, v, w, nn = generators.gnm(1 << 13, (1 << 13) * 64, seed=5)
    res = {}
    for algo, fn in (("boruvka", boruvka_dynamic),
                     ("filterBoruvka", filter_boruvka_dynamic)):
        fn(u, v, w, nn)
        us = timeit(lambda: fn(u, v, w, nn), warmup=0, iters=2)
        res[algo] = us
        emit(f"weak_scaling_dense/gnm_deg128/{algo}", us,
             f"edges={len(u)}")
    emit("weak_scaling_dense/gnm_deg128/filter_speedup",
         res["boruvka"] / max(res["filterBoruvka"], 1),
         "paper_claims_up_to_4x_on_dense_gnm")


# --------------------------------------------------------------------------
# sharded weak scaling over p (ISSUE 10): flat vs grid ghost push
# --------------------------------------------------------------------------

GRID_SCRIPT = """
import os, json, time
ndev = int(os.environ["WS_NDEV"])
R, C = int(os.environ["WS_ROWS"]), int(os.environ["WS_COLS"])
n = int(os.environ["WS_N"])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
import jax, numpy as np
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph, quantize_capacity
from repro.core.distributed_sharded import (distributed_sharded_msf,
                                            vertices_per_shard)
from repro.data import generators

AX = ("row", "col")
mesh = Mesh(np.array(jax.devices()).reshape(R, C), AX)
p = R * C
u, v, w, n = generators.generate("rgg2d", n, avg_degree=8.0, seed=7)
g, cap = build_dist_graph(u, v, w, n, p)
kmask, _ = oracle.kruskal(u, v, w, n)
ksel = np.nonzero(kmask)[0]
out = {"p": p, "rows": R, "cols": C, "n": int(n), "edges": len(u)}

def solve(push):
    tr = []
    t0 = time.perf_counter()
    res = distributed_sharded_msf(g, n, mesh, axis_names=AX,
                                  ghost_push=push, round_trace=tr)
    jax.block_until_ready(res[0])
    us = (time.perf_counter() - t0) * 1e6
    assert int(res[4]) == 0, (push, int(res[4]))
    sel = np.unique(np.asarray(g.eid)[np.asarray(res[0])])
    assert np.array_equal(sel, ksel), (push, "edge set != oracle")
    st = res[5]
    ghost = [t for t in tr if t["ghost"]]
    rec = {"us": us, "rounds": int(st.rounds),
           "ghost_rounds": len(ghost),
           "routed_items": float(st.items),
           "pushed_items": float(st.pushed),
           "cache_hits": float(st.hits),
           "buffer_mb": float(st.bytes) / 1e6,
           "cap_push_curve": [t["cap_push"] for t in ghost],
           "cap_push_col_curve": [t["cap_push_col"] for t in ghost],
           "cap_push_flat_curve": [t["cap_push_flat"] for t in ghost]}
    # copy-slot totals: what each push shape admits per shard per solve.
    # grid: the two legs' buffers; flat on a 2-axis mesh: p * cap per
    # hop of the grid schedule (h = 2); flat-equivalent for meshes the
    # flat mask cannot reach: the host-exact flat bound cap_push_flat
    # the grid driver still computes every round, snapped to the same
    # capacity rung ladder a real flat driver would allocate at
    # (cap_push / cap_push_col are quantized, so a raw-bound
    # comparator would under-count the flat side).
    if push == "grid":
        assert all(t["grid_push"] for t in ghost), "grid rounds expected"
        rec["push_slots"] = sum(C * t["cap_push"] + R * t["cap_push_col"]
                                for t in ghost)
    else:
        assert not any(t["grid_push"] for t in ghost)
        rec["push_slots"] = sum(p * t["cap_push"] * 2 for t in ghost)
    vps = vertices_per_shard(n, p)
    rec["push_slots_flat_equiv"] = sum(
        p * quantize_capacity(t["cap_push_flat"], vps) * 2 for t in ghost)
    assert rec["ghost_rounds"] > 0 and rec["cache_hits"] > 0, push
    return rec

out["grid"] = solve("grid")
if p <= 31:           # the flat mask exists only below the 31-shard cap
    out["flat"] = solve("flat")
g_rec = out["grid"]
g_rec["slots_vs_flat_equiv"] = (g_rec["push_slots"]
                                / max(g_rec["push_slots_flat_equiv"], 1))
print(json.dumps(out))
"""

# p, (rows, cols), n — fixed n/p = 512 (weak scaling over shard count)
GRID_CELLS = ((8, (4, 2), 4096), (32, (8, 4), 16384), (64, (8, 8), 32768))


def _run_grid_cell(p: int, shape, n: int, timeout: int = 3600) -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    env.update(WS_NDEV=str(p), WS_ROWS=str(shape[0]),
               WS_COLS=str(shape[1]), WS_N=str(n))
    proc = subprocess.run([sys.executable, "-c", GRID_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"p={p}: {proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run_grid(smoke: bool = False) -> None:
    if smoke:
        # CI cell: p = 32 grid push (impossible at seed), small n
        cell = _run_grid_cell(32, (8, 4), 2048, timeout=1800)
        ratio = cell["grid"]["slots_vs_flat_equiv"]
        emit("weak_scaling/sharded/p=32/grid", cell["grid"]["us"],
             f"push_slots={cell['grid']['push_slots']};"
             f"vs_flat_equiv={ratio:.3f}x;"
             f"hits={cell['grid']['cache_hits']:.0f}")
        # oracle identity is asserted in-process; here the scaling
        # claim: two O(sqrt p) legs vs the O(p) flat fan-out — loose
        # 0.5x bound around the ~2/sqrt(32) = 0.35 expectation
        assert ratio <= 0.5, f"grid push slots {ratio:.3f}x of flat-equiv"
        assert cell["grid"]["cache_hits"] > 0
        return
    cells = {}
    for p, shape, n in GRID_CELLS:
        cell = _run_grid_cell(p, shape, n)
        cells[f"p={p}"] = cell
        for push in ("flat", "grid"):
            if push not in cell:
                continue
            r = cell[push]
            emit(f"weak_scaling/sharded/p={p}/{push}", r["us"],
                 f"push_slots={r['push_slots']};"
                 f"routed_items={r['routed_items']:.0f};"
                 f"buffer_mb={r['buffer_mb']:.2f};"
                 f"ghost_rounds={r['ghost_rounds']}")
        emit(f"weak_scaling/sharded/p={p}/grid_vs_flat_equiv", 0.0,
             f"slots_ratio={cell['grid']['slots_vs_flat_equiv']:.3f}x;"
             f"bound_2_over_sqrt_p={2 / p ** 0.5:.3f}")
    path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_sharded_comm.json"))
    bench = {}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["grid_push"] = cells
    with open(path, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
    print(f"wrote grid_push section -> {path}")


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]
    if "--grid-only" in sys.argv[1:] or smoke:
        run_grid(smoke)
    else:
        run()
        run_grid()
    print("weak_scaling: OK")
