"""Serve a small model with batched continuous-batching decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro.configs.base import get_arch
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    cfg = get_arch("qwen2-1.5b").smoke
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=96,
                      temperature=0.0)
    prompts = [[1, 2, 3], [7, 8], [11, 12, 13, 14], [5], [9, 10], [2, 4]]
    reqs = [Request(rid=i, prompt=p, max_new=24)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out[:10]}"
              f"{'...' if len(r.out) > 10 else ''}")
    print(f"{len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, 4 slots, continuous batching)")


if __name__ == "__main__":
    main()
