"""Quickstart: compute an MSF with every engine on a generated graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import oracle
from repro.core.graph import from_numpy
from repro.core.mst import minimum_spanning_forest
from repro.data import generators


def main() -> None:
    u, v, w, n = generators.generate("rgg2d", 2048, avg_degree=8.0, seed=0)
    print(f"graph: rgg2d n={n} m={len(u)}")
    edges = from_numpy(u, v, w, n)
    _, expect = oracle.kruskal(u, v, w, n)
    print(f"oracle (Kruskal) MSF weight: {expect:.1f}")
    for algo in ("boruvka", "filter_boruvka"):
        for engine in ("static", "dynamic"):
            mask, wt = minimum_spanning_forest(edges, algorithm=algo,
                                               engine=engine)
            status = "OK" if abs(float(wt) - expect) < 1e-3 * expect \
                else "MISMATCH"
            print(f"  {algo:16s} engine={engine:8s} weight={float(wt):12.1f}"
                  f"  edges={int(np.asarray(mask).sum()):6d}  [{status}]")


if __name__ == "__main__":
    main()
