"""Train a small LM for a few hundred steps with the full substrate
(AdamW, remat'd scanned layers, checkpointing + auto-resume).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainConfig, train


def synthetic_data(cfg, batch=16, seq=64, seed=0):
    """Deterministic affine-next-token stream: learnable in minutes."""
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    while True:
        t0 = rng.integers(0, V, (batch, 1))
        seq_arr = [t0]
        for _ in range(seq):
            seq_arr.append((seq_arr[-1] * 5 + 7) % V)
        arr = np.concatenate(seq_arr, axis=1)
        yield {"tokens": jnp.asarray(arr[:, :seq], jnp.int32),
               "labels": jnp.asarray(arr[:, 1:seq + 1], jnp.int32)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    # reduced config, scaled up a little beyond the smoke size
    cfg = get_arch(args.arch).smoke
    cfg = dataclasses.replace(cfg, num_layers=4, d_model=128, d_ff=384,
                              num_heads=8, num_kv_heads=4)
    tc = TrainConfig(
        opt=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        ckpt_dir=args.ckpt, ckpt_every=50, log_every=10)
    res = train(cfg, tc, synthetic_data(cfg), num_steps=args.steps)
    print(f"final loss: {res['losses'][-1]:.4f} "
          f"(from {res['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
