"""End-to-end driver (the paper's kind of workload): distributed MSF on a
device mesh — generate, 1D-partition, run Borůvka + Filter-Borůvka with
local preprocessing, validate against the oracle, report throughput.

Re-executes itself with 8 virtual devices if only one is present:

    PYTHONPATH=src python examples/distributed_mst.py [--family rmat]
"""
import argparse
import os
import sys
import time

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import oracle  # noqa: E402
from repro.core.distributed import build_dist_graph, distributed_msf  # noqa: E402
from repro.core.distributed_sharded import distributed_sharded_msf  # noqa: E402
from repro.data import generators  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="rmat",
                    choices=list(generators.FAMILIES))
    ap.add_argument("--n", type=int, default=1 << 13)
    ap.add_argument("--degree", type=float, default=16.0)
    args = ap.parse_args()

    p = jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    print(f"devices: {p}  family: {args.family}")

    u, v, w, n = generators.generate(args.family, args.n, args.degree,
                                     seed=7)
    g, cap = build_dist_graph(u, v, w, n, p)
    print(f"graph: n={n} undirected_m={len(u)} slots/shard={cap}")
    _, expect = oracle.kruskal(u, v, w, n)

    def solve(label, runner):
        t0 = time.perf_counter()
        out = runner()
        jax.block_until_ready(out[0])
        compile_run = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = runner()
        jax.block_until_ready(out[0])
        run = time.perf_counter() - t0
        wt, cnt = out[1], out[2]
        stats = out[-1]  # CommStats, last element for both engines
        rounds = max(int(stats.rounds), 1)
        ok = abs(float(wt) - expect) < 1e-3 * max(expect, 1.0)
        print(f"  {label:26s} weight={float(wt):14.1f} edges={int(cnt):7d} "
              f"[{'OK' if ok else 'MISMATCH'}] "
              f"first={compile_run:.2f}s steady={run:.3f}s "
              f"({2 * len(u) / run / 1e6:.2f} Medges/s)")
        print(f"  {'':26s} comm: {int(stats.calls)} collectives over "
              f"{int(stats.rounds)} rounds "
              f"({int(stats.calls) / rounds:.1f}/round), "
              f"{float(stats.items) / 1e3:.1f}k items, "
              f"{float(stats.bytes) / 1e6:.2f} MB")

    for algo in ("boruvka", "filter_boruvka"):
        solve(algo, lambda: distributed_msf(
            g, n, mesh, algorithm=algo, axis_names=("data",)))
        # the sharded-label engine: O(n/p) label memory per device,
        # routed label exchange instead of dense allreduce
        solve(f"{algo}+sharded_labels", lambda: distributed_sharded_msf(
            g, n, mesh, algorithm=algo, axis_names=("data",)))


if __name__ == "__main__":
    main()
