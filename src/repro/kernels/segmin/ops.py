"""Jitted public wrapper: dense per-vertex min edges via the segmin kernel."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.segmin.ref import (EID_SENTINEL, dense_min_from_candidates,
                                      segmin_candidates_ref)
from repro.kernels.segmin.segmin import segmin_candidates


@functools.partial(jax.jit,
                   static_argnames=("n", "block", "interpret", "use_pallas"))
def min_edges_dense(seg: jax.Array, w: jax.Array, eid: jax.Array,
                    alive: jax.Array, n: int, *, block: int = 512,
                    interpret: bool = True, use_pallas: bool = True
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-vertex (min weight, argmin eid) over contiguous-run edges.

    Two-phase: Pallas block-segmented scan -> tiny scatter-min combine.
    ``use_pallas=False`` routes through the pure-jnp oracle (same
    contract), which is what the CPU test/bench path uses by default.
    """
    if use_pallas:
        cw, ce = segmin_candidates(seg, w, eid, alive, block=block,
                                   interpret=interpret)
    else:
        cw, ce = segmin_candidates_ref(seg, w, eid, alive)
    return dense_min_from_candidates(seg, cw, ce, n)
