"""Jitted public wrappers around the segmented-scan machinery.

``min_edges_dense`` is the dense per-vertex min-edge entry point (the
segmin kernel's phase 2).  ``run_metadata`` exposes the same
contiguous-run discipline the kernel's Hillis-Steele scan exploits as a
standalone jnp primitive: the sharded-label engine uses it to coalesce
label-lookup requests (one routed request per distinct source vertex
instead of one per edge slot — EXPERIMENTS.md §Sharded-label engine).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.segmin.ref import (EID_SENTINEL, dense_min_from_candidates,
                                      owner_scatter_min_ref,
                                      segmin_candidates_ref)
from repro.kernels.segmin.segmin import (default_interpret,
                                         owner_scatter_min,
                                         segmin_candidates)


def run_metadata(values: jax.Array, perm: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Contiguous equal-value run structure of ``values`` ([L]).

    Returns (head [L] bool — first slot of its run, head_idx [L] int32 —
    index of each slot's run head, run_id [L] int32 — dense run number).
    ``cummax``/``cumsum`` are the log-depth Hillis-Steele scans the segmin
    kernel runs block-wise; here they run array-wide because the result
    feeds a routed exchange, not a VMEM-resident reduction.  Pure
    shape-of-``values`` metadata: compute it once per edge array and
    reuse across rounds.

    With ``perm`` (an [L] int32 permutation) the runs are computed over
    the **permuted view** ``values[perm]`` and the returned metadata is
    in permuted-slot order.  This is the v-sorted secondary index of the
    sharded MST engine (ISSUE 4): the edge array is lexicographically
    ``(u, v)``-sorted, so equal-``v`` runs are short in slot order — but
    over ``perm = argsort(v)`` every distinct ``v`` is one maximal run,
    and both endpoint columns coalesce to one routed request per
    distinct vertex.  Callers map per-slot results back through
    ``out.at[perm].set(permuted_result)``.
    """
    if perm is not None:
        values = values[perm]
    L = values.shape[0]
    if L == 0:
        # the concatenate below would fabricate a length-1 head for an
        # empty array; an empty shard has no runs (the fused combine
        # kernel calls this on possibly-empty per-shard slices)
        z = jnp.zeros((0,), jnp.int32)
        return jnp.zeros((0,), bool), z, z
    idx = jnp.arange(L, dtype=jnp.int32)
    head = jnp.concatenate([jnp.ones((1,), bool),
                            values[1:] != values[:-1]])
    head_idx = lax.cummax(jnp.where(head, idx, jnp.int32(0)))
    run_id = jnp.cumsum(head.astype(jnp.int32)) - 1
    return head, head_idx, run_id


@functools.partial(jax.jit,
                   static_argnames=("n", "block", "interpret", "use_pallas"))
def min_edges_dense(seg: jax.Array, w: jax.Array, eid: jax.Array,
                    alive: jax.Array, n: int, *, block: int = 512,
                    interpret: Optional[bool] = None, use_pallas: bool = True
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-vertex (min weight, argmin eid) over contiguous-run edges.

    Two-phase: Pallas block-segmented scan -> tiny scatter-min combine.
    ``use_pallas=False`` routes through the pure-jnp oracle (same
    contract), which is what the CPU test/bench path uses by default.
    ``interpret=None`` resolves backend-aware (compiled on TPU,
    interpreted elsewhere).
    """
    if use_pallas:
        cw, ce = segmin_candidates(seg, w, eid, alive, block=block,
                                   interpret=interpret)
    else:
        cw, ce = segmin_candidates_ref(seg, w, eid, alive)
    return dense_min_from_candidates(seg, cw, ce, n)


@functools.partial(jax.jit,
                   static_argnames=("size", "block", "out_block",
                                    "interpret", "use_pallas"))
def scatter_min_tables(idx: jax.Array, w: jax.Array, eid: jax.Array,
                       pay1: jax.Array, pay2: jax.Array, ok: jax.Array,
                       size: int, *, block: int = 512,
                       out_block: int = 256,
                       interpret: Optional[bool] = None,
                       use_pallas: bool = True
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array]:
    """Fused (w, eid)-lexicographic scatter-min, dispatchable.

    The public face of the phase-3 kernel (``segmin.owner_scatter_min``)
    with the same ``use_pallas``/``interpret`` dispatch discipline as
    ``min_edges_dense``; ``use_pallas=False`` routes through the exact
    sequential oracle (``ref.owner_scatter_min_ref``) — the comparator
    the property wall pins both against.
    """
    if use_pallas:
        return owner_scatter_min(idx, w, eid, pay1, pay2, ok, size,
                                 block=block, out_block=out_block,
                                 interpret=interpret)
    return owner_scatter_min_ref(idx, w, eid, pay1, pay2, ok, size)
