"""Pure-jnp oracle for the segmented min-edge reduction (MINEDGES).

Given edges sorted by segment id (component of the source endpoint),
produce per-edge *boundary candidates*: for the last edge of each segment
run, the (min weight, argmin edge id) of that run; +inf / sentinel
elsewhere.  A cheap scatter-min over the candidates then yields the dense
per-vertex minima — the two-phase decomposition that maps the paper's
Min-Priority-Write onto a TPU (block-local segmented scan in VMEM, tiny
cross-block combine in HBM).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

EID_SENTINEL = jnp.int32(2 ** 30)


def segmin_candidates_ref(seg: jax.Array, w: jax.Array, eid: jax.Array,
                          alive: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
    """Reference: per-edge boundary candidates via plain segment ops.

    seg:   int32 [M], non-decreasing within the array
    w:     float32 [M]
    eid:   int32 [M] (global tie-break id; (w, eid) is the total order)
    alive: bool [M]

    Returns (cand_w [M], cand_eid [M]) where entry i is the (min w, min
    eid among w-ties) of seg-run ending at i if i is the last index of its
    run, else (+inf, sentinel).
    """
    m = seg.shape[0]
    wk = jnp.where(alive, w, jnp.inf)
    ek = jnp.where(alive, eid, EID_SENTINEL)
    is_last = jnp.concatenate([seg[1:] != seg[:-1], jnp.array([True])])

    # exact segmented min via scan (reference semantics, O(m))
    def step(carry, x):
        cseg, cw, ce = carry
        s, wv, ev = x
        new = s != cseg
        bw = jnp.where(new, wv, jnp.minimum(cw, wv))
        be = jnp.where(new, ev,
                       jnp.where(wv < cw, ev,
                                 jnp.where(wv == cw, jnp.minimum(ce, ev),
                                           ce)))
        return (s, bw, be), (bw, be)

    (_, _, _), (run_w, run_e) = jax.lax.scan(
        step, (jnp.int32(-1), jnp.float32(jnp.inf), EID_SENTINEL),
        (seg, wk, ek))
    cand_w = jnp.where(is_last, run_w, jnp.inf)
    cand_eid = jnp.where(is_last, run_e, EID_SENTINEL)
    return cand_w, cand_eid


def owner_scatter_min_ref(idx: jax.Array, w: jax.Array, eid: jax.Array,
                          pay1: jax.Array, pay2: jax.Array,
                          ok: jax.Array, size: int
                          ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                     jax.Array]:
    """Sequential oracle for the fused scatter-min kernel (phase 3).

    One candidate at a time, exact lexicographic (w, eid) update with
    payload-at-winner carry — the semantics both MINEDGES sites of the
    sharded engine need, with no reliance on scatter/reduction order.
    Candidates with ``ok=False`` never contribute (their ``idx`` may be
    garbage).  Returns (wmin [size], emin [size], pay1 [size],
    pay2 [size]) with defaults (inf, sentinel, -1, -1).
    """
    init = (jnp.full((size,), jnp.inf, jnp.float32),
            jnp.full((size,), EID_SENTINEL, jnp.int32),
            jnp.full((size,), -1, jnp.int32),
            jnp.full((size,), -1, jnp.int32))

    def step(tbl, x):
        wt, et, p1t, p2t = tbl
        i, wv, ev, a, b, o = x
        i = jnp.where(o, jnp.clip(i, 0, size - 1), 0)
        better = o & (wv < wt[i])
        e_better = o & (wv == wt[i]) & (ev < et[i])
        e_tie = o & (wv == wt[i]) & (ev == et[i])
        take = better | e_better
        wt = wt.at[i].set(jnp.where(o, jnp.minimum(wt[i], wv), wt[i]))
        et = et.at[i].set(jnp.where(take, ev, et[i]))
        p1t = p1t.at[i].set(jnp.where(take, a,
                                      jnp.where(e_tie,
                                                jnp.maximum(p1t[i], a),
                                                p1t[i])))
        p2t = p2t.at[i].set(jnp.where(take, b,
                                      jnp.where(e_tie,
                                                jnp.maximum(p2t[i], b),
                                                p2t[i])))
        return (wt, et, p1t, p2t), 0

    (wt, et, p1t, p2t), _ = jax.lax.scan(
        step, init, (idx, w.astype(jnp.float32), eid, pay1, pay2, ok))
    return wt, et, p1t, p2t


def dense_min_from_candidates(seg: jax.Array, cand_w: jax.Array,
                              cand_eid: jax.Array, n: int
                              ) -> Tuple[jax.Array, jax.Array]:
    """Phase 2: scatter the (few) boundary candidates into dense [n]."""
    wmin = jnp.full((n,), jnp.inf, cand_w.dtype).at[seg].min(cand_w)
    hit = jnp.isfinite(cand_w) & (cand_w == wmin[seg])
    e = jnp.where(hit, cand_eid, EID_SENTINEL)
    emin = jnp.full((n,), EID_SENTINEL, jnp.int32).at[seg].min(e)
    return wmin, emin
