"""Pure-jnp oracle for the segmented min-edge reduction (MINEDGES).

Given edges sorted by segment id (component of the source endpoint),
produce per-edge *boundary candidates*: for the last edge of each segment
run, the (min weight, argmin edge id) of that run; +inf / sentinel
elsewhere.  A cheap scatter-min over the candidates then yields the dense
per-vertex minima — the two-phase decomposition that maps the paper's
Min-Priority-Write onto a TPU (block-local segmented scan in VMEM, tiny
cross-block combine in HBM).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

EID_SENTINEL = jnp.int32(2 ** 30)


def segmin_candidates_ref(seg: jax.Array, w: jax.Array, eid: jax.Array,
                          alive: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
    """Reference: per-edge boundary candidates via plain segment ops.

    seg:   int32 [M], non-decreasing within the array
    w:     float32 [M]
    eid:   int32 [M] (global tie-break id; (w, eid) is the total order)
    alive: bool [M]

    Returns (cand_w [M], cand_eid [M]) where entry i is the (min w, min
    eid among w-ties) of seg-run ending at i if i is the last index of its
    run, else (+inf, sentinel).
    """
    m = seg.shape[0]
    wk = jnp.where(alive, w, jnp.inf)
    ek = jnp.where(alive, eid, EID_SENTINEL)
    is_last = jnp.concatenate([seg[1:] != seg[:-1], jnp.array([True])])

    # exact segmented min via scan (reference semantics, O(m))
    def step(carry, x):
        cseg, cw, ce = carry
        s, wv, ev = x
        new = s != cseg
        bw = jnp.where(new, wv, jnp.minimum(cw, wv))
        be = jnp.where(new, ev,
                       jnp.where(wv < cw, ev,
                                 jnp.where(wv == cw, jnp.minimum(ce, ev),
                                           ce)))
        return (s, bw, be), (bw, be)

    (_, _, _), (run_w, run_e) = jax.lax.scan(
        step, (jnp.int32(-1), jnp.float32(jnp.inf), EID_SENTINEL),
        (seg, wk, ek))
    cand_w = jnp.where(is_last, run_w, jnp.inf)
    cand_eid = jnp.where(is_last, run_e, EID_SENTINEL)
    return cand_w, cand_eid


def dense_min_from_candidates(seg: jax.Array, cand_w: jax.Array,
                              cand_eid: jax.Array, n: int
                              ) -> Tuple[jax.Array, jax.Array]:
    """Phase 2: scatter the (few) boundary candidates into dense [n]."""
    wmin = jnp.full((n,), jnp.inf, cand_w.dtype).at[seg].min(cand_w)
    hit = jnp.isfinite(cand_w) & (cand_w == wmin[seg])
    e = jnp.where(hit, cand_eid, EID_SENTINEL)
    emin = jnp.full((n,), EID_SENTINEL, jnp.int32).at[seg].min(e)
    return wmin, emin
