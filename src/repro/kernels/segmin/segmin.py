"""Pallas TPU kernel: block-segmented min-edge reduction (MINEDGES).

The paper's hottest per-round primitive is the per-component minimum
incident edge (Fig. 6 phase "min edge computation"; the shared-memory
variant uses parlay Min-Priority-Write).  A GPU port would use atomics;
TPUs have none — the TPU-native decomposition is:

  phase 1 (this kernel): block-local *segmented prefix-min scan* over the
    lexicographically sorted edge array held in VMEM, emitting per-edge
    boundary candidates — (min w, argmin eid) at the last edge of every
    equal-`seg` run, neutral elements elsewhere.  The scan is
    Hillis-Steele with a run guard: log2(block) unrolled vector steps,
    pure VPU ops, no gather/scatter, no atomics.  Because the edge array
    is sorted by source vertex, each source's run is contiguous, so the
    candidate count per block is the number of distinct sources, not the
    number of edges.

  phase 2 (ops.py, plain jnp): scatter-min of the candidates into the
    dense per-vertex vectors — the same dense vectors the replicated
    base case allReduces (Section IV-D), so the kernel output feeds the
    distributed pipeline directly.

Run semantics: runs are *contiguous* stretches of equal ``seg``; the seg
array need not be globally sorted (after contraction, ``seg = labels[u]``
is only piecewise constant in u), which phase 2 handles by combining
candidates of runs that share a component.

The (w, eid) pair is reduced lexicographically — the direction-independent
total order that keeps Borůvka cycle-free under ties.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EID_SENTINEL = 2 ** 30


def default_interpret() -> bool:
    """Backend-aware Pallas mode: compile on the TPU the kernels target,
    interpret everywhere else (CPU tests/benches, GPU fallback)."""
    return jax.default_backend() != "tpu"


def _segmin_kernel(seg_ref, w_ref, eid_ref, alive_ref, cw_ref, ce_ref,
                   *, block: int):
    seg = seg_ref[...]
    w = w_ref[...].astype(jnp.float32)
    eid = eid_ref[...]
    alive = alive_ref[...] != 0

    inf = jnp.float32(jnp.inf)
    sent = jnp.int32(EID_SENTINEL)
    val_w = jnp.where(alive, w, inf)
    val_e = jnp.where(alive, eid, sent)

    # Hillis-Steele segmented prefix-min: after step d the value at i
    # covers the last 2d elements of its run; min is idempotent, so
    # over-inclusive windows within one run are harmless.
    d = 1
    while d < block:
        pad_w = jnp.full((d,), inf, jnp.float32)
        pad_e = jnp.full((d,), sent, jnp.int32)
        pad_s = jnp.full((d,), -1, seg.dtype)
        sh_w = jnp.concatenate([pad_w, val_w[:-d]])
        sh_e = jnp.concatenate([pad_e, val_e[:-d]])
        sh_s = jnp.concatenate([pad_s, seg[:-d]])
        same = sh_s == seg
        better = same & (sh_w < val_w)
        tie = same & (sh_w == val_w)
        val_e = jnp.where(better, sh_e,
                          jnp.where(tie, jnp.minimum(val_e, sh_e), val_e))
        val_w = jnp.where(better, sh_w, val_w)
        d *= 2

    # boundary = last edge of its run inside this block
    nxt = jnp.concatenate([seg[1:], jnp.full((1,), -1, seg.dtype)])
    is_last = seg != nxt  # the final element always differs from -1
    cw_ref[...] = jnp.where(is_last, val_w, inf)
    ce_ref[...] = jnp.where(is_last, val_e, sent)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segmin_candidates(seg: jax.Array, w: jax.Array, eid: jax.Array,
                      alive: jax.Array, *, block: int = 512,
                      interpret: Optional[bool] = None):
    """Phase-1 kernel call. Arrays are padded to a multiple of ``block``.

    Padding entries must carry alive=False (any seg value).  Returns
    (cand_w f32 [M], cand_eid i32 [M]).  ``interpret=None`` resolves
    via ``default_interpret()`` (compiled on TPU, interpreted elsewhere).
    """
    if interpret is None:
        interpret = default_interpret()
    m = seg.shape[0]
    block = min(block, max(m, 8))
    pad = (-m) % block
    if pad:
        seg = jnp.concatenate([seg, jnp.full((pad,), -1, seg.dtype)])
        w = jnp.concatenate([w, jnp.full((pad,), jnp.inf, w.dtype)])
        eid = jnp.concatenate([eid, jnp.full((pad,), EID_SENTINEL,
                                             eid.dtype)])
        alive = jnp.concatenate([alive, jnp.zeros((pad,), alive.dtype)])
    mp = seg.shape[0]
    grid = (mp // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    cand_w, cand_e = pl.pallas_call(
        functools.partial(_segmin_kernel, block=block),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((mp,), jnp.float32),
                   jax.ShapeDtypeStruct((mp,), jnp.int32)],
        interpret=interpret,
    )(seg, w, eid, alive.astype(jnp.int8))
    return cand_w[:m], cand_e[:m]
