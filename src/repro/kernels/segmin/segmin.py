"""Pallas TPU kernel: block-segmented min-edge reduction (MINEDGES).

The paper's hottest per-round primitive is the per-component minimum
incident edge (Fig. 6 phase "min edge computation"; the shared-memory
variant uses parlay Min-Priority-Write).  A GPU port would use atomics;
TPUs have none — the TPU-native decomposition is:

  phase 1 (this kernel): block-local *segmented prefix-min scan* over the
    lexicographically sorted edge array held in VMEM, emitting per-edge
    boundary candidates — (min w, argmin eid) at the last edge of every
    equal-`seg` run, neutral elements elsewhere.  The scan is
    Hillis-Steele with a run guard: log2(block) unrolled vector steps,
    pure VPU ops, no gather/scatter, no atomics.  Because the edge array
    is sorted by source vertex, each source's run is contiguous, so the
    candidate count per block is the number of distinct sources, not the
    number of edges.

  phase 2 (ops.py, plain jnp): scatter-min of the candidates into the
    dense per-vertex vectors — the same dense vectors the replicated
    base case allReduces (Section IV-D), so the kernel output feeds the
    distributed pipeline directly.

Run semantics: runs are *contiguous* stretches of equal ``seg``; the seg
array need not be globally sorted (after contraction, ``seg = labels[u]``
is only piecewise constant in u), which phase 2 handles by combining
candidates of runs that share a component.

  phase 3 (``owner_scatter_min``, ISSUE 8): the fused min-semiring
    scatter the sharded engine's MINEDGES runs on both sides of the
    routed exchange — the pre-routing per-run (w, eid)-argmin combine
    and the owner-side per-component scatter-min — as one Pallas kernel
    over arbitrary (unsorted) slot indices, replacing the five-scatter
    jnp sequence without materialising its intermediate tables.

The (w, eid) pair is reduced lexicographically — the direction-independent
total order that keeps Borůvka cycle-free under ties.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EID_SENTINEL = 2 ** 30


def default_interpret() -> bool:
    """Backend-aware Pallas mode: compile on the TPU the kernels target,
    interpret everywhere else (CPU tests/benches, GPU fallback)."""
    return jax.default_backend() != "tpu"


def _segmin_kernel(seg_ref, w_ref, eid_ref, alive_ref, cw_ref, ce_ref,
                   *, block: int):
    seg = seg_ref[...]
    w = w_ref[...].astype(jnp.float32)
    eid = eid_ref[...]
    alive = alive_ref[...] != 0

    inf = jnp.float32(jnp.inf)
    sent = jnp.int32(EID_SENTINEL)
    val_w = jnp.where(alive, w, inf)
    val_e = jnp.where(alive, eid, sent)

    # Hillis-Steele segmented prefix-min: after step d the value at i
    # covers the last 2d elements of its run; min is idempotent, so
    # over-inclusive windows within one run are harmless.
    d = 1
    while d < block:
        pad_w = jnp.full((d,), inf, jnp.float32)
        pad_e = jnp.full((d,), sent, jnp.int32)
        pad_s = jnp.full((d,), -1, seg.dtype)
        sh_w = jnp.concatenate([pad_w, val_w[:-d]])
        sh_e = jnp.concatenate([pad_e, val_e[:-d]])
        sh_s = jnp.concatenate([pad_s, seg[:-d]])
        same = sh_s == seg
        better = same & (sh_w < val_w)
        tie = same & (sh_w == val_w)
        val_e = jnp.where(better, sh_e,
                          jnp.where(tie, jnp.minimum(val_e, sh_e), val_e))
        val_w = jnp.where(better, sh_w, val_w)
        d *= 2

    # boundary = last edge of its run inside this block
    nxt = jnp.concatenate([seg[1:], jnp.full((1,), -1, seg.dtype)])
    is_last = seg != nxt  # the final element always differs from -1
    cw_ref[...] = jnp.where(is_last, val_w, inf)
    ce_ref[...] = jnp.where(is_last, val_e, sent)


def _scatter_min_kernel(idx_ref, w_ref, eid_ref, p1_ref, p2_ref, ok_ref,
                        wt_ref, et_ref, p1t_ref, p2t_ref, *,
                        out_block: int, block: int):
    """Fused min-semiring scatter: one grid step folds one candidate
    block into one output tile's (w, eid, payload) accumulator.

    Grid is (out tiles, candidate blocks) with the candidate dimension
    innermost, so the output tile persists in VMEM across the whole
    candidate sweep (initialised at the first step).  Per step the
    block builds the [out_block, block] one-hot hit matrix — the
    TPU-native replacement for the scatter the jnp path pays five times
    — and reduces it to the tile's block-local (min w, min eid among
    w-ties, payload at the (w, eid) winner); a lexicographic combine
    then folds the block triple into the accumulator.  Payload-at-winner
    is reduced with max, which is exact because candidates tied on the
    full (w, eid) key carry identical payloads (both directed copies of
    an undirected edge ship the same eid and the same opposing
    component) — the same argument the jnp path's ``.at[].max`` relies
    on.

    A sparse-band guard skips candidate blocks whose (ok-gated) index
    range cannot touch this tile: for the pre-routing per-run combine
    the index column (``run_id``) is non-decreasing, so each candidate
    block intersects O(1) tiles and the sweep degenerates to the
    band — the fused equivalent of the segmented scan's contiguity
    exploitation.  Owner-side (unsorted ``comp - base``) it simply
    never fires.
    """
    c = pl.program_id(1)

    inf = jnp.float32(jnp.inf)
    sent = jnp.int32(EID_SENTINEL)

    @pl.when(c == 0)
    def _init():
        wt_ref[...] = jnp.full((out_block,), inf, jnp.float32)
        et_ref[...] = jnp.full((out_block,), sent, jnp.int32)
        p1t_ref[...] = jnp.full((out_block,), -1, jnp.int32)
        p2t_ref[...] = jnp.full((out_block,), -1, jnp.int32)

    idx = idx_ref[...]
    ok = ok_ref[...] != 0
    row0 = pl.program_id(0) * out_block
    lo = jnp.min(jnp.where(ok, idx, jnp.int32(2 ** 31 - 1)))
    hi = jnp.max(jnp.where(ok, idx, jnp.int32(-1)))

    @pl.when((lo < row0 + out_block) & (hi >= row0))
    def _accumulate():
        w = w_ref[...].astype(jnp.float32)
        eid = eid_ref[...]
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32,
                                               (out_block, block), 0)
        hit = (idx[None, :] == rows) & ok[None, :]
        wv = jnp.where(hit, w[None, :], inf)
        wb = jnp.min(wv, axis=1)
        tie = hit & (wv == wb[:, None])
        eb = jnp.min(jnp.where(tie, eid[None, :], sent), axis=1)
        winm = tie & (eid[None, :] == eb[:, None])
        p1b = jnp.max(jnp.where(winm, p1_ref[...][None, :], -1), axis=1)
        p2b = jnp.max(jnp.where(winm, p2_ref[...][None, :], -1), axis=1)

        cw, ce = wt_ref[...], et_ref[...]
        better = wb < cw
        wtie = wb == cw
        e_better = wtie & (eb < ce)
        e_tie = wtie & (eb == ce)
        take = better | e_better
        wt_ref[...] = jnp.minimum(cw, wb)
        et_ref[...] = jnp.where(better, eb,
                                jnp.where(wtie, jnp.minimum(ce, eb), ce))
        p1t_ref[...] = jnp.where(take, p1b,
                                 jnp.where(e_tie,
                                           jnp.maximum(p1t_ref[...], p1b),
                                           p1t_ref[...]))
        p2t_ref[...] = jnp.where(take, p2b,
                                 jnp.where(e_tie,
                                           jnp.maximum(p2t_ref[...], p2b),
                                           p2t_ref[...]))


@functools.partial(jax.jit, static_argnames=("size", "block", "out_block",
                                             "interpret"))
def owner_scatter_min(idx: jax.Array, w: jax.Array, eid: jax.Array,
                      pay1: jax.Array, pay2: jax.Array, ok: jax.Array,
                      size: int, *, block: int = 512,
                      out_block: int = 256,
                      interpret: Optional[bool] = None):
    """Fused (w, eid)-lexicographic scatter-min into ``size`` slots.

    The phase-3 MINEDGES kernel (ISSUE 8): candidates ``(idx, w, eid,
    pay1, pay2)`` gated by ``ok`` reduce into per-slot tables — exactly
    the reduction both MINEDGES sites of the sharded engine perform:

      * owner side, ``idx = comp - base``: the routed candidates'
        per-owned-component winner tables;
      * pre-routing combine, ``idx = run_id``: the per-source-run
        (w, eid)-argmin tables (run ids are one more ownership index,
        so one kernel serves both sites — the min-semiring framing of
        PAPERS.md arxiv 2110.04865 made concrete).

    Returns ``(wmin f32 [size], emin i32 [size], pay1 i32 [size],
    pay2 i32 [size])`` with defaults ``(inf, EID_SENTINEL, -1, -1)``;
    ``pay*`` carry the payloads of the (w, eid) winner.  Bit-identical
    to the jnp ``.at[].min``/``.at[].max`` path for any candidate order
    (min/max are associative-commutative and payloads are constant
    across exact (w, eid) ties).  ``ok=False`` lanes never contribute —
    their ``idx`` may be garbage.  Same block/``interpret`` discipline
    as ``segmin_candidates``.
    """
    if interpret is None:
        interpret = default_interpret()
    L = idx.shape[0]
    if L == 0 or size == 0:
        return (jnp.full((size,), jnp.inf, jnp.float32),
                jnp.full((size,), EID_SENTINEL, jnp.int32),
                jnp.full((size,), -1, jnp.int32),
                jnp.full((size,), -1, jnp.int32))
    block = min(block, max(L, 8))
    out_block = min(out_block, max(size, 8))
    pad = (-L) % block
    if pad:
        idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
        w = jnp.concatenate([w, jnp.full((pad,), jnp.inf, w.dtype)])
        eid = jnp.concatenate([eid, jnp.full((pad,), EID_SENTINEL,
                                             eid.dtype)])
        pay1 = jnp.concatenate([pay1, jnp.full((pad,), -1, pay1.dtype)])
        pay2 = jnp.concatenate([pay2, jnp.full((pad,), -1, pay2.dtype)])
        ok = jnp.concatenate([ok, jnp.zeros((pad,), ok.dtype)])
    sp = size + ((-size) % out_block)
    grid = (sp // out_block, idx.shape[0] // block)
    cspec = pl.BlockSpec((block,), lambda o, c: (c,))
    ospec = pl.BlockSpec((out_block,), lambda o, c: (o,))
    wt, et, p1t, p2t = pl.pallas_call(
        functools.partial(_scatter_min_kernel, out_block=out_block,
                          block=block),
        grid=grid,
        in_specs=[cspec] * 6,
        out_specs=[ospec] * 4,
        out_shape=[jax.ShapeDtypeStruct((sp,), jnp.float32),
                   jax.ShapeDtypeStruct((sp,), jnp.int32),
                   jax.ShapeDtypeStruct((sp,), jnp.int32),
                   jax.ShapeDtypeStruct((sp,), jnp.int32)],
        interpret=interpret,
    )(idx, w, eid, pay1, pay2, ok.astype(jnp.int8))
    return wt[:size], et[:size], p1t[:size], p2t[:size]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segmin_candidates(seg: jax.Array, w: jax.Array, eid: jax.Array,
                      alive: jax.Array, *, block: int = 512,
                      interpret: Optional[bool] = None):
    """Phase-1 kernel call. Arrays are padded to a multiple of ``block``.

    Padding entries must carry alive=False (any seg value).  Returns
    (cand_w f32 [M], cand_eid i32 [M]).  ``interpret=None`` resolves
    via ``default_interpret()`` (compiled on TPU, interpreted elsewhere).
    """
    if interpret is None:
        interpret = default_interpret()
    m = seg.shape[0]
    block = min(block, max(m, 8))
    pad = (-m) % block
    if pad:
        seg = jnp.concatenate([seg, jnp.full((pad,), -1, seg.dtype)])
        w = jnp.concatenate([w, jnp.full((pad,), jnp.inf, w.dtype)])
        eid = jnp.concatenate([eid, jnp.full((pad,), EID_SENTINEL,
                                             eid.dtype)])
        alive = jnp.concatenate([alive, jnp.zeros((pad,), alive.dtype)])
    mp = seg.shape[0]
    grid = (mp // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    cand_w, cand_e = pl.pallas_call(
        functools.partial(_segmin_kernel, block=block),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((mp,), jnp.float32),
                   jax.ShapeDtypeStruct((mp,), jnp.int32)],
        interpret=interpret,
    )(seg, w, eid, alive.astype(jnp.int8))
    return cand_w[:m], cand_e[:m]
