"""Pure-jnp oracle for the fused relabel + self-loop-kill (RELABEL)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def relabel_ref(u: jax.Array, v: jax.Array, w: jax.Array,
                labels: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Return (ru, rv, w') with w' = +inf for self-loops/padding.

    Self-loops are edges whose endpoints fell into the same component —
    these are the edges the paper's RELABEL discards; with static shapes
    they are neutralised instead (weight +inf never wins a reduction).
    """
    ru = labels[u]
    rv = labels[v]
    dead = (ru == rv) | ~jnp.isfinite(w)
    wp = jnp.where(dead, jnp.inf, w).astype(w.dtype)
    return ru, rv, wp
