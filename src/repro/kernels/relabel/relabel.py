"""Pallas TPU kernel: fused gather-relabel + self-loop neutralisation.

The paper's RELABEL scans all edges, looks up both endpoints' component
labels, and drops self-loops (Section IV-C).  On TPU this is a
gather-bound streaming op: edges stream HBM->VMEM in blocks while the
label table stays resident in VMEM, and the self-loop test + weight
neutralisation fuse into the same pass (one HBM round trip instead of
three).

VMEM budget: the label table is [n'] int32.  The kernel targets the
post-contraction regime (the paper's base-case threshold, Section IV-D:
n' <= max(2 * #PEs, 35_000) — a ~140 KB table), where the whole table
fits VMEM many times over.  Before the threshold the framework uses the
jnp path whose gathers XLA blocks itself.

Block layout: edge blocks [block]; the label table uses a single whole-
array BlockSpec so Mosaic keeps it resident across grid steps.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.segmin.segmin import default_interpret


def _relabel_kernel(u_ref, v_ref, w_ref, lab_ref, ru_ref, rv_ref, wp_ref):
    u = u_ref[...]
    v = v_ref[...]
    w = w_ref[...]
    labels = lab_ref[...]
    ru = labels[u]
    rv = labels[v]
    dead = (ru == rv) | ~jnp.isfinite(w)
    ru_ref[...] = ru
    rv_ref[...] = rv
    wp_ref[...] = jnp.where(dead, jnp.float32(jnp.inf), w).astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def relabel(u: jax.Array, v: jax.Array, w: jax.Array, labels: jax.Array,
            *, block: int = 512, interpret: Optional[bool] = None
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused relabel. Returns (ru, rv, w') with self-loops at +inf.
    ``interpret=None`` resolves backend-aware (compiled on TPU only)."""
    if interpret is None:
        interpret = default_interpret()
    m = u.shape[0]
    n = labels.shape[0]
    block = min(block, max(m, 8))
    pad = (-m) % block
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,), u.dtype)])
        v = jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
        w = jnp.concatenate([w, jnp.full((pad,), jnp.inf, w.dtype)])
    mp = u.shape[0]
    espec = pl.BlockSpec((block,), lambda i: (i,))
    lspec = pl.BlockSpec((n,), lambda i: (0,))  # resident across steps
    ru, rv, wp = pl.pallas_call(
        _relabel_kernel,
        grid=(mp // block,),
        in_specs=[espec, espec, espec, lspec],
        out_specs=[espec, espec, espec],
        out_shape=[jax.ShapeDtypeStruct((mp,), jnp.int32),
                   jax.ShapeDtypeStruct((mp,), jnp.int32),
                   jax.ShapeDtypeStruct((mp,), w.dtype)],
        interpret=interpret,
    )(u, v, w, labels)
    return ru[:m], rv[:m], wp[:m]
