"""Jitted public wrapper for the fused relabel kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.relabel.ref import relabel_ref
from repro.kernels.relabel.relabel import relabel as relabel_pallas


@functools.partial(jax.jit, static_argnames=("block", "interpret",
                                             "use_pallas"))
def relabel_edges(u: jax.Array, v: jax.Array, w: jax.Array,
                  labels: jax.Array, *, block: int = 512,
                  interpret: Optional[bool] = None, use_pallas: bool = True
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``interpret=None`` resolves backend-aware (compiled on TPU only)."""
    if use_pallas:
        return relabel_pallas(u, v, w, labels, block=block,
                              interpret=interpret)
    return relabel_ref(u, v, w, labels)
