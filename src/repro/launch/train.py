"""Training launcher: --arch <id> [--smoke] on the current device set.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --smoke --steps 100 --ckpt /tmp/ck

On a real TPU pod slice this is the process entry point (one process per
host; jax.distributed.initialize() is called when the env provides a
coordinator).  On CPU it trains the reduced config end-to-end with the
full substrate (ZeRO sharding when a mesh is requested, checkpoints,
auto-resume).
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 4x2 -> Mesh((4,2), (data, model))")
    args = ap.parse_args()

    if args.mesh and "XLA_FLAGS" not in os.environ:
        # virtual devices for local mesh experimentation
        n = 1
        for d in args.mesh.split("x"):
            n *= int(d)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n}"

    import jax
    import numpy as np
    from repro.configs.base import get_arch
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import TrainConfig, train

    if "coordinator_address" in os.environ.get("JAX_DIST", ""):
        jax.distributed.initialize()

    entry = get_arch(args.arch)
    cfg = entry.smoke if args.smoke else entry.config

    mesh = None
    if args.mesh:
        dims = tuple(int(d) for d in args.mesh.split("x"))
        names = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
            ("pod", "data", "model")
        mesh = jax.make_mesh(dims, names)

    def data_iter():
        rng = np.random.default_rng(0)
        import jax.numpy as jnp
        V = cfg.vocab_size
        while True:
            t0 = rng.integers(0, V, (args.batch, 1))
            seq = [t0]
            for _ in range(args.seq):
                seq.append((seq[-1] * 5 + 7) % V)
            arr = np.concatenate(seq, axis=1)
            batch = {"tokens": jnp.asarray(arr[:, :args.seq], jnp.int32),
                     "labels": jnp.asarray(arr[:, 1:args.seq + 1],
                                           jnp.int32)}
            if cfg.frontend == "patch":
                batch["patch_embeds"] = jnp.zeros(
                    (args.batch, cfg.frontend_len, cfg.d_model),
                    jnp.bfloat16)
            if cfg.frontend == "audio":
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.frontend_len, cfg.d_model),
                    jnp.bfloat16)
            yield batch

    tc = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
        microbatches=args.microbatches,
        ckpt_dir=args.ckpt, ckpt_every=max(args.steps // 4, 1))
    res = train(cfg, tc, data_iter(), num_steps=args.steps, mesh=mesh)
    print(f"done: final loss {res['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
