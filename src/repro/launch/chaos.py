import os
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

"""Chaos driver (ISSUE 7): the fault matrix, end to end.

    PYTHONPATH=src python -m repro.launch.chaos --smoke
    PYTHONPATH=src python -m repro.launch.chaos          # full, writes BENCH

Runs every fault class of ``comm/faults.py`` against the sharded engine
across the graph-family × execution-path grid and asserts the serving
stack's one robustness invariant: **an injected fault is either
detected or tolerated, never silent.**

  * *detected* — the run raised a typed error (overflow under strict
    replay, ``VerifyFailure``, ``CapacityError``), or the returned
    forest failed the fault-free on-device verifier armed with the
    Kruskal oracle's ground-truth weight and edge count;
  * *tolerated* — the final MSF is bit-identical to the fault-free
    baseline (the redundancy of the directed edge layout or the
    round structure absorbed the fault);
  * *SILENT* — anything else: a result that differs from the truth and
    passed verification.  One silent cell fails the driver (exit 1).

Fault → site pairings are chosen to hit each transport fault where it
hurts: capacity clipping and shard stalls at MINEDGES (the round's main
exchange), payload corruption on the in-flight candidate weights,
destination shuffles on the pointer-chase hops, receive-slot drops on
the ghost push.  Each cell replays a fault-free measured plan under
``faults.inject`` with ``replan=False`` — strict mode, so a misfit is a
raise, never a quiet fallback that would mask the fault.

After the matrix the driver re-runs every cell's graph fault-free and
asserts bit-identity against the pre-matrix baselines — injection must
not perturb the fault-free path (the hooks compile away when no plan is
active).  It also measures the warm-path overhead of
``execute_plan(verify=True)`` (the O(n/p) self-check the gateway can
switch on); full mode merges a ``chaos`` section with the matrix and
the overhead numbers into ``BENCH_sharded_comm.json``.

Recovery cells (ISSUE 9) kill the engine *mid-run*: an ``abort`` fault
raises ``ShardAbort`` at a round past the checkpoint cadence, the cell
resumes from the last certified ``MSFCheckpoint`` and asserts the
result is **bit-identical** to the fault-free run with re-executed
rounds ≤ the cadence; the elastic cell additionally remaps the
checkpoint onto a p/2-shard sub-mesh (re-partitioned edges, re-owner-
mapped vertex state) and asserts the exact Kruskal-oracle edge set.
Both run in ``--smoke`` (the CI gate) and in full mode.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from typing import List, Optional, Tuple  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.comm import faults  # noqa: E402
from repro.core import oracle  # noqa: E402
from repro.core.distributed import build_dist_graph  # noqa: E402
from repro.core.distributed_sharded import (  # noqa: E402
    distributed_sharded_msf, execute_plan, execute_plan_batched,
    plan_sharded_msf)
from repro.core.graph import CapacityError  # noqa: E402
from repro.core.verify import verify_forest  # noqa: E402
from repro.data import generators  # noqa: E402

# fault class -> FaultSpec aimed at the exchange site where it bites.
# corrupt flips an exponent bit (26) on a fraction of in-flight
# candidate weights: a sign-sized perturbation, so a swayed argmin
# picks an edge whose true weight differs from the oracle's by far
# more than the verifier's float tolerance — never an in-tolerance swap.
FAULT_MATRIX: Tuple[Tuple[str, faults.FaultSpec], ...] = (
    ("clip", faults.FaultSpec(kind="clip", site="minedges",
                              cap_frac=0.25)),
    ("corrupt", faults.FaultSpec(kind="corrupt", site="minedges",
                                 fraction=0.25, bit=26)),
    ("shuffle_dest", faults.FaultSpec(kind="shuffle_dest",
                                      site="contract", fraction=1.0)),
    ("drop", faults.FaultSpec(kind="drop", site="push", fraction=0.5)),
    ("stall", faults.FaultSpec(kind="stall", site="minedges", shard=0)),
)


def _build(family: str, n: int, p: int, seed: int,
           cap: Optional[int] = None):
    """One generated graph as (DistGraph, oracle mask/weight/count)."""
    u, v, w, n = generators.generate(family, n, avg_degree=8.0, seed=seed)
    if cap is None:
        cap = max(1, -(-2 * len(u) // p))
    g = build_dist_graph(u, v, w, n, p, cap=cap)[0]
    km, kw = oracle.kruskal(u, v, w, n)
    return g, km, kw, int(km.sum()), cap, len(u)


def _oracle_identical(g, mask, km) -> bool:
    eid = np.asarray(g.eid)
    return np.array_equal(np.unique(eid[np.asarray(mask)]),
                          np.flatnonzero(km))


def _classify(g, n, mesh, plan, spec, seed, base_mask, kw, kc):
    """Run one planned replay under injection and classify the outcome."""
    fp = faults.FaultPlan(seed=seed, specs=(spec,))
    injected = -1.0
    try:
        with faults.inject(fp):
            out = execute_plan(g, n, mesh, plan, replan=False)
            injected = float(out[5].injected)
    except (RuntimeError, CapacityError) as e:
        return "detected", f"raised {type(e).__name__}: {e}", injected
    mask = np.asarray(out[0])
    if np.array_equal(mask, base_mask):
        return "tolerated", "bit-identical MSF", injected
    rep = verify_forest(g, n, mesh, out[0], out[3], expected_weight=kw,
                        expected_count=kc, raise_on_fail=False)
    if not rep.ok:
        return "detected", "verify: " + "; ".join(rep.reasons), injected
    return "SILENT", "result differs from oracle yet verified", injected


def _classify_batched(graphs, n, mesh, plan, spec, seed, truths):
    """Same classification through the vmapped batched path."""
    fp = faults.FaultPlan(seed=seed, specs=(spec,))
    try:
        with faults.inject(fp):
            results, _ = execute_plan_batched(graphs, n, mesh, plan,
                                              replan=False)
    except (RuntimeError, CapacityError) as e:
        return "detected", f"raised {type(e).__name__}: {e}"
    verdicts = []
    for g, res, (base_mask, kw, kc) in zip(graphs, results, truths):
        mask = np.asarray(res[0])
        if np.array_equal(mask, base_mask):
            verdicts.append("tolerated")
            continue
        rep = verify_forest(g, n, mesh, res[0], res[3],
                            expected_weight=kw, expected_count=kc,
                            raise_on_fail=False)
        verdicts.append("detected" if not rep.ok else "SILENT")
    if "SILENT" in verdicts:
        return "SILENT", f"per-graph verdicts: {verdicts}"
    if "detected" in verdicts:
        return "detected", f"per-graph verdicts: {verdicts}"
    return "tolerated", "all graphs bit-identical"


def run_matrix(families, n: int, seed: int, batched: bool,
               verbose: bool = True) -> List[dict]:
    mesh = Mesh(np.array(jax.devices()), ("data",))
    p = mesh.devices.size
    cells: List[dict] = []
    baselines = []  # (graph, plan, base_mask, family) for the re-check
    for family in families:
        g, km, kw, kc, cap, m = _build(family, n, p, seed)
        plan = plan_sharded_msf(g, n, mesh)
        out0 = execute_plan(g, n, mesh, plan, replan=False)
        base_mask = np.asarray(out0[0])
        assert _oracle_identical(g, base_mask, km), \
            f"{family}: fault-free baseline != Kruskal oracle"
        baselines.append((g, plan, base_mask, family))
        for fault, spec in FAULT_MATRIX:
            verdict, why, injected = _classify(
                g, n, mesh, plan, spec, seed, base_mask, kw, kc)
            cells.append({"fault": fault, "family": family,
                          "path": "planned", "verdict": verdict,
                          "why": why, "injected_items": injected})
            if verbose:
                print(f"  {fault:<12} {family:<6} planned  -> {verdict}"
                      f"  ({why[:90]})")
        if batched:
            # two same-shape graphs through one vmapped dispatch; the
            # shared capacity is the max of the two exact needs
            e1 = generators.generate(family, n, avg_degree=8.0,
                                     seed=seed)
            e2 = generators.generate(family, n, avg_degree=8.0,
                                     seed=seed + 1)
            bcap = max(max(1, -(-2 * len(e[0]) // p)) for e in (e1, e2))
            pair, truths = [], []
            for u, v, w, _n in (e1, e2):
                pair.append(build_dist_graph(u, v, w, n, p,
                                             cap=bcap)[0])
                km_i, kw_i = oracle.kruskal(u, v, w, n)
                truths.append((km_i, kw_i, int(km_i.sum())))
            # the classification cells replay with replan=False, so the
            # plan must strictly fit BOTH graphs fault-free: measure on
            # the first, pad generously, and if the second still needs
            # residual rounds fall back to batching the first twice
            bplan = plan_sharded_msf(pair[0], n, mesh).pad(0.5)
            try:
                bres, _ = execute_plan_batched(pair, n, mesh, bplan,
                                               replan=False)
            except RuntimeError:
                pair[1] = pair[0]
                truths[1] = truths[0]
                bres, _ = execute_plan_batched(pair, n, mesh, bplan,
                                               replan=False)
            for g_i, res, (km_i, kw_i, kc_i) in zip(pair, bres, truths):
                assert _oracle_identical(g_i, np.asarray(res[0]), km_i), \
                    f"{family}: batched baseline != oracle"
            # baseline masks + oracle scalars for per-graph verdicts
            truths = [(np.asarray(r[0]), t[1], t[2])
                      for r, t in zip(bres, truths)]
            gg, g2 = pair
            for fault, spec in FAULT_MATRIX:
                verdict, why = _classify_batched(
                    [gg, g2], n, mesh, bplan, spec, seed, truths)
                cells.append({"fault": fault, "family": family,
                              "path": "batched", "verdict": verdict,
                              "why": why})
                if verbose:
                    print(f"  {fault:<12} {family:<6} batched  -> "
                          f"{verdict}  ({why[:90]})")
    # fault-free path must be unperturbed by everything above: with no
    # active FaultPlan the hooks are dead code and every cache was
    # cleared on the last inject() exit, so this retraces from scratch
    for g, plan, base_mask, family in baselines:
        out = execute_plan(g, n, mesh, plan, replan=False)
        assert np.array_equal(np.asarray(out[0]), base_mask), \
            f"{family}: fault-free path perturbed after the fault matrix"
    if verbose:
        print(f"  fault-free re-run: {len(baselines)} baselines "
              "bit-identical")
    return cells


# deputy-hop faults (ISSUE 10): the grid ghost push routes every dirty
# label through an intermediate rank, so a fault on EITHER leg must
# surface through the same detected-or-tolerated contract as the flat
# push.  corrupt is omitted on purpose: the push payload is int32
# (vid, parent) and the bit-flip hook only touches float32 lanes.
GRID_FAULT_MATRIX: Tuple[Tuple[str, faults.FaultSpec], ...] = (
    ("drop", faults.FaultSpec(kind="drop", site="ghost_push_col",
                              fraction=0.5)),
    ("drop", faults.FaultSpec(kind="drop", site="ghost_push_row",
                              fraction=0.5)),
    ("shuffle_dest", faults.FaultSpec(kind="shuffle_dest",
                                      site="ghost_push_row",
                                      fraction=1.0)),
    ("stall", faults.FaultSpec(kind="stall", site="ghost_push_col",
                               shard=0)),
)


def run_grid_push_cells(n: int, seed: int,
                        verbose: bool = True) -> List[dict]:
    """Fault cells on the two legs of the grid ghost push (ISSUE 10).

    A (row, col)-factored mesh, a measured plan with the grid lever
    frozen in, strict ``replan=False`` replay under each
    ``GRID_FAULT_MATRIX`` spec: a fault on the owner->deputy leg
    (``ghost_push_row``) or the deputy->rows leg (``ghost_push_col``)
    must be detected or tolerated, never silent.
    """
    devs = np.array(jax.devices())
    rows = 4 if devs.size % 4 == 0 else 2
    mesh = Mesh(devs.reshape(rows, devs.size // rows), ("row", "col"))
    p = devs.size
    g, km, kw, kc, _, _ = _build("rgg2d", n, p, seed)
    plan = plan_sharded_msf(g, n, mesh, ghost_push="grid")
    assert plan.grid_push and plan.ghost is not None, \
        "grid-push chaos needs the ghost cache live on the grid rung"
    out0 = execute_plan(g, n, mesh, plan, replan=False)
    base_mask = np.asarray(out0[0])
    assert _oracle_identical(g, base_mask, km), \
        "grid-push fault-free baseline != Kruskal oracle"
    cells: List[dict] = []
    for fault, spec in GRID_FAULT_MATRIX:
        verdict, why, injected = _classify(
            g, n, mesh, plan, spec, seed, base_mask, kw, kc)
        cells.append({"fault": fault, "family": "rgg2d",
                      "path": f"grid_push:{spec.site}",
                      "verdict": verdict, "why": why,
                      "injected_items": injected})
        if verbose:
            print(f"  {fault:<12} rgg2d  {spec.site:<14} -> {verdict}"
                  f"  ({why[:80]})")
    # injection must not perturb the fault-free grid path either
    out = execute_plan(g, n, mesh, plan, replan=False)
    assert np.array_equal(np.asarray(out[0]), base_mask), \
        "fault-free grid push perturbed after the fault cells"
    return cells


def run_recovery_cells(families, n: int, seed: int, ckpt_every: int = 2,
                       elastic: bool = True,
                       verbose: bool = True) -> List[dict]:
    """Kill-mid-run cells (ISSUE 9): abort past the cadence, resume.

    Per family: run the host driver fault-free, then again with
    ``ckpt_every`` under an ``abort`` injection at round
    ``ckpt_every + 1`` (a round *after* at least one certified
    checkpoint), catch the ``ShardAbort``, resume from the last
    checkpoint and assert (a) the resumed forest is bit-identical to
    the fault-free one and (b) re-executed rounds ≤ the cadence.  The
    elastic cell remaps the pre-abort checkpoint onto a p/2 sub-mesh
    with edges re-partitioned from the host store and asserts the
    resumed MSF equals the Kruskal oracle's edge set exactly.
    """
    mesh = Mesh(np.array(jax.devices()), ("data",))
    p = mesh.devices.size
    cells: List[dict] = []
    abort_round = ckpt_every + 1
    for family in families:
        u, v, w, n2 = generators.generate(family, n, avg_degree=8.0,
                                          seed=seed)
        g = build_dist_graph(u, v, w, n2, p)[0]
        km, _ = oracle.kruskal(u, v, w, n2)
        base = distributed_sharded_msf(g, n2, mesh)
        base_mask = np.asarray(base[0])
        assert _oracle_identical(g, base_mask, km), \
            f"{family}: fault-free driver baseline != Kruskal oracle"
        assert int(base[5].rounds) >= abort_round, \
            f"{family}: solve ends in {int(base[5].rounds)} rounds, " \
            f"before the injected abort at round {abort_round}"
        fp = faults.FaultPlan(seed=seed, specs=(
            faults.FaultSpec(kind="abort", site="minedges",
                             rounds=(abort_round,)),))
        cks: List = []
        died = False
        try:
            with faults.inject(fp):
                distributed_sharded_msf(g, n2, mesh,
                                        ckpt_every=ckpt_every,
                                        ckpt_out=cks)
        except faults.ShardAbort:
            died = True
        assert died, f"{family}: abort round {abort_round} never fired"
        assert cks, f"{family}: no certified checkpoint before abort"
        ck = cks[-1]
        res = distributed_sharded_msf(g, n2, mesh, resume_from=ck)
        identical = (np.array_equal(np.asarray(res[0]), base_mask)
                     and float(res[1]) == float(base[1])
                     and int(res[2]) == int(base[2]))
        re_exec = abort_round - 1 - ck.round_index
        cells.append({"cell": "resume", "family": family,
                      "abort_round": abort_round,
                      "ckpt_round": ck.round_index,
                      "re_executed_rounds": re_exec,
                      "bit_identical": bool(identical)})
        assert identical, \
            f"{family}: resumed run != fault-free run (ckpt {ck!r})"
        assert 0 <= re_exec <= ckpt_every, \
            f"{family}: {re_exec} re-executed rounds > cadence " \
            f"{ckpt_every}"
        if verbose:
            print(f"  resume       {family:<6} driver   -> recovered "
                  f"(ckpt@r{ck.round_index}, re-exec {re_exec} <= "
                  f"{ckpt_every}, bit-identical)")
        if elastic and family == families[0]:
            p2 = max(1, p // 2)
            mesh2 = Mesh(np.array(jax.devices()[:p2]), ("data",))
            g2, cap2 = build_dist_graph(u, v, w, n2, p2)
            ck2 = ck.remap(p2, cap2, np.asarray(g2.u), np.asarray(g2.v),
                           np.asarray(g2.eid))
            res2 = distributed_sharded_msf(g2, n2, mesh2,
                                           resume_from=ck2)
            ok = (_oracle_identical(g2, np.asarray(res2[0]), km)
                  and int(res2[4]) == 0)
            cells.append({"cell": "elastic", "family": family,
                          "p_from": p, "p_to": p2,
                          "ckpt_round": ck.round_index,
                          "oracle_identical": bool(ok)})
            assert ok, \
                f"{family}: elastic p{p}->p{p2} restore != oracle"
            if verbose:
                print(f"  elastic      {family:<6} p{p}->p{p2}  -> "
                      f"recovered (ckpt@r{ck.round_index}, oracle-"
                      "identical edge set)")
    return cells


def measure_verify_overhead(n: int, seed: int, iters: int = 5) -> dict:
    """Warm-path cost of execute_plan(verify=True) vs verify=False."""
    mesh = Mesh(np.array(jax.devices()), ("data",))
    p = mesh.devices.size
    g, km, kw, kc, _, _ = _build("gnm", n, p, seed)
    plan = plan_sharded_msf(g, n, mesh)
    for v in (False, True):  # warm both paths (compile + verifier build)
        execute_plan(g, n, mesh, plan, replan=False, verify=v)
    t0 = time.perf_counter()
    for _ in range(iters):
        execute_plan(g, n, mesh, plan, replan=False)
    t_plain = (time.perf_counter() - t0) / iters
    t0 = time.perf_counter()
    for _ in range(iters):
        execute_plan(g, n, mesh, plan, replan=False, verify=True)
    t_verify = (time.perf_counter() - t0) / iters
    return {"n": n, "iters": iters,
            "t_plain_ms": round(t_plain * 1e3, 3),
            "t_verify_ms": round(t_verify * 1e3, 3),
            "verify_overhead_x": round(t_verify / max(t_plain, 1e-9), 3)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrix (planned path only), no BENCH "
                    "write; asserts zero silent corruptions")
    ap.add_argument("--n", type=int, default=0,
                    help="vertices per graph (default 128 smoke / "
                    "512 full)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n = args.n or (128 if args.smoke else 512)

    print(f"chaos: {len(FAULT_MATRIX)} fault classes x gnm/rgg2d x "
          f"{'planned' if args.smoke else 'planned+batched'}, n={n}, "
          f"p={jax.device_count()}")
    cells = run_matrix(("gnm", "rgg2d"), n, args.seed,
                       batched=not args.smoke)
    print(f"grid push: {len(GRID_FAULT_MATRIX)} deputy-hop cells on a "
          "(row, col) mesh")
    cells += run_grid_push_cells(n, args.seed)
    silent = [c for c in cells if c["verdict"] == "SILENT"]
    counts = {v: sum(1 for c in cells if c["verdict"] == v)
              for v in ("detected", "tolerated", "SILENT")}
    print(f"chaos matrix: {len(cells)} cells -> {counts}")
    if silent:
        for c in silent:
            print(f"SILENT: {c}")
        raise SystemExit(1)

    # kill-mid-run recovery (ISSUE 9): smoke gets one resume cell and
    # one elastic p->p/2 cell; full covers both families
    rec_families = ("gnm",) if args.smoke else ("gnm", "rgg2d")
    rec_cells = run_recovery_cells(rec_families, n, args.seed)
    print(f"recovery: {len(rec_cells)} cells, all recovered")

    overhead = measure_verify_overhead(n, args.seed)
    print(f"verify=True overhead: {overhead['verify_overhead_x']}x "
          f"({overhead['t_plain_ms']}ms -> {overhead['t_verify_ms']}ms "
          f"warm, n={overhead['n']})")

    if not args.smoke:
        path = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                            "..", "..", "..",
                                            "BENCH_sharded_comm.json"))
        bench = {}
        if os.path.exists(path):
            with open(path) as f:
                bench = json.load(f)
        bench["chaos"] = {"n": n, "seed": args.seed, "cells": cells,
                          "verdict_counts": counts,
                          "recovery_cells": rec_cells,
                          "verify_overhead": overhead}
        with open(path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
        print(f"wrote chaos section -> {path}")
    print("chaos: OK (zero silent corruptions)")


if __name__ == "__main__":
    main()
