"""Serving launcher: continuous-batching decode for --arch <id>.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    import dataclasses
    import jax
    import numpy as np
    from repro.configs.base import get_arch
    from repro.models.model import init_params
    from repro.serve.engine import Request, ServeEngine

    entry = get_arch(args.arch)
    cfg = entry.smoke if args.smoke else entry.config
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(1, cfg.vocab_size, 4)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests, {tok} tokens, {dt:.2f}s "
          f"({tok / dt:.1f} tok/s, kv={cfg.kv_cache_dtype})")


if __name__ == "__main__":
    main()
