"""Input/state specs per (architecture x shape cell) and step builders.

Every cell is a (kind, seq, batch) triple from the assignment:
    train_4k     train_step   seq 4096,    global_batch 256
    prefill_32k  serve prefill seq 32768,  global_batch 32
    decode_32k   serve_step   1 new token, KV 32768, global_batch 128
    long_500k    serve_step   1 new token, state 524288, global_batch 1
                 (sub-quadratic archs only — full-attention archs are
                 skipped per DESIGN.md and recorded as such)

All arrays are ShapeDtypeStructs (no allocation); shardings follow
models/sharding.py rules with divisibility-aware fallbacks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import sharding as shd
from repro.models.model import (MeshContext, forward_decode, forward_prefill,
                                forward_train, init_caches, init_params)
from repro.train.optimizer import init_state, state_shardings
from repro.train.train_loop import TrainConfig, make_train_step

SHAPES: Dict[str, Dict] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}


def cell_supported(cfg: ModelConfig, shape_id: str) -> Tuple[bool, str]:
    if shape_id == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 512k-token cache cell skipped "
                       "per spec (sub-quadratic attns only); see DESIGN.md")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dp(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh: Mesh) -> int:
    s = 1
    for a in _dp(mesh):
        s *= mesh.shape[a]
    return s


def batch_sharding(mesh: Mesh, B: int) -> NamedSharding:
    dp = _dp(mesh)
    if B % max(_dp_size(mesh), 1) == 0 and B >= _dp_size(mesh):
        return NamedSharding(mesh, P(dp))
    return NamedSharding(mesh, P())


def _generic_sharding(leaf, mesh: Mesh, B: int,
                      mode: str = "feature") -> NamedSharding:
    """Caches/stubs: batch dim over DP (if divisible), plus 'model' on
    either the last divisible feature dim (mode="feature") or the
    sequence dim (mode="sequence", flash-decoding style length split —
    the §Perf fix for KV-head counts below the TP degree)."""
    dp = _dp(mesh)
    dsz = _dp_size(mesh)
    msz = mesh.shape["model"]
    spec = [None] * leaf.ndim
    for i, d in enumerate(leaf.shape):
        if d == B and d % dsz == 0 and d >= dsz:
            spec[i] = dp if len(dp) > 1 else dp[0]
            break
    order = range(leaf.ndim - 1, -1, -1)
    if mode == "sequence" and leaf.ndim >= 4:
        order = [2] + [i for i in range(leaf.ndim - 1, -1, -1) if i != 2]
    for i in order:
        if spec[i] is None and leaf.shape[i] % msz == 0 \
                and leaf.shape[i] >= msz and i != 0:
            spec[i] = "model"
            break
    return NamedSharding(mesh, P(*spec))


def model_inputs(cfg: ModelConfig, shape_id: str, mesh: Mesh):
    """Returns (input tree of SDS, matching shardings tree)."""
    info = SHAPES[shape_id]
    B, S = info["batch"], info["seq"]
    bsh = batch_sharding(mesh, B)
    rep = NamedSharding(mesh, P())
    if info["kind"] in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        shard = {"tokens": bsh, "labels": bsh}
        if cfg.frontend == "patch":
            batch["patch_embeds"] = _sds((B, cfg.frontend_len, cfg.d_model),
                                         jnp.bfloat16)
            shard["patch_embeds"] = _generic_sharding(
                batch["patch_embeds"], mesh, B)
        if cfg.frontend == "audio":
            batch["frames"] = _sds((B, cfg.frontend_len, cfg.d_model),
                                   jnp.bfloat16)
            shard["frames"] = _generic_sharding(batch["frames"], mesh, B)
        return batch, shard
    # decode
    caches = jax.eval_shape(partial(init_caches, cfg, B, S))
    cshard = jax.tree.map(
        lambda l: _generic_sharding(l, mesh, B, mode=cfg.cache_shard),
        caches)
    tokens = _sds((B,), jnp.int32)
    pos = _sds((B,), jnp.int32)
    tsh = batch_sharding(mesh, B)
    return {"caches": caches, "tokens": tokens, "pos": pos}, \
           {"caches": cshard, "tokens": tsh, "pos": tsh}


def params_and_shardings(cfg: ModelConfig, mesh: Mesh):
    pshape = jax.eval_shape(partial(init_params, cfg), jax.random.key(0))
    return pshape, shd.param_shardings(pshape, mesh)


def build_step(cfg: ModelConfig, shape_id: str, mesh: Mesh,
               remat: bool = True, donate_caches: bool = False):
    """Returns (fn, arg_sds tuple, in_shardings tuple, out_shardings[,
    donate]).  ``donate_caches`` aliases decode KV buffers in-place
    (§Perf: halves the decode memory term by eliding the cache copy)."""
    info = SHAPES[shape_id]
    mesh_ctx = MeshContext(mesh, _dp(mesh), ("model",))
    pshape, pshard = params_and_shardings(cfg, mesh)
    inputs, ishard = model_inputs(cfg, shape_id, mesh)
    rep = NamedSharding(mesh, P())

    if info["kind"] == "train":
        tc = TrainConfig()
        step = make_train_step(cfg, tc, mesh_ctx)
        oshape = jax.eval_shape(init_state, pshape)
        oshard = state_shardings(shd.valid_param_specs(pshape, mesh),
                                 pshape, mesh)
        args = (pshape, oshape, inputs)
        in_sh = (pshard, oshard, ishard)
        out_sh = (pshard, oshard, None)
        return step, args, in_sh, out_sh
    if info["kind"] == "prefill":
        def step(params, batch):
            return forward_prefill(cfg, params, batch, mesh_ctx)
        args = (pshape, inputs)
        in_sh = (pshard, ishard)
        return step, args, in_sh, None
    # decode
    def step(params, caches, tokens, pos):
        return forward_decode(cfg, params, caches, tokens, pos, mesh_ctx)
    args = (pshape, inputs["caches"], inputs["tokens"], inputs["pos"])
    in_sh = (pshard, ishard["caches"], ishard["tokens"], ishard["pos"])
    logits_sh = None
    if cfg.shard_logits and cfg.vocab_size % mesh.shape["model"] == 0:
        # serving keeps logits vocab-sharded (sample via sharded argmax)
        dp = _dp(mesh)
        B = SHAPES[shape_id]["batch"]
        bdim = dp if (B % _dp_size(mesh) == 0 and B >= _dp_size(mesh)) \
            else None
        logits_sh = NamedSharding(mesh, P(bdim, "model"))
    out_sh = (logits_sh, ishard["caches"])
    if donate_caches:
        return step, args, in_sh, out_sh, (1,)
    return step, args, in_sh, out_sh


def probe_configs(cfg: ModelConfig) -> Optional[Tuple[ModelConfig,
                                                      ModelConfig, int]]:
    """Two reduced-depth configs (L1, L2) and the period count for
    per-layer cost extrapolation (scan bodies are counted once by XLA
    cost analysis — see launch/roofline.py)."""
    if cfg.family == "hybrid":
        return None  # python-unrolled stack: raw costs are complete
    f = cfg.first_dense_layers
    p = cfg.moe_every if cfg.is_moe else 1
    L1, L2 = f + p, f + 2 * p
    n_periods = (cfg.num_layers - f) // p
    if n_periods < 2:
        return None
    kw = dict(num_layers=L1, scan_unroll=True)
    kw2 = dict(num_layers=L2, scan_unroll=True)
    if cfg.encoder_layers:
        kw["encoder_layers"] = 1
        kw2["encoder_layers"] = 2
    c1 = dataclasses.replace(cfg, **kw)
    c2 = dataclasses.replace(cfg, **kw2)
    return c1, c2, n_periods
