import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: AOT lower + compile every (architecture x shape x
mesh) cell on the production meshes, plus the distributed-MST step (the
paper's own workload), and emit the roofline table inputs.

MUST be run as a module (python -m repro.launch.dryrun); the XLA flag
above executes before any jax import so the host platform exposes 512
placeholder devices.  Nothing here allocates device memory: inputs are
ShapeDtypeStructs and params come from eval_shape.

Usage:
  python -m repro.launch.dryrun                        # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --mst                  # MST cell only
  python -m repro.launch.dryrun --out experiments/dryrun.json
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ARCH_IDS, get_arch  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shapes import (SHAPES, build_step, cell_supported,  # noqa: E402
                                 probe_configs)


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
    except Exception as e:  # CPU backend may not implement everything
        return {"error": str(e)}


def compile_cell(cfg, shape_id, mesh, donate_caches=False):
    built = build_step(cfg, shape_id, mesh, donate_caches=donate_caches)
    if len(built) == 5:
        step, args, in_sh, out_sh, donate = built
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
    else:
        step, args, in_sh, out_sh = built
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    t0 = time.time()
    try:
        with jax.sharding.use_mesh(mesh):
            lowered = jitted.lower(*args)
    except Exception:
        lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    cost = rl.cost_summary(compiled)
    coll = rl.collective_bytes_from_hlo(compiled.as_text())
    return {
        "cost": cost,
        "collectives": coll,
        "memory": _mem_dict(compiled),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
    }


def parse_overrides(pairs):
    """--override attn_impl=blockwise --override moe_impl=dispatch ..."""
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_cell(arch: str, shape_id: str, mesh, mesh_label: str,
             probes: bool = True, overrides=None, donate_caches=False):
    cfg = get_arch(arch).config
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    ok, why = cell_supported(cfg, shape_id)
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_label}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    try:
        full = compile_cell(cfg, shape_id, mesh,
                            donate_caches=donate_caches)
        rec.update(full)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
        return rec

    # probe extrapolation: XLA counts scan bodies once; compile at depth
    # L1 and L1+period, extrapolate flops/bytes to the real depth.
    info = SHAPES[shape_id]
    extra = None
    pc = probe_configs(cfg)
    if probes and pc is not None:
        c1, c2, n_periods = pc
        try:
            p1 = compile_cell(c1, shape_id, mesh)
            p2 = compile_cell(c2, shape_id, mesh)
            def extr(key, sub=None):
                v1 = p1[key][sub] if sub else p1[key]
                v2 = p2[key][sub] if sub else p2[key]
                return v1 + (n_periods - 1) * max(v2 - v1, 0.0)
            extra = {
                "flops": extr("cost", "flops"),
                "bytes": extr("cost", "bytes"),
                "coll_bytes_probe": extr("collectives", "total_bytes"),
                "n_periods": n_periods,
                "probe_L": [c1.num_layers, c2.num_layers],
            }
        except Exception as e:
            extra = {"error": f"{type(e).__name__}: {e}"}
    rec["extrapolated"] = extra

    # roofline terms: use extrapolated flops/bytes when available, and
    # the trip-count-weighted HLO collective bytes (already full-depth)
    flops = (extra or {}).get("flops") or rec["cost"]["flops"]
    bts = (extra or {}).get("bytes") or rec["cost"]["bytes"]
    coll = rec["collectives"].get("wire_bytes",
                                  rec["collectives"]["total_bytes"])
    chips = mesh.devices.size
    terms = rl.RooflineTerms(flops=flops, bytes_accessed=bts,
                             collective_bytes=coll, chips=chips)
    rec["roofline"] = terms.as_dict()
    mf = rl.model_flops(cfg, info, backward=(info["kind"] == "train"))
    rec["model_flops_global"] = mf
    rec["model_flops_per_chip"] = mf / chips
    rec["useful_ratio"] = (mf / chips) / flops if flops else 0.0
    return rec


def run_mst_cell(mesh, mesh_label: str, n_exp: int = 22,
                 edges_per_shard_exp: int = 18,
                 algorithm: str = "boruvka", local_preprocessing=True,
                 engine: str = "replicated", plan_path=None):
    """The paper's own workload on the production mesh: distributed
    Borůvka step over a 1D-partitioned edge list (weak-scaling shape:
    2^n_exp vertices, 2^edges_per_shard_exp directed slots per device).

    ``engine="sharded"`` costs the sharded-label engine's **planned**
    program instead (ISSUE 5): a ``RoundPlan`` — loaded from
    ``plan_path`` (``plan.to_json`` output, e.g. measured at benchmark
    scale) or synthesized on the geometric ladder
    (``core/plan.py: synthetic_plan``) — is AOT-lowered as one unrolled
    multi-round program and its compiled memory/collectives are
    recorded next to the flat-capacity lowering of the same shape, all
    without running anything.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    chips = mesh.devices.size
    n = 2 ** n_exp
    cap_total = chips * (2 ** edges_per_shard_exp)
    axes = tuple(mesh.axis_names)
    sh = NamedSharding(mesh, P(axes))

    def compile_step(step, specs, rec, prefix=""):
        t0 = time.time()
        compiled = jax.jit(step, in_shardings=(sh,) * 4).lower(
            *specs).compile()
        rec[prefix + "compile_s"] = round(time.time() - t0, 2)
        rec[prefix + "cost"] = rl.cost_summary(compiled)
        rec[prefix + "collectives"] = rl.collective_bytes_from_hlo(
            compiled.as_text())
        rec[prefix + "memory"] = _mem_dict(compiled)
        return compiled

    rec = {"arch": f"mst-{engine}-{algorithm}", "shape": f"n=2^{n_exp}",
           "mesh": mesh_label}
    try:
        if engine == "sharded":
            import warnings
            from repro.core.distributed_sharded import make_sharded_mst_step
            from repro.core.plan import RoundPlan, synthetic_plan
            if plan_path:
                # a measured plan's levers are frozen — the cell costs
                # what the plan encodes, recorded below
                with open(plan_path) as f:
                    plan = RoundPlan.from_json(f.read())
            else:
                plan = synthetic_plan(
                    n, cap_total, chips, algorithm=algorithm,
                    local_preprocessing=local_preprocessing)
            rec["plan"] = rl.plan_summary(plan)
            rec["plan_source"] = plan_path or "synthetic"
            rec["plan_local_preprocessing"] = plan.local_preprocessing
            step, specs = make_sharded_mst_step(n, cap_total, mesh,
                                                plan=plan)
            compile_step(step, specs, rec)
            # the flat-capacity comparator: same shape, fused engine
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                fstep, fspecs = make_sharded_mst_step(
                    n, cap_total, mesh, algorithm=plan.algorithm,
                    shrink_capacities=False)
            compile_step(fstep, fspecs, rec, prefix="flat_")
            ft = rec["flat_memory"].get("temp_bytes")
            pt = rec["memory"].get("temp_bytes")
            if ft and pt:
                rec["temp_bytes_shrink_vs_flat"] = ft / max(pt, 1)
            rec["note"] = ("planned program is fully unrolled: HLO "
                           "collective weights are exact per round; "
                           "flat comparator uses the static "
                           "log2(n)+1 while bound")
        else:
            from repro.core.distributed import make_mst_step
            step, specs = make_mst_step(
                n, cap_total, mesh, algorithm=algorithm, axis_names=axes,
                local_preprocessing=local_preprocessing)
            compile_step(step, specs, rec)
            rec["note"] = ("while-loop costs use the static iteration "
                           f"bound (log2(n)+1 = {int(math.log2(n)) + 1} "
                           "rounds)")
        terms = rl.RooflineTerms(
            flops=rec["cost"]["flops"], bytes_accessed=rec["cost"]["bytes"],
            collective_bytes=rec["collectives"].get(
                "wire_bytes", rec["collectives"]["total_bytes"]),
            chips=chips)
        rec["roofline"] = terms.as_dict()
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--shape", default=None, help="single shape id")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mst", action="store_true", help="MST cell only")
    ap.add_argument("--mst-algorithm", default="boruvka")
    ap.add_argument("--mst-no-preprocessing", action="store_true")
    ap.add_argument("--mst-engine", default="replicated",
                    choices=["replicated", "sharded"],
                    help="sharded = AOT-cost the planned (RoundPlan) "
                         "unrolled program vs its flat lowering")
    ap.add_argument("--mst-plan", default=None, metavar="PLAN_JSON",
                    help="RoundPlan JSON (plan.to_json) to cost; "
                         "default synthesizes a geometric-ladder plan")
    ap.add_argument("--override", action="append", default=[],
                    help="config overrides, e.g. attn_impl=blockwise")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--donate-caches", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()
    overrides = parse_overrides(args.override)

    assert jax.device_count() == 512, jax.device_count()
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod-16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multipod-2x16x16",
                       make_production_mesh(multi_pod=True)))

    records = []
    for label, mesh in meshes:
        if args.mst:
            rec = run_mst_cell(
                mesh, label, algorithm=args.mst_algorithm,
                local_preprocessing=not args.mst_no_preprocessing,
                engine=args.mst_engine, plan_path=args.mst_plan)
            print(json.dumps({k: rec[k] for k in rec
                              if k not in ("trace",)}, default=str)[:2000])
            records.append(rec)
            continue
        archs = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(SHAPES)
        for arch in archs:
            for shape_id in shapes:
                t0 = time.time()
                rec = run_cell(arch, shape_id, mesh,
                               label, probes=not args.no_probes,
                               overrides=overrides,
                               donate_caches=args.donate_caches)
                dt = time.time() - t0
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" comp={r['compute_s']:.4f}s"
                             f" mem={r['memory_s']:.4f}s"
                             f" coll={r['collective_s']:.4f}s"
                             f" useful={rec['useful_ratio']:.2f}")
                elif status == "failed":
                    extra = " " + rec["error"][:160]
                print(f"[{label}] {arch} x {shape_id}: {status}"
                      f" ({dt:.0f}s){extra}", flush=True)
                records.append(rec)
        if not args.arch and not args.shape:
            rec = run_mst_cell(mesh, label)
            print(f"[{label}] mst-boruvka: {rec['status']}", flush=True)
            records.append(rec)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1, default=str)
    nok = sum(1 for r in records if r["status"] == "ok")
    nsk = sum(1 for r in records if r["status"] == "skipped")
    nf = sum(1 for r in records if r["status"] == "failed")
    print(f"\ndry-run: {nok} ok, {nsk} skipped (documented), {nf} failed")
    print(f"wrote {args.out}")
    return 0 if nf == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
