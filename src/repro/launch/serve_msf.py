"""MSF serving launcher: plan-LRU + continuous-batching gateway loop.

    PYTHONPATH=src python -m repro.launch.serve_msf --smoke
    PYTHONPATH=src python -m repro.launch.serve_msf \
        --requests 100 --sizes 512,1024 --slots 4 --check

Generates a synthetic traffic mix of gnm / rgg2d graphs over a few
shapes, serves it through ``serve/msf_gateway.py`` on a mesh over all
visible devices, and reports requests/s, latency percentiles and the
plan-cache hit / replan accounting.  ``--check`` verifies every served
forest bit-identically against the Kruskal oracle.
"""
from __future__ import annotations

import argparse
import time
from typing import List, Sequence


def make_traffic(families: Sequence[str], sizes: Sequence[int],
                 requests: int, seed: int = 0,
                 avg_degree: float = 8.0) -> List["MSFRequest"]:
    """A synthetic serving mix: ``requests`` graphs cycling over the
    (family, n) grid with per-request weight/structure seeds, so shapes
    repeat (plan-cache hits) while contents differ (real solves)."""
    from repro.data import generators
    from repro.serve.msf_gateway import MSFRequest
    shapes = [(f, n) for f in families for n in sizes]
    out = []
    for i in range(requests):
        fam, n = shapes[i % len(shapes)]
        u, v, w, n = generators.generate(fam, n, avg_degree=avg_degree,
                                         seed=seed + i)
        out.append(MSFRequest(rid=i, family=fam, u=u, v=v, w=w, n=n))
    return out


def percentile(xs: Sequence[float], q: float) -> float:
    ys = sorted(xs)
    if not ys:
        return 0.0
    return ys[min(len(ys) - 1, int(q * len(ys)))]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny mix, asserts hit rate + oracle identity")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--families", default="gnm,rgg2d")
    ap.add_argument("--sizes", default="512,1024")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-size", type=int, default=8)
    ap.add_argument("--pad-margin", type=float, default=0.25)
    ap.add_argument("--algorithm", default="boruvka")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify every forest against the Kruskal oracle")
    args = ap.parse_args()

    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.serve.msf_gateway import MSFGateway

    if args.smoke:
        args.requests = min(args.requests, 24)
        args.sizes = "256"
        args.check = True

    mesh = Mesh(np.array(jax.devices()), ("data",))
    gw = MSFGateway(mesh, algorithm=args.algorithm,
                    cache_size=args.cache_size, batch_slots=args.slots,
                    pad_margin=args.pad_margin)
    reqs = make_traffic(args.families.split(","),
                        [int(s) for s in args.sizes.split(",")],
                        args.requests, seed=args.seed)
    t0 = time.perf_counter()
    for r in reqs:
        gw.submit(r)
    gw.run()
    dt = time.perf_counter() - t0

    assert all(r.done for r in reqs)
    if args.check:
        from repro.core import oracle
        for r in reqs:
            kmask, kweight = oracle.kruskal(r.u, r.v, r.w, r.n)
            assert np.array_equal(r.edges, np.nonzero(kmask)[0]), \
                f"request {r.rid}: forest != oracle"
        print(f"oracle check: {len(reqs)} forests bit-identical")

    lat = [r.latency for r in reqs]
    s = gw.stats
    print(f"{len(reqs)} requests in {dt:.2f}s ({len(reqs) / dt:.2f} req/s, "
          f"{s.batches} batches)")
    print(f"latency p50={percentile(lat, 0.50):.3f}s "
          f"p99={percentile(lat, 0.99):.3f}s")
    print(f"plan cache: {s.hits} hits / {s.misses} misses "
          f"(hit rate {s.hit_rate:.2f}), {s.evictions} evictions, "
          f"{s.replans} replans (rate {s.replan_rate:.2f}), "
          f"{s.refreshes} refreshes")
    if args.smoke:
        assert s.hit_rate > 0.5, f"smoke hit rate {s.hit_rate:.2f} <= 0.5"
        print("SMOKE OK")


if __name__ == "__main__":
    main()
