"""Roofline analysis from AOT-compiled artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e class, per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s per link

Three terms per (arch x shape x mesh):
    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = collective_bytes / (chips * ici_bw)

Sources:
  * ``compiled.cost_analysis()`` -> flops / bytes accessed.  XLA counts
    while/scan bodies ONCE, so layer-scanned models are corrected by the
    probe-extrapolation in dryrun.py (compile at depth L1 and L2, take
    the per-period delta, extrapolate to the full depth).
  * collective bytes are NOT in cost_analysis: parsed from the compiled
    HLO text — operand bytes of every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (start variants
    included, done variants skipped to avoid double counting).

Everything here is per-program (SPMD: one program, `chips` participants);
cost_analysis FLOPs are per-device for SPMD modules.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes / s / chip
ICI_BW = 50e9            # bytes / s / link (counting one link per hop)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# tuple results (XLA decomposes lax.all_to_all into a tuple-form
# all-to-all of per-peer slices) may contain /*index=k*/ comments, so
# the tuple alternative must admit '=' inside the parentheses — it only
# needs to exclude nested parens, which HLO shape tuples never have
_OP_RE = re.compile(
    r"=\s+(?P<res>\([^()]*\)|\S+)\s+"
    r"(?P<kind>(?:all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?)\(")
_GROUPS_COMPACT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, Tuple[list, bool]]:
    """name -> (lines, is_entry)."""
    comps: Dict[str, Tuple[list, bool]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = None
        if "{" in line and " = " not in s:
            m = _COMP_HEAD_RE.match(s)
        if m and not s.startswith("ROOT"):
            cur = m.group(2)
            comps[cur] = ([], m.group(1) is not None)
            continue
        if cur is not None:
            if s == "}":
                cur = None
            else:
                comps[cur][0].append(line)
    return comps


def _group_size(line: str) -> int:
    m = _GROUPS_COMPACT_RE.search(line)
    if m:  # replica_groups=[n_groups,group_size]<=[...]
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _line_collective(line: str) -> Optional[Tuple[str, float, float]]:
    """Returns (kind, operand_bytes, wire_bytes) for a collective line.

    Newer HLO prints shapes only on results, so sizes derive from the
    result shape + replica group size G:
      op              operand        wire (ring, receive-side)
      all-reduce      R              2R(G-1)/G
      all-gather      R/G            R(G-1)/G
      reduce-scatter  R*G            R(G-1)
      all-to-all      R              R(G-1)/G
      collective-permute R           R
    """
    m = _OP_RE.search(line)
    if not m:
        return None
    kind = m.group("kind").replace("-start", "")
    res = m.group("res")
    rbytes = 0.0
    for dm in _SHAPE_RE.finditer(res):
        rbytes += _shape_bytes(dm.group(1), dm.group(2))
    g = _group_size(line)
    if kind == "all-reduce":
        op, wire = rbytes, 2.0 * rbytes * (g - 1) / g
    elif kind == "all-gather":
        op, wire = rbytes / g, rbytes * (g - 1) / g
    elif kind == "reduce-scatter":
        op, wire = rbytes * g, rbytes * (g - 1)
    elif kind == "all-to-all":
        op, wire = rbytes, rbytes * (g - 1) / g
    else:  # collective-permute
        op, wire = rbytes, rbytes
    return kind, op, wire


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Weighted sum of collective operand bytes over the HLO module.

    XLA prints while/scan bodies once; this walks the computation graph
    from ENTRY, multiplying each while body by its trip count (parsed
    from the loop-condition constant — for data-dependent loops this is
    the static iteration bound, i.e. a worst-case estimate, flagged in
    EXPERIMENTS.md).
    """
    comps = _split_computations(hlo_text)
    entry = next((n for n, (_, is_e) in comps.items() if is_e), None)
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    wire: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    if entry is None:
        return {"total_bytes": 0.0, "wire_bytes": 0.0}

    _CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")

    def trip_count(cond_name: str, host_comp: str, while_line: str) -> int:
        # scan the cond computation and any fusion computations it calls
        names = [cond_name]
        lines = []
        seen = set()
        while names:
            nm = names.pop()
            if nm in seen:
                continue
            seen.add(nm)
            ls = comps.get(nm, ([], False))[0]
            lines.extend(ls)
            for ln in ls:
                cm = _CALLS_RE.search(ln)
                if cm:
                    names.append(cm.group(1))
        best = 0
        for ln in lines:
            for c in _CONST_RE.finditer(ln):
                best = max(best, int(c.group(1)))
        if best:
            return best
        # loop-invariant code motion may hoist the bound into the init
        # tuple: while(%tuple.N) — chase constants feeding that tuple
        tm = re.search(r"while\(%?([\w\.\-]+)\)", while_line)
        if tm:
            host_lines = comps.get(host_comp, ([], False))[0]
            defs = {}
            for ln in host_lines:
                dm = re.match(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=", ln)
                if dm:
                    defs[dm.group(1)] = ln

            def chase(opname: str, depth: int) -> int:
                dl = defs.get(opname, "")
                cm2 = _CONST_RE.search(dl)
                if cm2 and "s32[]" in dl:
                    return int(cm2.group(1))
                if depth <= 0:
                    return 0
                # follow copies / converts one hop
                nm = re.search(r"(?:copy|convert)\(%?([\w\.\-]+)\)", dl)
                if nm:
                    return chase(nm.group(1), depth - 1)
                return 0

            tup = defs.get(tm.group(1), "")
            for opm in re.finditer(r"%([\w\.\-]+)", tup.split("tuple(")[-1]):
                best = max(best, chase(opm.group(1), 3))
        return max(best, 1)

    seen_stack = set()

    def walk(name: str, weight: float) -> None:
        if name not in comps or name in seen_stack:
            return
        seen_stack.add(name)
        for line in comps[name][0]:
            col = _line_collective(line)
            if col:
                out[col[0]] += weight * col[1]
                wire[col[0]] += weight * col[2]
                counts[col[0]] += weight
            wm = _WHILE_RE.search(line)
            if wm:
                walk(wm.group(2),
                     weight * trip_count(wm.group(1), name, line))
                continue
            cm = _CALL_RE.search(line)
            if cm:
                walk(cm.group(1), weight)
        seen_stack.discard(name)

    walk(entry, 1.0)
    res = {f"{k}_bytes": v for k, v in out.items()}
    res.update({f"{k}_wire": v for k, v in wire.items()})
    res.update({f"{k}_count": c for k, c in counts.items()})
    res["total_bytes"] = sum(out.values())
    res["wire_bytes"] = sum(wire.values())
    return res


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per device
    bytes_accessed: float        # per device
    collective_bytes: float      # per device
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (full overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline: compute term / max term (1.0 = compute
        bound at peak)."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "coll_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "roofline_fraction": self.compute_fraction,
        }


def plan_summary(plan) -> Dict[str, float]:
    """Host-side static costing view of a ``core/plan.py: RoundPlan``.

    A plan *is* the compiled program's buffer story — every exchange of
    round ``r`` allocates ``[p, cap]`` buffers at the plan's static
    capacities — so the capacity trajectory can be costed without
    compiling, and compared against the compiled artifact's
    ``memory_analysis`` / HLO collective bytes (the two views are
    cross-checked in ``tests/test_roofline_crosscheck.py``).  Sums and
    maxima only, so dry-run records stay small.
    """
    caps = ("cap_edge", "cap_lookup", "cap_contract", "cap_relabel",
            "cap_push")
    out: Dict[str, float] = {
        "rounds": float(plan.num_rounds),
        "sentinel_rounds": float(sum(r.sentinel for r in plan.rounds)),
        "levels": float(len(plan.level_bounds)),
        "ghost": float(plan.ghost is not None),
        "edge_capacity_full": float(plan.edge_capacity_full),
    }
    for f in caps:
        vals = [getattr(r, f) for r in plan.rounds]
        out[f"{f}_sum"] = float(sum(vals))
        out[f"{f}_max"] = float(max(vals))
    # flat comparator: the fused engine ships the full edge capacity
    # for every round the plan runs
    out["cap_edge_flat_sum"] = float(plan.edge_capacity_full
                                     * plan.num_rounds)
    out["cap_edge_shrink"] = out["cap_edge_flat_sum"] / max(
        out["cap_edge_sum"], 1.0)
    return out


def cost_summary(compiled) -> Dict[str, float]:
    # cost_analysis() returns one dict on JAX >= 0.5 but a one-element
    # list of dicts on 0.4.x (see repro.compat for the policy)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def model_flops(cfg, shape_info: Dict, backward: bool) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D tokens (train) or 2*N_active*D
    (forward-only), attention term included for long sequences."""
    tokens = shape_info["batch"] * (shape_info["seq"]
                                    if shape_info["kind"] != "decode" else 1)
    n = cfg.active_param_count()
    mult = 6.0 if backward else 2.0
    base = mult * n * tokens
    # attention score/value flops: 2 * 2 * tokens * ctx * H * hd (fwd)
    if cfg.family not in ("ssm",):
        ctx = shape_info["seq"]
        att = 2 * 2 * tokens * ctx * cfg.num_heads * cfg.hd
        if shape_info["kind"] == "train":
            att *= 0.5 * 3.0  # causal half, fwd+bwd
        base += att * cfg.num_layers
    return base
