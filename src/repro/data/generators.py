"""Graph generators mirroring the paper's benchmark families (KaGen analog).

All generators are host-side numpy (the data pipeline layer), deterministic
given a seed, and return canonical undirected edges (u < v, no self loops)
plus the vertex count.  Weights are drawn uniformly from [1, 255) as in the
paper's experimental setup (Section VII).

Families (Section VII): 2D grid, 2D/3D random geometric (RGG), random
hyperbolic (RHG), Erdős-Renyi (GNM), RMAT (Graph500 probabilities).
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

Edges = Tuple[np.ndarray, np.ndarray, np.ndarray, int]  # u, v, w, n


def assign_weights(m: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 0x9E3779B9)
    return rng.uniform(1.0, 255.0, size=m).astype(np.float32)


def _finish(u: np.ndarray, v: np.ndarray, n: int, seed: int,
            dedup: bool = True) -> Edges:
    lo = np.minimum(u, v).astype(np.int64)
    hi = np.maximum(u, v).astype(np.int64)
    keep = lo != hi
    lo, hi = lo[keep], hi[keep]
    if dedup and len(lo):
        key = lo * np.int64(n) + hi
        _, idx = np.unique(key, return_index=True)
        lo, hi = lo[idx], hi[idx]
    w = assign_weights(len(lo), seed)
    return lo.astype(np.int32), hi.astype(np.int32), w, n


def grid2d(rows: int, cols: int, seed: int = 0) -> Edges:
    """2D grid with 4-neighbourhoods (maximal locality)."""
    n = rows * cols
    ids = np.arange(n).reshape(rows, cols)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], axis=1)
    e = np.concatenate([right, down], axis=0)
    return _finish(e[:, 0], e[:, 1], n, seed, dedup=False)


def gnm(n: int, m: int, seed: int = 0) -> Edges:
    """Erdős-Renyi G(n, m): m uniform random edges (parallel ones deduped)."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=int(m * 1.1) + 16, dtype=np.int64)
    v = rng.integers(0, n, size=int(m * 1.1) + 16, dtype=np.int64)
    eu, ev, w, _ = _finish(u, v, n, seed)
    if len(eu) > m:
        eu, ev, w = eu[:m], ev[:m], w[:m]
    return eu, ev, w, n


def rmat(scale: int, m: int, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Edges:
    """RMAT with Graph500 default probabilities (skewed degrees)."""
    n = 1 << scale
    rng = np.random.default_rng(seed)
    d = 1.0 - a - b - c
    probs = np.array([a, b, c, d])
    cum = np.cumsum(probs)
    u = np.zeros(m, np.int64)
    v = np.zeros(m, np.int64)
    for bit in range(scale):
        r = rng.random(m)
        quad = np.searchsorted(cum, r)
        u = (u << 1) | (quad >> 1)
        v = (v << 1) | (quad & 1)
    return _finish(u, v, n, seed)


def rgg2d(n: int, avg_degree: float = 8.0, seed: int = 0) -> Edges:
    """2D random geometric graph via cell binning (high locality)."""
    rng = np.random.default_rng(seed)
    r = math.sqrt(avg_degree / (math.pi * n))
    pts = rng.random((n, 2))
    return _rgg(pts, r, n, seed)


def rgg3d(n: int, avg_degree: float = 8.0, seed: int = 0) -> Edges:
    rng = np.random.default_rng(seed)
    r = (3.0 * avg_degree / (4.0 * math.pi * n)) ** (1.0 / 3.0)
    pts = rng.random((n, 3))
    return _rgg(pts, r, n, seed)


def _rgg(pts: np.ndarray, r: float, n: int, seed: int) -> Edges:
    """Neighbour search on a uniform grid of cell size r."""
    dim = pts.shape[1]
    ncell = max(1, int(1.0 / r))
    cell = np.minimum((pts * ncell).astype(np.int64), ncell - 1)
    key = cell[:, 0]
    for d in range(1, dim):
        key = key * ncell + cell[:, d]
    order = np.argsort(key, kind="stable")
    # vertex ids follow spatial order => locality in the edge list, the
    # property the paper's local preprocessing exploits.
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n)
    pts_s = pts[order]
    key_s = key[order]
    starts = np.searchsorted(key_s, np.arange(ncell ** dim))
    ends = np.searchsorted(key_s, np.arange(ncell ** dim), side="right")
    us, vs = [], []
    offsets = np.array(np.meshgrid(*([[-1, 0, 1]] * dim))).T.reshape(-1, dim)
    cell_s = cell[order]
    for ci in np.unique(key_s):
        i0, i1 = starts[ci], ends[ci]
        if i0 >= i1:
            continue
        mine = np.arange(i0, i1)
        base = cell_s[i0]
        neigh = [mine]
        for off in offsets:
            if (off == 0).all():
                continue
            nb = base + off
            if (nb < 0).any() or (nb >= ncell).any():
                continue
            nk = nb[0]
            for d in range(1, dim):
                nk = nk * ncell + nb[d]
            j0, j1 = starts[nk], ends[nk]
            if j0 < j1:
                neigh.append(np.arange(j0, j1))
        cand = np.concatenate(neigh)
        d2 = ((pts_s[mine][:, None, :] - pts_s[cand][None, :, :]) ** 2).sum(-1)
        ii, jj = np.nonzero(d2 <= r * r)
        a, b = mine[ii], cand[jj]
        keep = a < b
        us.append(a[keep])
        vs.append(b[keep])
    u = np.concatenate(us) if us else np.zeros(0, np.int64)
    v = np.concatenate(vs) if vs else np.zeros(0, np.int64)
    return _finish(u, v, n, seed, dedup=True)


def rhg(n: int, avg_degree: float = 8.0, gamma: float = 3.0,
        seed: int = 0) -> Edges:
    """Random hyperbolic graph (power-law degrees, partial locality).

    Threshold model on the hyperbolic disk of radius R; simplified KaGen:
    R tuned so that the expected degree is roughly ``avg_degree``.
    """
    rng = np.random.default_rng(seed)
    alpha = (gamma - 1.0) / 2.0
    R = 2.0 * math.log(n) + math.log(8.0 * alpha ** 2
                                     / (math.pi * avg_degree * (alpha - .5) ** 2))
    R = max(R, 1.0)
    # radial CDF: cosh(alpha r) growth
    uu = rng.random(n)
    rad = np.arccosh(1.0 + uu * (np.cosh(alpha * R) - 1.0)) / alpha
    ang = rng.random(n) * 2.0 * math.pi
    # sort by angle => vertex ids follow the disk => locality
    order = np.argsort(ang, kind="stable")
    rad, ang = rad[order], ang[order]
    # blocked pairwise check (fine for benchmark sizes)
    us, vs = [], []
    block = 2048
    for i0 in range(0, n, block):
        i1 = min(i0 + block, n)
        for j0 in range(i0, n, block):
            j1 = min(j0 + block, n)
            dphi = np.abs(ang[i0:i1, None] - ang[None, j0:j1])
            dphi = np.minimum(dphi, 2.0 * math.pi - dphi)
            ch = (np.cosh(rad[i0:i1, None]) * np.cosh(rad[None, j0:j1])
                  - np.sinh(rad[i0:i1, None]) * np.sinh(rad[None, j0:j1])
                  * np.cos(dphi))
            d = np.arccosh(np.maximum(ch, 1.0))
            ii, jj = np.nonzero(d <= R)
            a, b = ii + i0, jj + j0
            keep = a < b
            us.append(a[keep])
            vs.append(b[keep])
    u = np.concatenate(us) if us else np.zeros(0, np.int64)
    v = np.concatenate(vs) if vs else np.zeros(0, np.int64)
    return _finish(u, v, n, seed, dedup=True)


FAMILIES = {
    "grid2d": lambda n, deg, seed: grid2d(int(math.sqrt(n)),
                                          int(math.sqrt(n)), seed),
    "rgg2d": lambda n, deg, seed: rgg2d(n, deg, seed),
    "rgg3d": lambda n, deg, seed: rgg3d(n, deg, seed),
    "rhg": lambda n, deg, seed: rhg(n, deg, seed=seed),
    "gnm": lambda n, deg, seed: gnm(n, int(n * deg / 2), seed),
    "rmat": lambda n, deg, seed: rmat(max(1, int(math.log2(n))),
                                      int(n * deg / 2), seed),
}


def generate(family: str, n: int, avg_degree: float = 8.0,
             seed: int = 0) -> Edges:
    return FAMILIES[family](n, avg_degree, seed)
