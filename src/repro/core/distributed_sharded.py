"""Sharded-label distributed Borůvka / Filter-Borůvka (Section IV, the
scalable path for n >> memory/PE).

``core/distributed.py`` replicates the vertex→component label vector on
every shard, which costs O(n) memory per PE and an allReduce of
n-vectors per round — the paper's *base case*.  This module implements
the representation the paper's 65 536-core runs rely on: the label
vector is **1D-sharded by vertex id** (owner of vertex ``vid`` is shard
``vid // vertices_per_shard``) and every label access becomes a routed
message through the capacity-bounded exchange of ``comm/exchange.py``
(the XLA-native stand-in for the paper's sparse ``MPI_Alltoallv``).

The phases, with the communication-minimisation levers of ISSUE 2 (all
individually toggleable; EXPERIMENTS.md §Sharded-label engine records
the measured all-to-all / routed-volume deltas):

  LOCALPREPROCESSING  (``local_preprocessing=True``, Section IV-A)
             Contract provably-local MST edges comm-free (shared
             boundary vertices stay roots, same core as the replicated
             engine), then seed the routed rounds with ONE routed label
             scatter to the owners — not the dense psum(n) the
             replicated engine uses, which would reintroduce the O(n)
             collective this representation exists to avoid.  Edges both
             of whose endpoints were contracted into the same component
             are retired into the ``dead`` mask before the first round.
  MINEDGES   Each edge shard looks up the component of both endpoints
             from the owners (request/reply).  With ``coalesce=True``
             the lexicographically sorted edge array is deduplicated
             first: one request per contiguous equal-endpoint run
             (segmented-scan run detection shared with kernels/segmin),
             answers fanned back out locally — lookup volume drops by
             ~avg-degree and ``lookup_capacity`` shrinks to the
             host-computed run-head bound.  With ``src_only=True`` each
             directed copy ships its ``(comp, w, eid, other)`` candidate
             only to the owner of its *source* component: both directed
             copies exist, so that owner still sees every edge incident
             to its components — 1 routed exchange + 1 confirmation
             instead of 2 + 2.  The owner scatter-mins with the (w, eid)
             order over its owned slots only.
  CONTRACT   Pointer doubling over the sharded parent array: each
             doubling step is one request_reply round asking
             ``owner(parent[x])`` for ``parent[parent[x]]``
             (EXCHANGELABELS).  The 2-cycle of a pair of components that
             choose each other is broken toward the smaller id.  With
             ``adaptive_doubling=True`` the fixed log2(n) schedule
             becomes a while_loop that stops one step after no parent
             changes (post round 1 contraction trees are shallow).
  RELABEL    Every owned vertex re-resolves its label through one more
             lookup of the contracted parent array.  Slots whose
             endpoints resolve to the same component join the persistent
             ``dead`` mask and stop generating requests and candidates.

Chosen-edge marking: in src-only mode a mutual pair of components
necessarily chose the *same* edge (each side's minimum bounds the
other's), and mutuality is exactly the 2-cycle the contraction already
detects — so the owner marks a winner iff it is not the larger side of a
2-cycle, which marks every MSF edge on exactly one directed slot without
the second confirmation exchange.  In the 2-exchange mode the canonical
(u < v) copy is marked, as before.  Either way the slot mask marks each
undirected MSF edge exactly once (the engines' shared contract).

Per-shard label memory is O(n/p) instead of O(n); all exchanges are
capacity-bounded with explicit overflow accounting (never silent): with
the default capacities (``edge_capacity = edges/shard``,
``label_capacity = vertices/shard``, ``lookup_capacity`` = the exact
host-side run-head bound) overflow is impossible and results are exact;
undersized capacities report a positive overflow count and the caller
must retry larger (EXPERIMENTS.md §Sharded-label engine).

Tie-breaking is the direction-independent ``(w, eid)`` order shared by
all engines and the Kruskal oracle, so the produced MSF edge set is
bit-identical across engines (tests/test_engine_equivalence.py).
"""
from __future__ import annotations

import functools
import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm.exchange import ExchangeStats, reply, routed_exchange
from repro.core.distributed import (ESENT, CommStats, DistGraph,
                                    _doubling_iters,
                                    _local_preprocessing_core,
                                    _weight_pivots)
from repro.kernels.segmin.ops import run_metadata


# --------------------------------------------------------------------------
# sharded building blocks (all run inside shard_map)
# --------------------------------------------------------------------------

def _sharded_lookup(table: jax.Array, vids: jax.Array, valid: jax.Array,
                    vps: int, capacity: int, axes: Tuple[str, ...],
                    schedule: str = "grid",
                    stats: Optional[ExchangeStats] = None):
    """Resolve ``table[vids[i]]`` where ``table`` is 1D-sharded by id.

    ``table`` is this shard's [vps] slice of a global [p * vps] int32
    array; ``vids`` are global ids.  Owner routing: the request carries
    the id itself, the owner answers ``table[id - base]``, the answer is
    routed back to the requesting slot (the paper's request/reply label
    exchange).  Returns (values [L], ok [L], overflow) — entries with
    ``ok`` False overflowed the exchange and carry garbage; with
    ``stats`` the updated accumulator is appended to the tuple.
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    ex = routed_exchange(vids, vids // vps, valid, capacity, names,
                         schedule, stats=stats)
    off = jnp.clip(ex.recv - base, 0, vps - 1)
    answers = jnp.where(ex.recv_ok, table[off], jnp.int32(-1))
    if stats is None:
        out = reply(ex, answers, names, schedule)
        return out, ex.sent_ok, ex.overflow
    out, st = reply(ex, answers, names, schedule, stats=ex.stats)
    return out, ex.sent_ok, ex.overflow, st


def _coalesced_lookup(table: jax.Array, vids: jax.Array, runs,
                      valid: jax.Array, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str,
                      stats: ExchangeStats):
    """``_sharded_lookup`` with request coalescing over equal-vid runs.

    The edge array is lexicographically sorted, so consecutive slots
    request the same vertex ~avg-degree times.  ``runs`` is the
    precomputed ``run_metadata(vids)`` (static across rounds): only run
    heads whose run contains at least one valid slot send a request, and
    the reply fans back out locally through the head index.  Divides
    routed lookup items by the average run length and lets ``capacity``
    shrink to the run-head bound (``default_lookup_capacity``), with the
    same exact overflow accounting — a dropped head drops its whole run,
    reported through ``overflow``/``ok``.
    """
    names = tuple(axes)
    head, head_idx, run_id = runs
    any_valid = compat.vary(jnp.zeros(valid.shape, bool), names
                            ).at[run_id].max(valid)
    req = head & any_valid[run_id]
    base = lax.axis_index(names) * vps
    ex = routed_exchange(vids, vids // vps, req, capacity, names,
                         schedule, stats=stats)
    off = jnp.clip(ex.recv - base, 0, vps - 1)
    answers = jnp.where(ex.recv_ok, table[off], jnp.int32(-1))
    out_h, st = reply(ex, answers, names, schedule, stats=ex.stats)
    return out_h[head_idx], valid & ex.sent_ok[head_idx], ex.overflow, st


def _sharded_preprocess(u, v, w, eid, valid, n: int, vps: int,
                        capacity: int, axes: Tuple[str, ...],
                        schedule: str, stats: ExchangeStats):
    """Sharded LOCALPREPROCESSING (Section IV-A + ISSUE 2 lever 1).

    Runs the comm-free local contraction, then seeds the sharded label
    vector with ONE routed scatter of the changed (vid, root) pairs to
    the owners — each vertex is contracted on at most one shard, so the
    owner-side scatter has no conflicts.  Also returns the initial
    ``dead`` slot mask: edges whose endpoints contracted into the same
    local component can never be MSF candidates again.

    Returns (lab [vps], pre_mst [cap] bool, dead0 [cap] bool, overflow,
    stats).  Capacity ``label_capacity`` is overflow-free by
    construction: an owner owns ``vps`` vertices, so no sender can have
    more than ``vps`` changed labels for it.
    """
    names = tuple(axes)
    loc_labels, pre_mst = _local_preprocessing_core(u, v, w, eid, valid,
                                                    n, names)
    iota_n = jnp.arange(n, dtype=jnp.int32)
    changed = loc_labels != iota_n
    ex = routed_exchange((compat.vary(iota_n, names), loc_labels),
                         iota_n // vps, changed, capacity, names,
                         schedule, stats=stats)
    base = lax.axis_index(names) * vps
    vid = base + jnp.arange(vps, dtype=jnp.int32)
    rvid = ex.recv[0].reshape(-1)
    rlab = ex.recv[1].reshape(-1)
    ok = ex.recv_ok.reshape(-1)
    off = jnp.where(ok, rvid - base, vps)  # vps = drop row
    lab = jnp.concatenate([vid, jnp.full((1,), -1, jnp.int32)]
                          ).at[off].set(rlab)[:vps]
    dead0 = loc_labels[u] == loc_labels[v]  # includes self-loops u == v
    return lab, pre_mst, dead0, ex.overflow, ex.stats


def _owner_scatter_min(comp, wc, ec, oc, okc, base, vps: int):
    """Owner-side (w, eid)-ordered scatter-min over owned component slots.

    Shared by both MINEDGES variants so the tie-break discipline cannot
    diverge between them.  ``comp/wc/ec/oc/okc`` are the flat received
    candidates; slot ``vps`` is the drop row for unused buffer entries.
    Returns (has [vps], other [vps], is_win [flat], off [flat]).
    """
    off = jnp.where(okc, comp - base, vps)
    wmin = jnp.full((vps + 1,), jnp.inf, wc.dtype).at[off].min(
        jnp.where(okc, wc, jnp.inf))
    at_min = okc & (wc == wmin[off])
    emin = jnp.full((vps + 1,), ESENT, jnp.int32).at[off].min(
        jnp.where(at_min, ec, ESENT))
    is_win = at_min & (ec == emin[off])
    other = jnp.full((vps + 1,), -1, jnp.int32).at[off].max(
        jnp.where(is_win, oc, -1))
    has = emin[:vps] < ESENT
    return has, other[:vps], is_win, off


def _sharded_minedges(ru, rv, wk, eid, alive, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str,
                      stats: ExchangeStats):
    """Owner-computes MINEDGES, 2-exchange variant (the PR 1 baseline).

    Each *directed* edge copy ships a ``(comp, w, eid, other)`` candidate
    to the owner of both its source component (keyed ``ru``) and its
    destination component (keyed ``rv``): together they hand every owner
    all edges incident to its components.  The owner scatter-mins with
    the (w, eid) order over its [vps] slots and confirms winners back to
    the submitting slot, so the caller can mark the canonical copy.

    Returns (has [vps], other [vps], win [L], overflow, stats).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    ex_u = routed_exchange((ru, wk, eid, rv), ru // vps, alive, capacity,
                           names, schedule, stats=stats)
    ex_v = routed_exchange((rv, wk, eid, ru), rv // vps, alive, capacity,
                           names, schedule, stats=ex_u.stats)

    def flat(ex):
        comp, w_, e_, o_ = ex.recv
        return (comp.reshape(-1), w_.reshape(-1), e_.reshape(-1),
                o_.reshape(-1), ex.recv_ok.reshape(-1))

    ku, wu, eu, ou, oku = flat(ex_u)
    kv, wv, ev, ov, okv = flat(ex_v)
    comp = jnp.concatenate([ku, kv])
    wc = jnp.concatenate([wu, wv])
    ec = jnp.concatenate([eu, ev])
    oc = jnp.concatenate([ou, ov])
    okc = jnp.concatenate([oku, okv])
    has, other, is_win, _ = _owner_scatter_min(comp, wc, ec, oc, okc,
                                               base, vps)
    # confirm winners to the submitting slots (both exchanges carry the
    # same (w, eid) for the two copies of an undirected edge, so a slot
    # wins iff either of its endpoint components chose it)
    nu = ku.shape[0]
    win_u, st = reply(ex_u, is_win[:nu].reshape(ex_u.recv_ok.shape), names,
                      schedule, stats=ex_v.stats)
    win_v, st = reply(ex_v, is_win[nu:].reshape(ex_v.recv_ok.shape), names,
                      schedule, stats=st)
    win = (win_u & ex_u.sent_ok) | (win_v & ex_v.sent_ok)
    return has, other, win, ex_u.overflow + ex_v.overflow, st


def _sharded_minedges_src(ru, rv, wk, eid, alive, vps: int, capacity: int,
                          axes: Tuple[str, ...], schedule: str,
                          stats: ExchangeStats):
    """Owner-computes MINEDGES, src-only variant (ISSUE 2 lever 3).

    Both directed copies of every edge are present, so the owner of
    component ``c`` already receives every edge incident to ``c``
    through the ``ru``-keyed exchange alone (the invariant
    ``boruvka_shrink_srconly`` exploits in the replicated engine): the
    ``rv``-keyed exchange is dropped, halving MINEDGES to 1 routed
    exchange + 1 confirmation.  The confirmation is deferred — the
    caller replies through the returned ``ex`` once the contraction's
    first lookup has revealed which winners are the larger side of a
    2-cycle (see module docstring: exact-once marking).

    Returns (has [vps], other [vps], is_win [p*C] flat, off [p*C] flat
    owner slot per candidate, ex).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    ex = routed_exchange((ru, wk, eid, rv), ru // vps, alive, capacity,
                         names, schedule, stats=stats)
    comp, w_, e_, o_ = (x.reshape(-1) for x in ex.recv)
    okc = ex.recv_ok.reshape(-1)
    has, other, is_win, off = _owner_scatter_min(comp, w_, e_, o_, okc,
                                                 base, vps)
    return has, other, is_win, off, ex


def _sharded_contract(has, other, n: int, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str,
                      adaptive: bool, stats: ExchangeStats):
    """Pointer doubling over the sharded parent array (request/reply).

    Every owned slot is a potential component root: roots with a chosen
    edge point at the other endpoint's component, everything else at
    itself.  The 2-cycle of mutually chosen components keeps the smaller
    id as root; then doubling rounds of one routed lookup each — a fixed
    log2(n) schedule, or (``adaptive``) a while_loop that stops one step
    after a psum reports no parent changed, which post round 1 cuts the
    schedule to the actual tree depth.  The iteration cap stays at
    log2(n) either way, so undersized capacities (garbage answers) can
    not loop forever.

    Returns (parent [vps] fully contracted, keep [vps] — exact-once
    owner-side marking decision for src-only MINEDGES (winner and not
    the larger side of a 2-cycle), overflow, stats).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    vid = base + jnp.arange(vps, dtype=jnp.int32)
    ones = compat.vary(jnp.ones((vps,), bool), names)
    parent0 = jnp.where(has, other, vid)
    gp, _, ov0, stats = _sharded_lookup(parent0, parent0, ones, vps,
                                        capacity, names, schedule,
                                        stats=stats)
    # a 2-cycle (mutually chosen components) necessarily chose the SAME
    # edge — each side's minimum bounds the other's — so `keep` marks
    # every winning (component, edge) pair on exactly one owner
    mutual = gp == vid
    keep = has & (~mutual | (vid < parent0))
    parent = jnp.where(mutual & (vid < parent0), vid, parent0)
    iters = _doubling_iters(n)

    if adaptive:
        def dbl_a(carry):
            par, ov, st, i, _ = carry
            nxt, _, o, st = _sharded_lookup(par, par, ones, vps, capacity,
                                            names, schedule, stats=st)
            chg = lax.psum(jnp.sum((nxt != par).astype(jnp.int32)),
                           names) > 0
            return nxt, ov + o, st, i + 1, chg

        def cond(carry):
            return carry[4] & (carry[3] < iters)

        parent, ov, stats, _, _ = lax.while_loop(
            cond, dbl_a,
            (parent, ov0, stats, jnp.int32(0), jnp.array(True)))
    else:
        def dbl(_, carry):
            par, ov, st = carry
            nxt, _, o, st = _sharded_lookup(par, par, ones, vps, capacity,
                                            names, schedule, stats=st)
            return nxt, ov + o, st

        parent, ov, stats = lax.fori_loop(0, iters, dbl,
                                          (parent, ov0, stats))
    return parent, keep, ov, stats


def _sharded_rounds(u, v, w, eid, valid, lab, mst, dead, n: int, vps: int,
                    axes: Tuple[str, ...], active: Optional[jax.Array],
                    max_rounds: int, cap_edge: int, cap_label: int,
                    cap_lookup: int, overflow, stats: ExchangeStats,
                    rounds, schedule: str, coalesce: bool, src_only: bool,
                    adaptive: bool):
    """Borůvka rounds with 1D-sharded labels.

    ``active`` optionally restricts the edge set (the filter levels);
    ``dead`` persists across rounds AND levels (once ``ru == rv`` a slot
    is dead forever — labels only coarsen).  The loop carry is
    (lab [vps], mst [cap], dead [cap], go, round, overflow, stats).
    """
    names = tuple(axes)
    live0 = valid if active is None else (valid & active)
    # run structure of the endpoint arrays is static across rounds
    runs_u = run_metadata(u) if coalesce else None
    runs_v = run_metadata(v) if coalesce else None

    def lookup_ep(table, runs, vids, live, st):
        if coalesce:
            return _coalesced_lookup(table, vids, runs, live, vps,
                                     cap_lookup, names, schedule, st)
        return _sharded_lookup(table, vids, live, vps, cap_lookup,
                               names, schedule, stats=st)

    def round_(state):
        lab, mst, dead, _, r, ovf, st = state
        live = live0 & ~dead
        ru, ok_u, o1, st = lookup_ep(lab, runs_u, u, live, st)
        rv, ok_v, o2, st = lookup_ep(lab, runs_v, v, live, st)
        looked = ok_u & ok_v
        # dead-edge retirement: same component now => same forever
        dead = dead | (looked & (ru == rv))
        alive = looked & (ru != rv) & live
        wk = jnp.where(alive, w, jnp.inf)
        if src_only:
            has, other, is_win, off, ex = _sharded_minedges_src(
                ru, rv, wk, eid, alive, vps, cap_edge, names, schedule, st)
            parent, keep, o4, st = _sharded_contract(
                has, other, n, vps, cap_label, names, schedule, adaptive,
                ex.stats)
            keep_ext = jnp.concatenate([keep, jnp.zeros((1,), bool)])
            confirm = (is_win & keep_ext[off]).reshape(ex.recv_ok.shape)
            win, st = reply(ex, confirm, names, schedule, stats=st)
            # owner-side dedup => exactly one directed slot per MSF edge
            mst = mst | (win & ex.sent_ok)
            o3 = ex.overflow
        else:
            has, other, win, o3, st = _sharded_minedges(
                ru, rv, wk, eid, alive, vps, cap_edge, names, schedule, st)
            # both directed copies are confirmed; mark only the canonical
            # one so the global mask is exact-once
            mst = mst | (win & (u < v))
            parent, _, o4, st = _sharded_contract(
                has, other, n, vps, cap_label, names, schedule, adaptive,
                st)
        lab, _, o5, st = _sharded_lookup(
            parent, lab, compat.vary(jnp.ones((vps,), bool), names), vps,
            cap_label, names, schedule, stats=st)
        go = lax.psum(jnp.sum(has.astype(jnp.int32)), names) > 0
        return lab, mst, dead, go, r + 1, ovf + o1 + o2 + o3 + o4 + o5, st

    def cond(state):
        return state[3] & (state[4] < max_rounds)

    lab, mst, dead, _, r, overflow, stats = lax.while_loop(
        cond, round_,
        (lab, mst, dead, jnp.array(True), jnp.int32(0), overflow, stats))
    return lab, mst, dead, overflow, stats, rounds + r


# --------------------------------------------------------------------------
# the full per-shard program + host wrapper
# --------------------------------------------------------------------------

def _sharded_shard_fn(u, v, w, eid, n: int, vps: int,
                      axes: Tuple[str, ...], algorithm: str,
                      num_levels: int, max_rounds: Optional[int],
                      cap_edge: int, cap_label: int, cap_lookup: int,
                      schedule: str, local_preprocessing: bool,
                      coalesce: bool, src_only: bool, adaptive: bool):
    names = tuple(axes)
    valid = jnp.isfinite(w)
    base = lax.axis_index(names) * vps
    lab = base + jnp.arange(vps, dtype=jnp.int32)
    mst = compat.vary(jnp.zeros(u.shape, bool), names)
    # psum outputs are axis-invariant, so the overflow accumulator, the
    # comm counters and the loop's ``go`` flag stay unvarying on both
    # JAX generations
    overflow = jnp.int32(0)
    stats = ExchangeStats.zeros()
    rounds = jnp.int32(0)
    mr = (math.ceil(math.log2(max(n, 2))) + 1) if max_rounds is None \
        else max_rounds

    if local_preprocessing:
        lab, pre_mst, dead, ovf, stats = _sharded_preprocess(
            u, v, w, eid, valid, n, vps, cap_label, names, schedule, stats)
        overflow += ovf
    else:
        pre_mst = compat.vary(jnp.zeros(u.shape, bool), names)
        dead = u == v  # self-loops can never be MSF candidates

    common = dict(n=n, vps=vps, axes=names, max_rounds=mr,
                  cap_edge=cap_edge, cap_label=cap_label,
                  cap_lookup=cap_lookup, schedule=schedule,
                  coalesce=coalesce, src_only=src_only, adaptive=adaptive)
    if algorithm == "boruvka":
        lab, mst, dead, overflow, stats, rounds = _sharded_rounds(
            u, v, w, eid, valid, lab, mst, dead, active=None,
            overflow=overflow, stats=stats, rounds=rounds, **common)
    elif algorithm == "filter_boruvka":
        pivots = _weight_pivots(w, valid, num_levels, names)
        lo = jnp.float32(-jnp.inf)
        for lvl in range(num_levels):
            hi = pivots[lvl] if lvl < num_levels - 1 else jnp.float32(jnp.inf)
            active = (w > lo) & (w <= hi)
            lab, mst, dead, overflow, stats, rounds = _sharded_rounds(
                u, v, w, eid, valid, lab, mst, dead, active=active,
                overflow=overflow, stats=stats, rounds=rounds, **common)
            lo = hi
    else:
        raise ValueError(algorithm)

    full_mask = mst | pre_mst
    weight = lax.psum(jnp.sum(jnp.where(full_mask, w, 0.0)), names)
    count = lax.psum(jnp.sum(full_mask.astype(jnp.int32)), names)
    comm = CommStats(stats.calls, stats.items, stats.bytes, rounds)
    return full_mask, weight, count, lab, overflow, comm


@functools.lru_cache(maxsize=64)
def _build_sharded_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                      axes: Tuple[str, ...], algorithm: str,
                      num_levels: int, max_rounds: Optional[int],
                      cap_edge: int, cap_label: int, cap_lookup: int,
                      schedule: str, local_preprocessing: bool,
                      coalesce: bool, src_only: bool, adaptive: bool):
    fn = partial(_sharded_shard_fn, n=n, vps=vps, axes=axes,
                 algorithm=algorithm, num_levels=num_levels,
                 max_rounds=max_rounds, cap_edge=cap_edge,
                 cap_label=cap_label, cap_lookup=cap_lookup,
                 schedule=schedule,
                 local_preprocessing=local_preprocessing,
                 coalesce=coalesce, src_only=src_only, adaptive=adaptive)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, P(), P(), spec, P(), P())))


def vertices_per_shard(n: int, num_shards: int) -> int:
    return max(1, -(-n // num_shards))


def default_lookup_capacity(graph: DistGraph, num_shards: int,
                            n: int) -> int:
    """Exact-by-construction capacity for the coalesced endpoint lookups.

    One host-side pass over the (already host-built) edge arrays counts,
    per (shard, owner) pair, the contiguous equal-value runs of each
    endpoint array — the maximum possible number of coalesced requests
    any shard sends any owner.  Typically ~edges/(shard·avg_degree)
    instead of edges/shard, which shrinks the [p, C] lookup buffers by
    the same factor the coalescing shrinks the routed volume.
    """
    vps = vertices_per_shard(n, num_shards)
    cap = graph.cap_total // num_shards
    mx = 1
    for arr in (graph.u, graph.v):
        a = np.asarray(arr).reshape(num_shards, cap)
        head = np.ones((num_shards, cap), bool)
        head[:, 1:] = a[:, 1:] != a[:, :-1]
        dest = a // vps
        for s in range(num_shards):
            d = dest[s][head[s]]
            if d.size:
                mx = max(mx, int(np.bincount(d).max()))
    return mx


def distributed_sharded_msf(graph: DistGraph, n: int,
                            mesh: jax.sharding.Mesh, *,
                            algorithm: str = "boruvka",
                            axis_names: Optional[Sequence[str]] = None,
                            num_levels: int = 4,
                            max_rounds: Optional[int] = None,
                            edge_capacity: Optional[int] = None,
                            label_capacity: Optional[int] = None,
                            lookup_capacity: Optional[int] = None,
                            schedule: str = "grid",
                            local_preprocessing: bool = True,
                            coalesce: bool = True,
                            src_only: bool = True,
                            adaptive_doubling: bool = True):
    """Run the sharded-label distributed MSF on a mesh.

    Returns (mask, weight, count, labels, overflow, stats):
      * ``mask`` is aligned with ``graph`` slots, exactly one directed
        copy per MSF edge (the canonical u < v copy when
        ``src_only=False``);
      * ``labels`` is the *sharded* label vector laid out shard-major
        ([p * vertices_per_shard], slice [:n] for the per-vertex view);
      * ``overflow`` counts exchange items that exceeded capacity summed
        over all rounds — results are exact iff it is 0 (guaranteed with
        the default capacities); callers passing smaller capacities must
        retry larger on a positive count;
      * ``stats`` is a ``CommStats`` (all-to-all invocations, routed
        items, buffer bytes, rounds) — the honest comm metric the
        optimization flags move (benchmarks/sharded_scaling.py).

    The flags default to the optimized engine; passing
    ``local_preprocessing=False, coalesce=False, src_only=False,
    adaptive_doubling=False`` reproduces the PR 1 baseline exactly.
    """
    axes = tuple(axis_names or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    vps = vertices_per_shard(n, p)
    cap = graph.cap_total // p
    # is-None (not falsy) checks: an explicit 0 must be honored — it
    # yields all-overflow results, which the overflow count reports
    ce = int(cap if edge_capacity is None else edge_capacity)
    cl = int(vps if label_capacity is None else label_capacity)
    if lookup_capacity is None:
        # the exact host-side bound needs concrete edge arrays; under AOT
        # lowering (make_sharded_mst_step) fall back to the safe bound
        concrete = not isinstance(graph.u, jax.core.Tracer)
        lk = default_lookup_capacity(graph, p, n) if (coalesce and concrete) \
            else ce
    else:
        lk = int(lookup_capacity)
    shard_fn = _build_sharded_fn(n, vps, mesh, axes, algorithm, num_levels,
                                 max_rounds, ce, cl, lk, schedule,
                                 local_preprocessing, coalesce, src_only,
                                 adaptive_doubling)
    return shard_fn(graph.u, graph.v, graph.w, graph.eid)


def make_sharded_mst_step(n: int, cap_total: int, mesh: jax.sharding.Mesh,
                          algorithm: str = "boruvka", **kw):
    """AOT-lowerable sharded MSF step (dry-run/roofline harness parity)."""
    def step(u, v, w, eid):
        g = DistGraph(u, v, w, eid)
        return distributed_sharded_msf(g, n, mesh, algorithm=algorithm, **kw)

    specs = (
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.float32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
    )
    return step, specs
