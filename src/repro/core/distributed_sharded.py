"""Sharded-label distributed Borůvka / Filter-Borůvka (Section IV, the
scalable path for n >> memory/PE).

``core/distributed.py`` replicates the vertex→component label vector on
every shard, which costs O(n) memory per PE and an allReduce of
n-vectors per round — the paper's *base case*.  This module implements
the representation the paper's 65 536-core runs rely on: the label
vector is **1D-sharded by vertex id** (owner of vertex ``vid`` is shard
``vid // vertices_per_shard``) and every label access becomes a routed
message through the capacity-bounded exchange of ``comm/exchange.py``
(the XLA-native stand-in for the paper's sparse ``MPI_Alltoallv``):

  MINEDGES   Each edge shard looks up the component of both endpoints
             from the owners (request/reply), scatter-mins locally over
             *nothing* — instead it ships one ``(component, w, eid,
             other_component)`` candidate per directed copy to the
             component's owner, which scatter-mins over its owned slots
             only.  Winning candidates are confirmed back to the sending
             edge slot so the canonical (u < v) copy can be marked.
  CONTRACT   Pointer doubling over the sharded parent array: each
             doubling step is one request_reply round asking
             ``owner(parent[x])`` for ``parent[parent[x]]``
             (EXCHANGELABELS).  The 2-cycle of a pair of components that
             choose each other is broken toward the smaller id, exactly
             as in the replicated engine.
  RELABEL    Every owned vertex re-resolves its label through one more
             lookup of the contracted parent array.

Per-shard label memory is O(n/p) instead of O(n); all exchanges are
capacity-bounded with explicit overflow accounting (never silent): with
the default capacities (``edge_capacity = edges/shard``,
``label_capacity = vertices/shard``) overflow is impossible and results
are exact; undersized capacities report a positive overflow count and
the caller must retry larger (EXPERIMENTS.md §Sharded-label engine).

Tie-breaking is the direction-independent ``(w, eid)`` order shared by
all engines and the Kruskal oracle, so the produced MSF edge set is
bit-identical across engines (tests/test_engine_equivalence.py).
"""
from __future__ import annotations

import functools
import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm.exchange import reply, routed_exchange
from repro.core.distributed import (ESENT, DistGraph, _doubling_iters,
                                    _weight_pivots)


# --------------------------------------------------------------------------
# sharded building blocks (all run inside shard_map)
# --------------------------------------------------------------------------

def _sharded_lookup(table: jax.Array, vids: jax.Array, valid: jax.Array,
                    vps: int, capacity: int, axes: Tuple[str, ...],
                    schedule: str = "grid"):
    """Resolve ``table[vids[i]]`` where ``table`` is 1D-sharded by id.

    ``table`` is this shard's [vps] slice of a global [p * vps] int32
    array; ``vids`` are global ids.  Owner routing: the request carries
    the id itself, the owner answers ``table[id - base]``, the answer is
    routed back to the requesting slot (the paper's request/reply label
    exchange).  Returns (values [L], ok [L], overflow) — entries with
    ``ok`` False overflowed the exchange and carry garbage.
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    ex = routed_exchange(vids, vids // vps, valid, capacity, names, schedule)
    off = jnp.clip(ex.recv - base, 0, vps - 1)
    answers = jnp.where(ex.recv_ok, table[off], jnp.int32(-1))
    out = reply(ex, answers, names, schedule)
    return out, ex.sent_ok, ex.overflow


def _sharded_minedges(ru, rv, wk, eid, alive, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str = "grid"):
    """Owner-computes MINEDGES over sharded component slots.

    Each *directed* edge copy ships a ``(comp, w, eid, other)`` candidate
    to the owner of both its source component (keyed ``ru``) and its
    destination component (keyed ``rv``): together they hand every owner
    all edges incident to its components.  The owner scatter-mins with
    the (w, eid) order over its [vps] slots and confirms winners back to
    the submitting slot, so the caller can mark the canonical copy.

    Returns (has [vps], other [vps], win [L], overflow).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    ex_u = routed_exchange((ru, wk, eid, rv), ru // vps, alive, capacity,
                           names, schedule)
    ex_v = routed_exchange((rv, wk, eid, ru), rv // vps, alive, capacity,
                           names, schedule)

    def flat(ex):
        comp, w_, e_, o_ = ex.recv
        return (comp.reshape(-1), w_.reshape(-1), e_.reshape(-1),
                o_.reshape(-1), ex.recv_ok.reshape(-1))

    ku, wu, eu, ou, oku = flat(ex_u)
    kv, wv, ev, ov, okv = flat(ex_v)
    comp = jnp.concatenate([ku, kv])
    wc = jnp.concatenate([wu, wv])
    ec = jnp.concatenate([eu, ev])
    oc = jnp.concatenate([ou, ov])
    okc = jnp.concatenate([oku, okv])
    # slot vps is the drop row for unused buffer entries
    off = jnp.where(okc, comp - base, vps)
    wmin = jnp.full((vps + 1,), jnp.inf, wc.dtype).at[off].min(
        jnp.where(okc, wc, jnp.inf))
    at_min = okc & (wc == wmin[off])
    emin = jnp.full((vps + 1,), ESENT, jnp.int32).at[off].min(
        jnp.where(at_min, ec, ESENT))
    is_win = at_min & (ec == emin[off])
    other = jnp.full((vps + 1,), -1, jnp.int32).at[off].max(
        jnp.where(is_win, oc, -1))
    has = emin[:vps] < ESENT
    # confirm winners to the submitting slots (both exchanges carry the
    # same (w, eid) for the two copies of an undirected edge, so a slot
    # wins iff either of its endpoint components chose it)
    nu = ku.shape[0]
    win_u = reply(ex_u, is_win[:nu].reshape(ex_u.recv_ok.shape), names,
                  schedule)
    win_v = reply(ex_v, is_win[nu:].reshape(ex_v.recv_ok.shape), names,
                  schedule)
    win = (win_u & ex_u.sent_ok) | (win_v & ex_v.sent_ok)
    return has, other[:vps], win, ex_u.overflow + ex_v.overflow


def _sharded_contract(has, other, n: int, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str = "grid"):
    """Pointer doubling over the sharded parent array (request/reply).

    Every owned slot is a potential component root: roots with a chosen
    edge point at the other endpoint's component, everything else at
    itself.  The 2-cycle of mutually chosen components keeps the smaller
    id as root; then log2(n) doubling rounds, each one routed lookup.
    Returns (parent [vps] fully contracted, overflow).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    vid = base + jnp.arange(vps, dtype=jnp.int32)
    ones = compat.vary(jnp.ones((vps,), bool), names)
    parent = jnp.where(has, other, vid)
    gp, _, ov0 = _sharded_lookup(parent, parent, ones, vps, capacity,
                                 names, schedule)
    parent = jnp.where((gp == vid) & (vid < parent), vid, parent)

    def dbl(_, carry):
        par, ov = carry
        nxt, _, o = _sharded_lookup(par, par, ones, vps, capacity, names,
                                    schedule)
        return nxt, ov + o

    parent, ov = lax.fori_loop(0, _doubling_iters(n), dbl, (parent, ov0))
    return parent, ov


def _sharded_rounds(u, v, w, eid, valid, lab, mst, n: int, vps: int,
                    axes: Tuple[str, ...], active: Optional[jax.Array],
                    max_rounds: int, cap_edge: int, cap_label: int,
                    overflow, schedule: str = "grid"):
    """Borůvka rounds with 1D-sharded labels.

    ``active`` optionally restricts the edge set (the filter levels).
    The loop carry is (lab [vps], mst [cap], go, round, overflow).
    """
    names = tuple(axes)
    live = valid if active is None else (valid & active)

    def round_(state):
        lab, mst, _, r, ovf = state
        ru, ok_u, o1 = _sharded_lookup(lab, u, live, vps, cap_edge, names,
                                       schedule)
        rv, ok_v, o2 = _sharded_lookup(lab, v, live, vps, cap_edge, names,
                                       schedule)
        alive = ok_u & ok_v & (ru != rv) & live
        wk = jnp.where(alive, w, jnp.inf)
        has, other, win, o3 = _sharded_minedges(ru, rv, wk, eid, alive,
                                                vps, cap_edge, names,
                                                schedule)
        # each undirected MSF edge is confirmed on both directed copies;
        # mark only the canonical one so the global mask is exact-once
        mst = mst | (win & (u < v))
        parent, o4 = _sharded_contract(has, other, n, vps, cap_label,
                                       names, schedule)
        lab, _, o5 = _sharded_lookup(
            parent, lab, compat.vary(jnp.ones((vps,), bool), names), vps,
            cap_label, names, schedule)
        go = lax.psum(jnp.sum(has.astype(jnp.int32)), names) > 0
        return lab, mst, go, r + 1, ovf + o1 + o2 + o3 + o4 + o5

    def cond(state):
        return state[2] & (state[3] < max_rounds)

    lab, mst, _, _, overflow = lax.while_loop(
        cond, round_,
        (lab, mst, jnp.array(True), jnp.int32(0), overflow))
    return lab, mst, overflow


# --------------------------------------------------------------------------
# the full per-shard program + host wrapper
# --------------------------------------------------------------------------

def _sharded_shard_fn(u, v, w, eid, n: int, vps: int,
                      axes: Tuple[str, ...], algorithm: str,
                      num_levels: int, max_rounds: Optional[int],
                      cap_edge: int, cap_label: int, schedule: str):
    names = tuple(axes)
    valid = jnp.isfinite(w)
    base = lax.axis_index(names) * vps
    lab = base + jnp.arange(vps, dtype=jnp.int32)
    mst = compat.vary(jnp.zeros(u.shape, bool), names)
    # psum outputs are axis-invariant, so the overflow accumulator (and
    # the loop's ``go`` flag) stay unvarying on both JAX generations
    overflow = jnp.int32(0)
    mr = (math.ceil(math.log2(max(n, 2))) + 1) if max_rounds is None \
        else max_rounds

    if algorithm == "boruvka":
        lab, mst, overflow = _sharded_rounds(
            u, v, w, eid, valid, lab, mst, n, vps, names, None, mr,
            cap_edge, cap_label, overflow, schedule)
    elif algorithm == "filter_boruvka":
        pivots = _weight_pivots(w, valid, num_levels, names)
        lo = jnp.float32(-jnp.inf)
        for lvl in range(num_levels):
            hi = pivots[lvl] if lvl < num_levels - 1 else jnp.float32(jnp.inf)
            active = (w > lo) & (w <= hi)
            lab, mst, overflow = _sharded_rounds(
                u, v, w, eid, valid, lab, mst, n, vps, names, active, mr,
                cap_edge, cap_label, overflow, schedule)
            lo = hi
    else:
        raise ValueError(algorithm)

    weight = lax.psum(jnp.sum(jnp.where(mst, w, 0.0)), names)
    count = lax.psum(jnp.sum(mst.astype(jnp.int32)), names)
    return mst, weight, count, lab, overflow


@functools.lru_cache(maxsize=64)
def _build_sharded_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                      axes: Tuple[str, ...], algorithm: str,
                      num_levels: int, max_rounds: Optional[int],
                      cap_edge: int, cap_label: int, schedule: str):
    fn = partial(_sharded_shard_fn, n=n, vps=vps, axes=axes,
                 algorithm=algorithm, num_levels=num_levels,
                 max_rounds=max_rounds, cap_edge=cap_edge,
                 cap_label=cap_label, schedule=schedule)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, P(), P(), spec, P())))


def vertices_per_shard(n: int, num_shards: int) -> int:
    return max(1, -(-n // num_shards))


def distributed_sharded_msf(graph: DistGraph, n: int,
                            mesh: jax.sharding.Mesh, *,
                            algorithm: str = "boruvka",
                            axis_names: Optional[Sequence[str]] = None,
                            num_levels: int = 4,
                            max_rounds: Optional[int] = None,
                            edge_capacity: Optional[int] = None,
                            label_capacity: Optional[int] = None,
                            schedule: str = "grid"):
    """Run the sharded-label distributed MSF on a mesh.

    Returns (mask, weight, count, labels, overflow):
      * ``mask`` is aligned with ``graph`` slots, one canonical directed
        copy per MSF edge;
      * ``labels`` is the *sharded* label vector laid out shard-major
        ([p * vertices_per_shard], slice [:n] for the per-vertex view);
      * ``overflow`` counts exchange items that exceeded capacity summed
        over all rounds — results are exact iff it is 0 (guaranteed with
        the default capacities); callers passing smaller capacities must
        retry larger on a positive count.
    """
    axes = tuple(axis_names or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    vps = vertices_per_shard(n, p)
    cap = graph.cap_total // p
    # is-None (not falsy) checks: an explicit 0 must be honored — it
    # yields all-overflow results, which the overflow count reports
    ce = int(cap if edge_capacity is None else edge_capacity)
    cl = int(vps if label_capacity is None else label_capacity)
    shard_fn = _build_sharded_fn(n, vps, mesh, axes, algorithm, num_levels,
                                 max_rounds, ce, cl, schedule)
    return shard_fn(graph.u, graph.v, graph.w, graph.eid)


def make_sharded_mst_step(n: int, cap_total: int, mesh: jax.sharding.Mesh,
                          algorithm: str = "boruvka", **kw):
    """AOT-lowerable sharded MSF step (dry-run/roofline harness parity)."""
    def step(u, v, w, eid):
        g = DistGraph(u, v, w, eid)
        return distributed_sharded_msf(g, n, mesh, algorithm=algorithm, **kw)

    specs = (
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.float32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
    )
    return step, specs
