"""Sharded-label distributed Borůvka / Filter-Borůvka (Section IV, the
scalable path for n >> memory/PE).

``core/distributed.py`` replicates the vertex→component label vector on
every shard, which costs O(n) memory per PE and an allReduce of
n-vectors per round — the paper's *base case*.  This module implements
the representation the paper's 65 536-core runs rely on: the label
vector is **1D-sharded by vertex id** (owner of vertex ``vid`` is shard
``vid // vertices_per_shard``) and every label access becomes a routed
message through the capacity-bounded exchange of ``comm/exchange.py``
(the XLA-native stand-in for the paper's sparse ``MPI_Alltoallv``).

The phases, with the communication-minimisation levers of ISSUE 2 (all
individually toggleable; EXPERIMENTS.md §Sharded-label engine records
the measured all-to-all / routed-volume deltas):

  LOCALPREPROCESSING  (``local_preprocessing=True``, Section IV-A)
             Contract provably-local MST edges comm-free, then seed the
             routed rounds with ONE routed label scatter to the owners.
             The contraction runs in the shard's **bucketed vertex
             space** — the distinct source ids of its sorted edge slice,
             at most edges/shard of them — so no [n]-sized scratch is
             ever materialised (ISSUE 3: peak memory O(n/p) in *every*
             phase, not just the carried state).  Edges both of whose
             endpoints were contracted into the same component are
             retired into the ``dead`` mask before the first round.
  MINEDGES   Each edge shard looks up the component of both endpoints
             from the owners (request/reply).  With ``coalesce=True``
             the lexicographically sorted edge array is deduplicated
             first: one request per contiguous equal-endpoint run
             (segmented-scan run detection shared with kernels/segmin),
             answers fanned back out locally — lookup volume drops by
             ~avg-degree and ``lookup_capacity`` shrinks to the
             host-computed run-head bound.  With ``src_only=True`` each
             directed copy ships its ``(comp, w, eid, other)`` candidate
             only to the owner of its *source* component: both directed
             copies exist, so that owner still sees every edge incident
             to its components — 1 routed exchange + 1 confirmation
             instead of 2 + 2.  The owner scatter-mins with the (w, eid)
             order over its owned slots only.
  CONTRACT   Pointer doubling over the sharded parent array: each
             doubling step is one request_reply round asking
             ``owner(parent[x])`` for ``parent[parent[x]]``
             (EXCHANGELABELS).  Slots whose parent is themselves (roots
             and everything without a chosen edge) answer locally and
             never enter the exchange.  The 2-cycle of a pair of
             components that choose each other is broken toward the
             smaller id.  With ``adaptive_doubling=True`` the fixed
             log2(n) schedule becomes a while_loop that stops one step
             after no parent changes (post round 1 contraction trees are
             shallow).
  RELABEL    Every owned vertex re-resolves its label through one more
             lookup of the contracted parent array.  Slots whose
             endpoints resolve to the same component join the persistent
             ``dead`` mask and stop generating requests and candidates.
             With ``relabel_skip=True`` (ISSUE 4) a vertex whose label
             is a component that chose no edge this round is **settled**
             — such a component has no alive incident edge, so neither
             it nor anything merging into it can ever change again (a
             choosing neighbour would have handed it a candidate) — and
             stops requesting for the rest of the level, mirroring
             CONTRACT's self-parent filter; the shrinking driver drops
             the RELABEL capacity below vps accordingly.

Ghost-vertex label cache (ISSUE 4 tentpole, ``ghost_cache=True`` by
default; the paper's ghost vertices, Section IV): the two per-round
endpoint lookups are the dominant routed volume once MINEDGES is
aggregated, and the ``v`` column barely coalesces in slot order (runs of
equal v are short after the lexicographic (u, v) sort).  Two changes:

  * a **v-sorted secondary index** (``VIndex``: a per-shard permutation
    sorting the v column, plus ``kernels/segmin run_metadata`` over the
    permuted view) makes *both* endpoint columns coalesce to one request
    per distinct remote vertex — used by the coalesced lookup path even
    with the cache off;
  * each shard keeps **ghost tables** ``gu``/``gv`` (cached label per
    distinct endpoint value, sized by the host from the distinct-value
    run counts), filled once at setup by live-gated coalesced lookups
    (all-dead runs are never read again, so never filled), after which
    each shard subscribes — one row per **distinct cached component
    root** — with the roots' owners.  Every round the endpoint labels
    are read locally from the tables (cache *hits*), and after the
    contraction each owner multicasts the **root deltas**
    ``(c, parent[c])`` for exactly the merged roots to root ``c``'s
    subscribers (``scatter_updates``, the dirty push); receivers
    rewrite entries by value, and the subscriber bitmasks are forwarded
    to the surviving roots' owners so subscriptions merge along with
    the components.  The dirty set is the merged-root set, which
    shrinks geometrically with the alive-component count — unlike
    per-vertex label churn, which stays flat while a giant component
    absorbs the graph — so steady-state lookup traffic is O(Δroots)
    instead of O(edges/shard) per round.  ``ExchangeStats`` carries
    hit/miss/push counters so the delta is measurable
    (benchmarks/sharded_scaling.py).  The int32 subscriber bitmask caps
    the scheme at 31 shards; larger meshes fall back to coalesced
    lookups automatically.

Shrinking capacity schedule (ISSUE 3 tentpole, ``shrink_capacities``,
default on): with flat capacities every round ships MINEDGES buffers
sized for the worst case ``edge_capacity = edges/shard`` even after the
dead-edge mask has retired most of the graph.  The shrinking driver
instead runs the *same* round body one jitted step at a time from the
host: before each round it bounds next round's exchanges from the
measured dead-edge mask (alive slots per shard for MINEDGES, the
alive-run-head count for coalesced lookups, the alive-component count
per owner for CONTRACT), snaps each bound up to the geometric capacity
ladder shared with ``boruvka_shrink`` (``core/distributed.py:
shrink_schedule`` — a small static unroll of decreasing capacities, so
the number of distinct compiled step programs stays logarithmic), and
compiles/reuses the step at those capacities.  Bounds are exact by
construction — a slot sends at most one candidate, a run sends at most
one request, a component requests at most one parent hop — so overflow
stays 0 and results are bit-identical to the flat engine; the explicit
overflow accounting remains as the safety net for user-supplied
capacities.  The dominant buffer-bytes term thereby decays geometrically
across rounds instead of staying flat (EXPERIMENTS.md §Shrinking
capacity schedule has the measured per-round trajectory).

Plan/execute split (ISSUE 5): the schedule above is also available as
a first-class value.  ``plan_sharded_msf`` runs the host-interleaved
driver once as a *measurement backend* and freezes the capacities it
chose into a serializable ``core/plan.py: RoundPlan``;
``execute_plan`` / ``distributed_sharded_msf(plan=...)`` /
``make_sharded_mst_step(plan=...)`` replay the plan as a
Python-unrolled multi-round program — per-round static capacities, one
compiled artifact, AOT-lowerable — with ``pad(margin)`` headroom for
serving and an overflow/residual → replan fallback that keeps the
never-silent contract.  The dry-run/roofline layer costs a planned
program's compiled memory and collectives without running it.

Chosen-edge marking: in src-only mode a mutual pair of components
necessarily chose the *same* edge (each side's minimum bounds the
other's), and mutuality is exactly the 2-cycle the contraction already
detects — so the owner marks a winner iff it is not the larger side of a
2-cycle, which marks every MSF edge on exactly one directed slot without
the second confirmation exchange.  In the 2-exchange mode the canonical
(u < v) copy is marked, as before.  Either way the slot mask marks each
undirected MSF edge exactly once (the engines' shared contract).

Per-shard label memory is O(n/p) instead of O(n); all exchanges are
capacity-bounded with explicit overflow accounting (never silent): with
the default capacities (``edge_capacity = edges/shard``,
``label_capacity = vertices/shard``, ``lookup_capacity`` = the exact
host-side run-head bound) overflow is impossible and results are exact;
undersized capacities report a positive overflow count and the caller
must retry larger (EXPERIMENTS.md §Sharded-label engine).

Tie-breaking is the direction-independent ``(w, eid)`` order shared by
all engines and the Kruskal oracle, so the produced MSF edge set is
bit-identical across engines (tests/test_engine_equivalence.py).
"""
from __future__ import annotations

import functools
import math
import warnings
from functools import partial
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import faults
from repro.comm.exchange import (ExchangeStats, _hops, reply,
                                 routed_exchange, scatter_updates,
                                 scatter_updates_grid)
from repro.core.distributed import (ESENT, CommStats, DistGraph,
                                    _doubling_iters, _weight_pivots,
                                    quantize_capacity)
from repro.core.msf_checkpoint import CheckpointError, MSFCheckpoint
from repro.core.plan import GhostPlan, RoundPlan, RoundSpec
from repro.kernels.segmin.ops import run_metadata
from repro.kernels.segmin.segmin import owner_scatter_min

# the ghost push encodes subscriber sets as int32 bitmasks; bit 31 is
# the sign bit, so the *flat* push caps at 31 shards.  The two-level
# grid push (ISSUE 10) stores one mask per mesh axis instead — 31 rows
# x 31 columns — lifting the addressable mesh to 961 shards; beyond
# that (or on meshes that do not factor into exactly two axes) the
# engine falls back to coalesced lookups.
MAX_GHOST_SHARDS = 31
MAX_GHOST_SHARDS_GRID = MAX_GHOST_SHARDS ** 2  # 961

# default checkpoint cadence (ISSUE 9): every this-many executed rounds
# both drivers run the verify barrier and snapshot — amortized to keep
# the measured overhead under the 15% acceptance bound at default scale
# (benchmarks/serve_msf.py `recovery` records the number)
DEFAULT_CKPT_EVERY = 8


class VIndex(NamedTuple):
    """Per-shard v-sorted secondary index (ISSUE 4).

    The edge slice is lexicographically (u, v)-sorted, so the v column's
    equal-value runs are short in slot order.  ``perm`` sorts the local
    slots by ``where(valid, v, n)`` (padding keys to the tail), ``runs``
    is ``run_metadata`` over that permuted view (one maximal run per
    distinct v), ``key`` the permuted key column, and ``rank`` maps each
    original slot to its distinct-v rank — the index into the v ghost
    table.  Static per solve: build once, reuse every round.
    """
    perm: jax.Array   # [cap] int32 — local permutation (v-sorted order)
    rank: jax.Array   # [cap] int32 — slot -> distinct-v rank
    runs: Tuple[jax.Array, jax.Array, jax.Array]  # run_metadata(key)
    key: jax.Array    # [cap] int32 — permuted keys (invalid slots = n)


def _build_v_index(v: jax.Array, valid: jax.Array, n: int,
                   names: Tuple[str, ...],
                   perm: Optional[jax.Array] = None) -> VIndex:
    """Build the v-sorted index; ``perm`` lets the host-orchestrated
    driver pass its precomputed per-shard argsort (any stable sort of
    the same keys yields identical runs/ranks, so host and device
    constructions are interchangeable)."""
    cap = v.shape[0]
    key0 = jnp.where(valid, v, jnp.int32(n))
    if perm is None:
        perm = jnp.argsort(key0, stable=True).astype(jnp.int32)
    runs = run_metadata(key0, perm=perm)
    rank = compat.vary(jnp.zeros((cap,), jnp.int32), names
                       ).at[perm].set(runs[2])
    return VIndex(perm, rank, runs, key0[perm])


# --------------------------------------------------------------------------
# sharded building blocks (all run inside shard_map)
# --------------------------------------------------------------------------

def _sharded_lookup(table: jax.Array, vids: jax.Array, valid: jax.Array,
                    vps: int, capacity: int, axes: Tuple[str, ...],
                    schedule: str = "grid",
                    stats: Optional[ExchangeStats] = None,
                    count_misses: bool = False,
                    site: str = "lookup"):
    """Resolve ``table[vids[i]]`` where ``table`` is 1D-sharded by id.

    ``table`` is this shard's [vps] slice of a global [p * vps] int32
    array; ``vids`` are global ids.  Owner routing: the request carries
    the id itself, the owner answers ``table[id - base]``, the answer is
    routed back to the requesting slot (the paper's request/reply label
    exchange).  Returns (values [L], ok [L], overflow) — entries with
    ``ok`` False overflowed the exchange and carry garbage; with
    ``stats`` the updated accumulator is appended to the tuple.
    ``count_misses`` books the request items under ``stats.misses`` too
    (endpoint-lookup call sites only — with no ghost cache every
    endpoint lookup is a miss; CONTRACT/RELABEL lookups never count).
    """
    names = tuple(axes)
    if stats is not None:
        return _lookup_request_reply(table, vids, valid, vps, capacity,
                                     names, schedule, stats,
                                     count_misses=count_misses, site=site)
    base = lax.axis_index(names) * vps
    ex = routed_exchange(vids, vids // vps, valid, capacity, names,
                         schedule, site=site)
    off = jnp.clip(ex.recv - base, 0, vps - 1)
    answers = jnp.where(ex.recv_ok, table[off], jnp.int32(-1))
    out = reply(ex, answers, names, schedule)
    return out, ex.sent_ok, ex.overflow


def _lookup_request_reply(table: jax.Array, vids: jax.Array,
                          req: jax.Array, vps: int, capacity: int,
                          names: Tuple[str, ...], schedule: str,
                          stats: ExchangeStats,
                          count_misses: bool = True,
                          site: str = "lookup"):
    """One owner-routed label request/reply leg with the miss accounting
    booked once — the shared core of every lookup/fill variant (only the
    request-set construction and the answer fan-out differ per caller),
    so the ``2 * p * capacity``-slots-per-lookup conservation law of
    ``tests/test_comm.py`` lives in exactly one place.  ``count_misses``
    is False for the CONTRACT/RELABEL lookups, which are not endpoint
    misses.  Returns (out [L] per-request answers, sent_ok [L],
    overflow, stats)."""
    base = lax.axis_index(names) * vps
    items0 = stats.items
    ex = routed_exchange(vids, vids // vps, req, capacity, names,
                         schedule, stats=stats, site=site)
    off = jnp.clip(ex.recv - base, 0, vps - 1)
    answers = jnp.where(ex.recv_ok, table[off], jnp.int32(-1))
    out, st = reply(ex, answers, names, schedule, stats=ex.stats)
    if count_misses:
        st = st._replace(misses=st.misses + (ex.stats.items - items0))
    return out, ex.sent_ok, ex.overflow, st


def _coalesced_lookup(table: jax.Array, vids: jax.Array, runs,
                      valid: jax.Array, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str,
                      stats: ExchangeStats):
    """``_sharded_lookup`` with request coalescing over equal-vid runs.

    ``runs`` is the precomputed ``run_metadata`` over ``vids`` (static
    across rounds): only run heads whose run contains at least one valid
    slot send a request, and the reply fans back out locally through the
    head index.  Divides routed lookup items by the average run length
    and lets ``capacity`` shrink to the run-head bound
    (``default_lookup_capacity``), with the same exact overflow
    accounting — a dropped head drops its whole run, reported through
    ``overflow``/``ok``.  ``runs`` must not be ``None`` — callers
    dispatch to the uncoalesced ``_sharded_lookup`` themselves (see
    ``_round_body``), so the stats accumulator is threaded through
    exactly one path.
    """
    names = tuple(axes)
    head, head_idx, run_id = runs
    any_valid = compat.vary(jnp.zeros(valid.shape, bool), names
                            ).at[run_id].max(valid)
    req = head & any_valid[run_id]
    out_h, ok_h, ovf, st = _lookup_request_reply(
        table, vids, req, vps, capacity, names, schedule, stats)
    return out_h[head_idx], valid & ok_h[head_idx], ovf, st


def _vsorted_lookup(table: jax.Array, vidx: VIndex, valid: jax.Array,
                    vps: int, capacity: int, axes: Tuple[str, ...],
                    schedule: str, stats: ExchangeStats):
    """Coalesced lookup of the v endpoint through the v-sorted index.

    One request per distinct-v run containing a valid slot (the
    run-length win the slot-order v column cannot give); the answers fan
    out per run and back to original slot order through ``vidx.rank``.

    This gathers/scatters through the derived run/rank arrays rather
    than ``vidx.perm`` directly.  (Historical: an early JAX 0.4.x CPU
    backend miscompiled a closed-over ``argsort`` permutation gathered
    inside a ``lax.while_loop`` body; the pinned 0.4.37 no longer
    reproduces it — tests/test_serve_msf.py pins the repro pattern —
    and the run/rank form is kept because it is also what the
    coalesced-reply fan-out needs.)
    """
    names = tuple(axes)
    head, head_idx, run_id = vidx.runs
    L = valid.shape[0]
    run_live = compat.vary(jnp.zeros((L,), bool), names
                           ).at[vidx.rank].max(valid)
    req = head & run_live[run_id]
    out_h, ok_h, ovf, st = _lookup_request_reply(
        table, vidx.key, req, vps, capacity, names, schedule, stats)
    idx = jnp.where(head, run_id, L)  # answers live at run heads
    ra = compat.vary(jnp.full((L + 1,), -1, jnp.int32), names
                     ).at[idx].set(out_h, mode="drop")
    okr = compat.vary(jnp.zeros((L + 1,), bool), names
                      ).at[idx].set(ok_h, mode="drop")
    return (ra[vidx.rank], valid & okr[vidx.rank], ovf, st)


# --------------------------------------------------------------------------
# ghost-vertex label cache (ISSUE 4)
# --------------------------------------------------------------------------

def _ghost_fill(table: jax.Array, vids: jax.Array, runs,
                valid: jax.Array, G: int, vps: int, capacity: int,
                axes: Tuple[str, ...], schedule: str,
                stats: ExchangeStats):
    """Fill one ghost table: one coalesced request per distinct-value
    run with >= 1 valid slot (exactly the miss set — booked under
    ``stats.misses``).  Returns (ghost [G] labels by run rank, overflow,
    stats); unrequested/unanswered entries hold -1 and stay unread.
    """
    names = tuple(axes)
    head, head_idx, run_id = runs
    any_valid = compat.vary(jnp.zeros(valid.shape, bool), names
                            ).at[run_id].max(valid)
    req = head & any_valid[run_id]
    out, ok, ovf, st = _lookup_request_reply(
        table, vids, req, vps, capacity, names, schedule, stats,
        site="fill")
    ghost = compat.vary(jnp.full((G,), -1, jnp.int32), names).at[
        jnp.where(ok, run_id, G)].set(out, mode="drop")
    return ghost, ovf, st


def _bit_or_scatter(mask: jax.Array, idx: jax.Array, bits: jax.Array,
                    ok: jax.Array, p: int,
                    names: Tuple[str, ...]) -> jax.Array:
    """``mask[idx[i]] |= bits[i]`` for ok items (drop row = len(mask)).

    jnp scatters have no bitwise-or mode, so the int32 bitmasks are
    expanded to [*, p] bool, combined with a scatter-max per bit, and
    repacked — p <= MAX_GHOST_SHARDS keeps this tiny.
    """
    L = mask.shape[0]
    lanes = jnp.arange(p, dtype=jnp.int32)
    cur = ((mask[:, None] >> lanes) & 1) > 0
    add = (((bits[:, None] >> lanes) & 1) > 0) & ok[:, None]
    pad = compat.vary(jnp.zeros((1, p), bool), names)
    acc = jnp.concatenate([cur, pad]).at[jnp.where(ok, idx, L)].max(add)
    return jnp.sum(acc[:L].astype(jnp.int32) << lanes, axis=1)


def _ghost_setup(u, v, valid, live, lab, vperm, n: int, vps: int,
                 Gu: int, Gv: int, cap_fill_u: int, cap_fill_v: int,
                 cap_sub: int, axes: Tuple[str, ...], schedule: str,
                 stats: ExchangeStats, grid_push: bool = False):
    """Build the per-shard ghost state: tables + root subscriptions.

    Runs once per solve, after preprocessing.  The two coalesced fills
    (one request per distinct live endpoint) are the only vertex-grained
    lookups the ghost engine ever pays; afterwards each shard sends one
    *root subscription* per distinct cached component root — the owners
    accumulate per-owned-root subscriber bitmasks, which the per-round
    delta push keys on.  Everything is gated on ``live`` (``valid``
    minus the preprocessing dead mask, ignoring any filter window): an
    all-dead run can never be read again — the dead mask only grows —
    so filling or subscribing it would only fatten the push.

    Returns (gstate, vidx, runs_u, overflow, stats) with the uniform
    4-tuple ``gstate = (gu, gv, rs_row, rs_col)``.  In flat-push mode
    ``rs_row`` is the single whole-mesh subscriber bitmask and
    ``rs_col`` stays zeros; in grid mode (ISSUE 10) the subscription
    ships the subscriber's *per-axis* bits and the owner accumulates the
    (row mask, col mask) pair whose outer product the two-hop push
    covers.
    """
    names = tuple(axes)
    big = jnp.int32(n)
    runs_u = run_metadata(u)
    vu = jnp.where(valid, u, big)
    vidx = _build_v_index(v, valid, n, names, perm=vperm)
    gu, o1, st = _ghost_fill(lab, vu, runs_u, live, Gu, vps,
                             cap_fill_u, names, schedule, stats)
    gv, o2, st = _ghost_fill(lab, vidx.key, vidx.runs,
                             live[vidx.perm], Gv, vps,
                             cap_fill_v, names, schedule, st)
    # one subscription per distinct cached root: sort the concatenated
    # cached labels (straight-line argsort — outside any loop, see the
    # loop-closure note on _vsorted_lookup) and send the run heads
    p = 1
    for a in names:
        p *= compat.axis_size(a)
    cat = jnp.concatenate([gu, gv])
    cat = jnp.sort(jnp.where(cat >= 0, cat, ESENT))  # unfilled to the pad
    head = jnp.concatenate([compat.vary(jnp.ones((1,), bool), names),
                            cat[1:] != cat[:-1]])
    req = head & (cat < ESENT)
    items0 = st.items
    zeros = compat.vary(jnp.zeros((vps,), jnp.int32), names)
    base = lax.axis_index(names) * vps
    if grid_push:
        row_ax, col_ax = names
        rowbit = jnp.int32(1) << lax.axis_index(row_ax).astype(jnp.int32)
        colbit = jnp.int32(1) << lax.axis_index(col_ax).astype(jnp.int32)
        ex = routed_exchange((cat, jnp.broadcast_to(rowbit, cat.shape),
                              jnp.broadcast_to(colbit, cat.shape)),
                             cat // vps, req, cap_sub, names, schedule,
                             stats=st, site="subscribe")
        st = ex.stats
        st = st._replace(pushed=st.pushed + (st.items - items0))
        rvid = ex.recv[0].reshape(-1) - base
        okr = ex.recv_ok.reshape(-1)
        R = compat.axis_size(row_ax)
        C = compat.axis_size(col_ax)
        rs_row = _bit_or_scatter(zeros, rvid, ex.recv[1].reshape(-1),
                                 okr, R, names)
        rs_col = _bit_or_scatter(zeros, rvid, ex.recv[2].reshape(-1),
                                 okr, C, names)
    else:
        mybit = jnp.int32(1) << lax.axis_index(names).astype(jnp.int32)
        ex = routed_exchange((cat, jnp.broadcast_to(mybit, cat.shape)),
                             cat // vps, req, cap_sub, names, schedule,
                             stats=st, site="subscribe")
        st = ex.stats
        # subscription maintenance rides the push counter so misses +
        # pushed stays the honest total ghost overhead
        st = st._replace(pushed=st.pushed + (st.items - items0))
        rs_row = _bit_or_scatter(zeros, ex.recv[0].reshape(-1) - base,
                                 ex.recv[1].reshape(-1),
                                 ex.recv_ok.reshape(-1), p, names)
        rs_col = zeros
    return ((gu, gv, rs_row, rs_col), vidx, runs_u,
            o1 + o2 + ex.overflow, st)


def _ghost_push(gstate, parent: jax.Array, vps: int, capacity: int,
                cap_col: int, axes: Tuple[str, ...], schedule: str,
                stats: ExchangeStats, grid_push: bool = False):
    """Root-delta push: invalidate-by-replacement of ghost entries.

    The dirty set is keyed by **component root**, not vertex: a ghost
    entry holds its vertex's current root, and this round's contraction
    rewrote exactly the roots with ``parent[c] != c`` — a set that
    shrinks geometrically with the alive-component count, unlike the
    per-vertex label churn (which stays flat while a giant component
    absorbs the graph).  Each owner multicasts ``(c, parent[c])`` to the
    subscribers of root ``c`` — flat ``scatter_updates``, or the
    two-hop ``scatter_updates_grid`` when ``grid_push`` (the cross
    product of the per-axis masks over-delivers, which is safe exactly
    because receivers rewrite table entries whose *value* is ``c`` via
    one binary search per entry: no entry valued ``c`` → no-op).
    Subscriptions merge along with the components: the owner forwards
    the mask(s) of ``c`` to ``owner(parent[c])``, where they OR into
    the surviving root's mask(s) (``parent`` is fully contracted, so
    forwards always target final roots, never chain).  Overflow follows
    the exchange contract — counted, never silent; a dropped copy would
    leave a stale ghost entry, so results are only trusted at overflow
    0, same as every exchange.
    """
    names = tuple(axes)
    p = 1
    for a in names:
        p *= compat.axis_size(a)
    gu, gv, rs_row, rs_col = gstate
    base = lax.axis_index(names) * vps
    vid = base + jnp.arange(vps, dtype=jnp.int32)
    dirty = (parent != vid) & (rs_row != 0)
    items0 = stats.items
    if grid_push:
        row_ax, col_ax = names
        R = compat.axis_size(row_ax)
        C = compat.axis_size(col_ax)
        upd = scatter_updates_grid((vid, parent), rs_row, rs_col, dirty,
                                   capacity, cap_col, names, stats=stats,
                                   site_row="ghost_push_row",
                                   site_col="ghost_push_col")
        # subscriber masks follow the merge: both axis masks of c move
        # to owner(parent[c]) over the plain routed (request) path
        fx = routed_exchange((parent, rs_row, rs_col), parent // vps,
                             dirty, capacity, names, schedule,
                             stats=upd.stats, site="push")
        st = fx.stats
        st = st._replace(pushed=st.pushed + (st.items - items0))
        rs_row = jnp.where(dirty, 0, rs_row)  # merged c: not a root now
        rs_col = jnp.where(dirty, 0, rs_col)
        fvid = fx.recv[0].reshape(-1) - base
        fok = fx.recv_ok.reshape(-1)
        rs_row = _bit_or_scatter(rs_row, fvid, fx.recv[1].reshape(-1),
                                 fok, R, names)
        rs_col = _bit_or_scatter(rs_col, fvid, fx.recv[2].reshape(-1),
                                 fok, C, names)
    else:
        upd = scatter_updates((vid, parent), rs_row, dirty, capacity,
                              names, schedule, stats=stats, site="push")
        fx = routed_exchange((parent, rs_row), parent // vps, dirty,
                             capacity, names, schedule, stats=upd.stats,
                             site="push")
        st = fx.stats
        st = st._replace(pushed=st.pushed + (st.items - items0))
        rs_row = jnp.where(dirty, 0, rs_row)  # merged c: not a root now
        rs_row = _bit_or_scatter(rs_row,
                                 fx.recv[0].reshape(-1) - base,
                                 fx.recv[1].reshape(-1),
                                 fx.recv_ok.reshape(-1), p, names)
    # apply the received (old root -> new root) pairs by value
    okp = upd.recv_ok.reshape(-1)
    rold = jnp.where(okp, upd.recv[0].reshape(-1), ESENT)
    rnew = upd.recv[1].reshape(-1)
    order = jnp.argsort(rold)  # in-body argsort: loop-safe
    sc = rold[order]
    sr = rnew[order]
    M = sc.shape[0]

    def apply(gt):
        j = jnp.clip(jnp.searchsorted(sc, gt), 0, M - 1)
        hit = sc[j] == gt  # unfilled entries are -1: never match
        return jnp.where(hit, sr[j], gt)

    return ((apply(gu), apply(gv), rs_row, rs_col),
            upd.overflow + fx.overflow, st)


def _relabel_lookup(parent: jax.Array, has: jax.Array, lab: jax.Array,
                    settled: jax.Array, vps: int, capacity: int,
                    axes: Tuple[str, ...], schedule: str,
                    stats: ExchangeStats):
    """RELABEL with the settled-vertex skip (ISSUE 4 satellite).

    Unsettled owned vertices ask ``owner(lab[x])`` for the contracted
    parent *and* whether that component chose an edge this round.  A
    component that chose nothing has no alive incident edge, so no
    neighbour can ever merge into it either (it would have received that
    candidate) — its members' labels are final for the level and stop
    requesting, which is what lets the shrinking driver drop the RELABEL
    capacity below vps (the dense analogue of CONTRACT's self-parent
    filter).  Returns (lab, settled, overflow, stats).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    req = ~settled
    ex = routed_exchange(lab, lab // vps, req, capacity, names, schedule,
                         stats=stats, site="relabel")
    off = jnp.clip(ex.recv - base, 0, vps - 1)
    ans_lab = jnp.where(ex.recv_ok, parent[off], jnp.int32(-1))
    ans_cho = jnp.where(ex.recv_ok, has[off], False)
    (out_lab, out_cho), st = reply(ex, (ans_lab, ans_cho), names,
                                   schedule, stats=ex.stats)
    okr = req & ex.sent_ok
    lab = jnp.where(okr, out_lab, lab)
    settled = settled | (okr & ~out_cho)
    return lab, settled, ex.overflow, st


def _sharded_preprocess(u, v, w, eid, valid, n: int, vps: int,
                        capacity: int, axes: Tuple[str, ...],
                        schedule: str, stats: ExchangeStats):
    """Sharded LOCALPREPROCESSING (Section IV-A) with O(edges/shard) peak.

    PR 2's version ran the replicated engine's dense contraction core
    and scattered the changed labels to the owners — correct, but its
    transient [n] scratch (per-shard label / min-reduction vectors and
    an L = n routed exchange) made preprocessing the one phase whose
    *peak* memory was O(n) per device.  This version contracts in the
    shard's **bucketed vertex space** instead: the distinct source ids
    of its (lexicographically sorted) edge slice, indexed by run rank —
    at most cap = edges/shard of them.  Every endpoint of a
    provably-local edge appears as a source on this shard (the doubled
    representation guarantees the reverse copy, and a source run that
    straddles a shard boundary makes its vertex shared, hence
    non-local), so run ranks cover every vertex the contraction may
    touch and all scratch is [cap + 1]-sized, never [n].

    The contraction itself is the Section IV-A discipline of
    ``_local_preprocessing_core`` transplanted into rank space: shared
    boundary vertices stay roots, a component contracts only if its
    global (w, eid)-minimum edge is provably local, ties break on the
    global undirected eid, so the contracted edges are a subset of the
    unique MSF and the final edge set stays bit-identical to the
    Kruskal oracle.

    Returns (lab [vps], pre_mst [cap] bool, dead0 [cap] bool, overflow,
    stats).  The owner scatter ships one (vid, root) pair per *changed
    distinct vertex* (L = cap, down from the old L = n): an owner owns
    ``vps`` vertices and a shard has at most cap distinct sources, so
    the effective ``min(capacity, cap)`` stays overflow-free by
    construction for the default ``label_capacity``.
    """
    names = tuple(axes)
    cap = u.shape[0]
    big = jnp.int32(n)  # > every vertex id; doubles as "no vertex"

    # --- shard boundary structure (tiny [p] all_gathers, no [n] mask) --
    cnt = jnp.sum(valid.astype(jnp.int32))
    has_edges = cnt > 0
    first = jnp.where(has_edges, u[0], -1)
    last = jnp.where(has_edges, u[jnp.clip(cnt - 1, 0, cap - 1)], -2)
    firsts = lax.all_gather(first, names, tiled=False).reshape(-1)
    lasts = lax.all_gather(last, names, tiled=False).reshape(-1)
    p = firsts.shape[0]
    k = max(p - 1, 1)
    if p > 1:
        shared = (lasts[:-1] == firsts[1:]) & (lasts[:-1] >= 0)
        sh_ids = jnp.sort(jnp.where(shared, lasts[:-1].astype(jnp.int32),
                                    big))
    else:
        sh_ids = compat.vary(jnp.full((k,), big), names)

    def is_shared(x):
        j = jnp.clip(jnp.searchsorted(sh_ids, x), 0, k - 1)
        return sh_ids[j] == x

    # --- bucketed local vertex space: distinct sources by run rank -----
    vu = jnp.where(valid, u, big)  # valid slots are a sorted prefix
    head = jnp.concatenate([compat.vary(jnp.ones((1,), bool), names),
                            vu[1:] != vu[:-1]])
    du = jnp.cumsum(head.astype(jnp.int32)) - 1          # [cap] slot -> rank
    uvals = compat.vary(jnp.full((cap,), big), names).at[du].set(vu)
    dv = jnp.clip(jnp.searchsorted(uvals, v), 0, cap - 1)
    v_found = (uvals[dv] == v) & valid
    shared_rank = is_shared(uvals)
    local_edge = valid & v_found & ~is_shared(u) & ~is_shared(v)

    iota = jnp.arange(cap, dtype=jnp.int32)
    sent = jnp.int32(cap)  # drop row of the [cap + 1] scatter arrays
    nloc = max(min(n, cap), 2)  # distinct local vertices <= min(n, cap)

    def round_(state):
        lab, mst, _, r = state
        ru = lab[du]
        rvx = jnp.where(v_found, lab[dv], sent)
        same = v_found & (lab[du] == lab[dv])
        alive = valid & ~same
        wk = jnp.where(alive, w, jnp.inf)
        wmin = jnp.full((cap + 1,), jnp.inf, w.dtype
                        ).at[ru].min(wk).at[rvx].min(wk)
        # tie-break by the *global undirected* eid (not the local slot or
        # rank) so the contracted edges are a subset of the unique
        # (w, eid) MSF — the same total order every engine uses
        at_min_u = jnp.isfinite(wk) & (wk == wmin[ru])
        at_min_v = jnp.isfinite(wk) & (wk == wmin[rvx])
        eminid = jnp.full((cap + 1,), ESENT, jnp.int32)
        eminid = eminid.at[ru].min(jnp.where(at_min_u, eid, ESENT))
        eminid = eminid.at[rvx].min(jnp.where(at_min_v, eid, ESENT))
        cu = jnp.where(at_min_u & (eid == eminid[ru]), iota, sent)
        cv = jnp.where(at_min_v & (eid == eminid[rvx]), iota, sent)
        emin = jnp.full((cap + 1,), sent, jnp.int32
                        ).at[ru].min(cu).at[rvx].min(cv)
        has = emin[:cap] < sent
        ce = jnp.clip(emin[:cap], 0, cap - 1)
        # contract only if the component's global-min edge is local
        eligible = has & local_edge[ce] & ~shared_rank
        emin_m = jnp.where(eligible, emin[:cap], sent)
        ce = jnp.clip(emin_m, 0, cap - 1)
        cru = lab[du[ce]]
        crv = lab[dv[ce]]
        other = cru + crv - iota
        parent = jnp.where(eligible, other, iota)
        gp = parent[parent]
        parent = jnp.where((gp == iota) & (iota < parent), iota, parent)
        roots = lax.fori_loop(0, _doubling_iters(nloc),
                              lambda _, p_: p_[p_], parent)
        mst = mst.at[ce].max(eligible.astype(jnp.int32))
        lab = roots[lab]
        return lab, mst, jnp.any(eligible), r + 1

    max_rounds = _doubling_iters(nloc) + 1

    def cond(state):
        return state[2] & (state[3] < max_rounds)

    lab0 = compat.vary(iota, names)
    mst0 = compat.vary(jnp.zeros((cap,), jnp.int32), names)
    lab, mst, _, _ = lax.while_loop(
        cond, round_,
        (lab0, mst0, compat.vary(jnp.array(True), names), jnp.int32(0)))

    # --- one routed (vid, root) scatter to the owners ------------------
    groot = uvals[lab]                 # [rank] -> global root vid
    root_slot = groot[du]              # [cap] per-slot root of its source
    changed = head & valid & (root_slot != u)
    ex = routed_exchange((u, root_slot), u // vps, changed,
                         min(capacity, cap), names, schedule, stats=stats,
                         site="prep")
    base = lax.axis_index(names) * vps
    vid = base + jnp.arange(vps, dtype=jnp.int32)
    rvid = ex.recv[0].reshape(-1)
    rlab = ex.recv[1].reshape(-1)
    ok = ex.recv_ok.reshape(-1)
    off = jnp.where(ok, rvid - base, vps)  # vps = drop row
    lab_out = jnp.concatenate([vid, jnp.full((1,), -1, jnp.int32)]
                              ).at[off].set(rlab)[:vps]
    same = v_found & (lab[du] == lab[dv])
    dead0 = (u == v) | same  # locally-internal edges incl. self-loops
    return lab_out, mst.astype(bool), dead0, ex.overflow, ex.stats


def _owner_scatter_min(comp, wc, ec, oc, okc, base, vps: int,
                       use_pallas: bool = False,
                       names: Tuple[str, ...] = ()):
    """Owner-side (w, eid)-ordered scatter-min over owned component slots.

    Shared by both MINEDGES variants so the tie-break discipline cannot
    diverge between them.  ``comp/wc/ec/oc/okc`` are the flat received
    candidates; slot ``vps`` is the drop row for unused buffer entries.
    Returns (has [vps], other [vps], is_win [flat], off [flat]).

    ``use_pallas=True`` (the ``pallas_minedges`` lever, ISSUE 8) routes
    the table build through the fused ``owner_scatter_min`` kernel —
    one grid sweep producing (wmin, emin, other) per owned slot with
    the identical lexicographic order, no ``[vps+1]`` scatter
    intermediates — and keeps only the O(flat) winner-confirmation
    gathers in jnp.  Both branches return bit-identical values (the
    property wall of tests/test_kernels_fuzz.py pins this).
    """
    off = jnp.where(okc, comp - base, vps)
    if use_pallas:
        # garbage buffer rows may hold out-of-range comps: clamp to a
        # real row, the kernel's ok mask drops them before they touch it
        idx = jnp.where(okc, comp - base, 0)
        wt, et, pt, _ = owner_scatter_min(idx, wc, ec, oc, oc, okc, vps)
        wt = compat.vary(wt, names)
        et = compat.vary(et, names)
        pt = compat.vary(pt, names)
        wmin = jnp.concatenate([wt.astype(wc.dtype),
                                jnp.full((1,), jnp.inf, wc.dtype)])
        emin = jnp.concatenate([et, jnp.full((1,), ESENT, jnp.int32)])
        at_min = okc & (wc == wmin[off])
        is_win = at_min & (ec == emin[off])
        return et < ESENT, pt, is_win, off
    wmin = jnp.full((vps + 1,), jnp.inf, wc.dtype).at[off].min(
        jnp.where(okc, wc, jnp.inf))
    at_min = okc & (wc == wmin[off])
    emin = jnp.full((vps + 1,), ESENT, jnp.int32).at[off].min(
        jnp.where(at_min, ec, ESENT))
    is_win = at_min & (ec == emin[off])
    other = jnp.full((vps + 1,), -1, jnp.int32).at[off].max(
        jnp.where(is_win, oc, -1))
    has = emin[:vps] < ESENT
    return has, other[:vps], is_win, off


def _sharded_minedges(ru, rv, wk, eid, alive, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str,
                      stats: ExchangeStats, use_pallas: bool = False):
    """Owner-computes MINEDGES, 2-exchange variant (the PR 1 baseline).

    Each *directed* edge copy ships a ``(comp, w, eid, other)`` candidate
    to the owner of both its source component (keyed ``ru``) and its
    destination component (keyed ``rv``): together they hand every owner
    all edges incident to its components.  The owner scatter-mins with
    the (w, eid) order over its [vps] slots and confirms winners back to
    the submitting slot, so the caller can mark the canonical copy.

    Returns (has [vps], other [vps], win [L], overflow, stats).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    ex_u = routed_exchange((ru, wk, eid, rv), ru // vps, alive, capacity,
                           names, schedule, stats=stats, site="minedges")
    ex_v = routed_exchange((rv, wk, eid, ru), rv // vps, alive, capacity,
                           names, schedule, stats=ex_u.stats,
                           site="minedges")

    def flat(ex):
        comp, w_, e_, o_ = ex.recv
        return (comp.reshape(-1), w_.reshape(-1), e_.reshape(-1),
                o_.reshape(-1), ex.recv_ok.reshape(-1))

    ku, wu, eu, ou, oku = flat(ex_u)
    kv, wv, ev, ov, okv = flat(ex_v)
    comp = jnp.concatenate([ku, kv])
    wc = jnp.concatenate([wu, wv])
    ec = jnp.concatenate([eu, ev])
    oc = jnp.concatenate([ou, ov])
    okc = jnp.concatenate([oku, okv])
    has, other, is_win, _ = _owner_scatter_min(comp, wc, ec, oc, okc,
                                               base, vps, use_pallas,
                                               names)
    # confirm winners to the submitting slots (both exchanges carry the
    # same (w, eid) for the two copies of an undirected edge, so a slot
    # wins iff either of its endpoint components chose it)
    nu = ku.shape[0]
    win_u, st = reply(ex_u, is_win[:nu].reshape(ex_u.recv_ok.shape), names,
                      schedule, stats=ex_v.stats)
    win_v, st = reply(ex_v, is_win[nu:].reshape(ex_v.recv_ok.shape), names,
                      schedule, stats=st)
    win = (win_u & ex_u.sent_ok) | (win_v & ex_v.sent_ok)
    return has, other, win, ex_u.overflow + ex_v.overflow, st


def _sharded_minedges_src(ru, rv, wk, eid, alive, runs, vps: int,
                          capacity: int, axes: Tuple[str, ...],
                          schedule: str, stats: ExchangeStats,
                          use_pallas: bool = False):
    """Owner-computes MINEDGES, src-only variant (ISSUE 2 lever 3 +
    ISSUE 3 per-run candidate aggregation).

    Both directed copies of every edge are present, so the owner of
    component ``c`` already receives every edge incident to ``c``
    through the ``ru``-keyed exchange alone (the invariant
    ``boruvka_shrink_srconly`` exploits in the replicated engine): the
    ``rv``-keyed exchange is dropped, halving MINEDGES to 1 routed
    exchange + 1 confirmation.

    Candidates are additionally **pre-aggregated per source run** (the
    classic combiner): the edge array is sorted by source, every slot of
    a contiguous equal-``u`` run shares its source component, and the
    owner's scatter-min only needs each run's local (w, eid)-argmin —
    min-of-mins is exact and the tie order is unchanged, so the chosen
    edge set is bit-identical.  One candidate per *alive run* instead of
    one per alive slot divides the exchange volume by the average run
    length and — decisive for the shrinking capacity schedule — makes
    the host's exact per-(shard, owner) candidate bound decay with the
    alive-run count rather than the raw alive-edge count
    (``_minedges_capacity_bound``).

    The confirmation is deferred — the caller replies through the
    returned ``ex`` once the contraction's first lookup has revealed
    which winners are the larger side of a 2-cycle (see module
    docstring: exact-once marking), then fans the per-run confirmation
    back onto the run's argmin slot via ``loc_win``/``head_idx``.

    Returns (has [vps], other [vps], is_win [p*C] flat, off [p*C] flat
    owner slot per candidate, ex, loc_win [L] — the run's argmin slot,
    head_idx [L] — each slot's run head).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    head, head_idx, run_id = runs
    L = ru.shape[0]
    if use_pallas:
        # fused combine (ISSUE 8): one kernel sweep yields the per-run
        # (min w, argmin eid) plus both payload channels — the chosen
        # other-endpoint component (max rv over the run's argmin slots)
        # and the run's own component (ru is constant within an equal-u
        # run, so max-over-alive == ru-at-winner) — without the five
        # scatter intermediates.  Dead runs come back (inf, ESENT, -1,
        # -1) in both paths, and alive => finite wk, so run-aliveness
        # is exactly isfinite(wtbl).
        wtbl, etbl, otbl, ctbl = owner_scatter_min(
            run_id, wk, eid, rv, ru, alive, L)
        wtbl = compat.vary(wtbl.astype(wk.dtype), names)
        etbl = compat.vary(etbl, names)
        otbl = compat.vary(otbl, names)
        ctbl = compat.vary(ctbl, names)
        at_min = alive & (wk == wtbl[run_id])
        loc_win = at_min & (eid == etbl[run_id])
        send = head & jnp.isfinite(wtbl)[run_id]
        comp_c = ctbl[run_id]
        payload = (comp_c, wtbl[run_id], etbl[run_id], otbl[run_id])
    else:
        # per-run segmented (w, eid) argmin over alive slots (O(cap)
        # scratch)
        wrun = compat.vary(jnp.full((L,), jnp.inf, wk.dtype), names
                           ).at[run_id].min(wk)
        at_min = alive & (wk == wrun[run_id])
        erun = compat.vary(jnp.full((L,), ESENT, jnp.int32), names
                           ).at[run_id].min(jnp.where(at_min, eid, ESENT))
        loc_win = at_min & (eid == erun[run_id])
        orun = compat.vary(jnp.full((L,), -1, jnp.int32), names
                           ).at[run_id].max(jnp.where(loc_win, rv, -1))
        crun = compat.vary(jnp.full((L,), -1, jnp.int32), names
                           ).at[run_id].max(jnp.where(alive, ru, -1))
        anyrun = compat.vary(jnp.zeros((L,), bool), names
                             ).at[run_id].max(alive)
        send = head & anyrun[run_id]
        comp_c = crun[run_id]
        payload = (comp_c, wrun[run_id], erun[run_id], orun[run_id])
    ex = routed_exchange(payload, comp_c // vps, send, capacity,
                         names, schedule, stats=stats, site="minedges")
    comp, w_, e_, o_ = (x.reshape(-1) for x in ex.recv)
    okc = ex.recv_ok.reshape(-1)
    has, other, is_win, off = _owner_scatter_min(comp, w_, e_, o_, okc,
                                                 base, vps, use_pallas,
                                                 names)
    return has, other, is_win, off, ex, loc_win, head_idx


def _sharded_contract(has, other, n: int, vps: int, capacity: int,
                      axes: Tuple[str, ...], schedule: str,
                      adaptive: bool, stats: ExchangeStats):
    """Pointer doubling over the sharded parent array (request/reply).

    Every owned slot is a potential component root: roots with a chosen
    edge point at the other endpoint's component, everything else at
    itself.  The 2-cycle of mutually chosen components keeps the smaller
    id as root; then doubling rounds of one routed lookup each — a fixed
    log2(n) schedule, or (``adaptive``) a while_loop that stops one step
    after a psum reports no parent changed, which post round 1 cuts the
    schedule to the actual tree depth.  The iteration cap stays at
    log2(n) either way, so undersized capacities (garbage answers) can
    not loop forever.

    Self-parents answer locally: only ``parent[x] != x`` rows enter the
    exchange (a root's grandparent is itself), and the requesting set
    only shrinks as doubling converges.  That is what lets the shrinking
    capacity driver bound ``capacity`` by the per-owner alive-component
    count instead of the flat vps — only components with a chosen edge
    ever have a non-self parent.

    Returns (parent [vps] fully contracted, keep [vps] — exact-once
    owner-side marking decision for src-only MINEDGES (winner and not
    the larger side of a 2-cycle), overflow, stats).
    """
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    vid = base + jnp.arange(vps, dtype=jnp.int32)
    parent0 = jnp.where(has, other, vid)

    def hop(par, st):
        req = par != vid
        nxt, _, o, st = _sharded_lookup(par, par, req, vps, capacity,
                                        names, schedule, stats=st,
                                        site="contract")
        return jnp.where(req, nxt, par), o, st

    gp, ov0, stats = hop(parent0, stats)
    # a 2-cycle (mutually chosen components) necessarily chose the SAME
    # edge — each side's minimum bounds the other's — so `keep` marks
    # every winning (component, edge) pair on exactly one owner
    mutual = gp == vid
    keep = has & (~mutual | (vid < parent0))
    parent = jnp.where(mutual & (vid < parent0), vid, parent0)
    iters = _doubling_iters(n)

    if adaptive:
        def dbl_a(carry):
            par, ov, st, i, _ = carry
            nxt, o, st = hop(par, st)
            chg = lax.psum(jnp.sum((nxt != par).astype(jnp.int32)),
                           names) > 0
            return nxt, ov + o, st, i + 1, chg

        def cond(carry):
            return carry[4] & (carry[3] < iters)

        parent, ov, stats, _, _ = lax.while_loop(
            cond, dbl_a,
            (parent, ov0, stats, jnp.int32(0), jnp.array(True)))
    else:
        def dbl(_, carry):
            par, ov, st = carry
            nxt, o, st = hop(par, st)
            return nxt, ov + o, st

        parent, ov, stats = lax.fori_loop(0, iters, dbl,
                                          (parent, ov0, stats))
    return parent, keep, ov, stats


def _round_body(u, v, w, eid, live0, lab, mst, dead, runs_u, runs_v,
                vidx, gstate, settled, n: int, vps: int,
                names: Tuple[str, ...], cap_edge: int, cap_label: int,
                cap_lookup: int, cap_contract: int, cap_push: int,
                cap_push_col: int, schedule: str, coalesce: bool,
                src_only: bool, adaptive: bool, ghost: bool,
                relabel_skip: bool, pallas_minedges: bool,
                grid_push: bool, stats: ExchangeStats):
    """One MINEDGES → CONTRACT → RELABEL round over 1D-sharded labels.

    Shared verbatim by the fused while_loop engine (flat capacities,
    AOT-lowerable) and the host-orchestrated shrinking-capacity driver,
    so the two execution modes cannot diverge semantically — they only
    differ in the static capacities each round is compiled with.
    ``cap_contract`` bounds the doubling lookups; the flat path passes
    ``cap_label`` (vps) for it, the shrinking driver the per-owner
    alive-component bound.

    Endpoint resolution picks one of four paths (same values, different
    routed volume): ``ghost`` reads both labels from the local ghost
    tables (cache hits; coherence maintained by the end-of-round dirty
    push); ``coalesce`` sends one request per equal-vid run — the u
    column in slot order, the v column through the v-sorted index
    (``vidx``) or, when only ``runs_v`` is given, in slot order (the
    PR 3 path, kept reproducible as the ``vsorted_index=False``
    comparator); the fallback (all None) requests per slot.

    Returns (lab, mst, dead, gstate, settled, go, overflow_delta, stats).
    """
    live = live0 & ~dead
    if ghost:
        gu, gv = gstate[0], gstate[1]
        head_u, _, run_id_u = runs_u
        head_v, _, run_id_v = vidx.runs
        au = compat.vary(jnp.zeros(live.shape, bool), names
                         ).at[run_id_u].max(live)
        # rank-keyed (never perm-keyed: see _vsorted_lookup) run-liveness
        av = compat.vary(jnp.zeros(live.shape, bool), names
                         ).at[vidx.rank].max(live)
        hits = lax.psum(
            jnp.sum((head_u & au[run_id_u]).astype(jnp.float32))
            + jnp.sum((head_v & av[run_id_v]).astype(jnp.float32)), names)
        st = stats._replace(hits=stats.hits + hits)
        ru = gu[jnp.clip(run_id_u, 0, gu.shape[0] - 1)]
        rv = gv[jnp.clip(vidx.rank, 0, gv.shape[0] - 1)]
        looked = live
        o1 = o2 = jnp.int32(0)
    else:
        # dispatch here, not inside _coalesced_lookup: exactly one of
        # the two paths runs per endpoint, each booking its own slots
        # once (runs_u may exist for src_only even when coalesce is off)
        if coalesce and runs_u is not None:
            ru, ok_u, o1, st = _coalesced_lookup(
                lab, u, runs_u, live, vps, cap_lookup, names, schedule,
                stats)
        else:
            ru, ok_u, o1, st = _sharded_lookup(
                lab, u, live, vps, cap_lookup, names, schedule,
                stats=stats, count_misses=True)
        if coalesce and vidx is not None:
            rv, ok_v, o2, st = _vsorted_lookup(
                lab, vidx, live, vps, cap_lookup, names, schedule, st)
        elif coalesce and runs_v is not None:
            rv, ok_v, o2, st = _coalesced_lookup(
                lab, v, runs_v, live, vps, cap_lookup, names, schedule,
                st)
        else:
            rv, ok_v, o2, st = _sharded_lookup(
                lab, v, live, vps, cap_lookup, names, schedule,
                stats=st, count_misses=True)
        looked = ok_u & ok_v
    # dead-edge retirement: same component now => same forever
    dead = dead | (looked & (ru == rv))
    alive = looked & (ru != rv) & live
    wk = jnp.where(alive, w, jnp.inf)
    if src_only:
        has, other, is_win, off, ex, loc_win, head_idx = \
            _sharded_minedges_src(ru, rv, wk, eid, alive, runs_u, vps,
                                  cap_edge, names, schedule, st,
                                  pallas_minedges)
        parent, keep, o4, st = _sharded_contract(
            has, other, n, vps, cap_contract, names, schedule, adaptive,
            ex.stats)
        keep_ext = jnp.concatenate([keep, jnp.zeros((1,), bool)])
        confirm = (is_win & keep_ext[off]).reshape(ex.recv_ok.shape)
        win, st = reply(ex, confirm, names, schedule, stats=st)
        # per-run confirmation fans back onto the run's argmin slot;
        # owner-side dedup => exactly one directed slot per MSF edge
        mst = mst | (loc_win & (win & ex.sent_ok)[head_idx])
        o3 = ex.overflow
    else:
        has, other, win, o3, st = _sharded_minedges(
            ru, rv, wk, eid, alive, vps, cap_edge, names, schedule, st,
            pallas_minedges)
        # both directed copies are confirmed; mark only the canonical
        # one so the global mask is exact-once
        mst = mst | (win & (u < v))
        parent, _, o4, st = _sharded_contract(
            has, other, n, vps, cap_contract, names, schedule, adaptive,
            st)
    if relabel_skip:
        lab, settled, o5, st = _relabel_lookup(
            parent, has, lab, settled, vps, cap_label, names, schedule,
            st)
    else:
        lab, _, o5, st = _sharded_lookup(
            parent, lab, compat.vary(jnp.ones((vps,), bool), names), vps,
            cap_label, names, schedule, stats=st, site="relabel")
    o6 = jnp.int32(0)
    if ghost:
        gstate, o6, st = _ghost_push(gstate, parent, vps, cap_push,
                                     cap_push_col, names, schedule, st,
                                     grid_push)
    go = lax.psum(jnp.sum(has.astype(jnp.int32)), names) > 0
    return (lab, mst, dead, gstate, settled, go,
            o1 + o2 + o3 + o4 + o5 + o6, st)


def _sharded_rounds(u, v, w, eid, valid, lab, mst, dead, gstate, vidx,
                    runs_u, runs_v, n: int, vps: int,
                    axes: Tuple[str, ...], active: Optional[jax.Array],
                    max_rounds: int, cap_edge: int, cap_label: int,
                    cap_lookup: int, cap_push: int, cap_push_col: int,
                    overflow, stats: ExchangeStats, rounds,
                    schedule: str, coalesce: bool, src_only: bool,
                    adaptive: bool, ghost: bool, relabel_skip: bool,
                    pallas_minedges: bool, grid_push: bool):
    """Borůvka rounds with 1D-sharded labels (fused while_loop, flat caps).

    ``active`` optionally restricts the edge set (the filter levels);
    ``dead`` persists across rounds AND levels (once ``ru == rv`` a slot
    is dead forever — labels only coarsen), and so does the ghost state
    — the tables track the *total* label vector, so filter levels reuse
    them.  ``settled`` is per-level: a new weight window revives edges,
    so a component that chose nothing last level may choose again.  The
    loop carry is (lab [vps], mst [cap], dead [cap], gu, gv, rs_row,
    rs_col, settled [vps], go, round, overflow, stats).
    """
    names = tuple(axes)
    live0 = valid if active is None else (valid & active)
    settled0 = compat.vary(jnp.zeros((vps,), bool), names)
    if ghost:
        gu0, gv0, rs0, rsc0 = gstate
    else:
        # 1-element placeholders keep one carry structure for both modes
        gu0 = gv0 = rs0 = rsc0 = compat.vary(
            jnp.zeros((1,), jnp.int32), names)

    def round_(state):
        (lab, mst, dead, gu, gv, rsubs, rsubc, settled, _, r, ovf,
         st) = state
        gs = (gu, gv, rsubs, rsubc) if ghost else None
        lab, mst, dead, gs, settled, go, o, st = _round_body(
            u, v, w, eid, live0, lab, mst, dead, runs_u, runs_v, vidx,
            gs, settled, n, vps, names, cap_edge, cap_label, cap_lookup,
            cap_label, cap_push, cap_push_col, schedule, coalesce,
            src_only, adaptive, ghost, relabel_skip, pallas_minedges,
            grid_push, st)
        if ghost:
            gu, gv, rsubs, rsubc = gs
        return (lab, mst, dead, gu, gv, rsubs, rsubc, settled, go,
                r + 1, ovf + o, st)

    def cond(state):
        return state[8] & (state[9] < max_rounds)

    (lab, mst, dead, gu, gv, rsubs, rsubc, _, _, r, overflow,
     stats) = lax.while_loop(
        cond, round_,
        (lab, mst, dead, gu0, gv0, rs0, rsc0, settled0, jnp.array(True),
         jnp.int32(0), overflow, stats))
    if ghost:
        gstate = (gu, gv, rsubs, rsubc)
    return lab, mst, dead, gstate, overflow, stats, rounds + r


# --------------------------------------------------------------------------
# the full per-shard program + host wrapper
# --------------------------------------------------------------------------

def _sharded_shard_fn(u, v, w, eid, n: int, vps: int,
                      axes: Tuple[str, ...], algorithm: str,
                      num_levels: int, max_rounds: Optional[int],
                      cap_edge: int, cap_label: int, cap_lookup: int,
                      cap_push: int, cap_push_col: int, schedule: str,
                      local_preprocessing: bool, coalesce: bool,
                      src_only: bool, adaptive: bool, ghost: bool,
                      relabel_skip: bool, vsorted: bool,
                      pallas_minedges: bool, grid_push: bool):
    names = tuple(axes)
    valid = jnp.isfinite(w)
    base = lax.axis_index(names) * vps
    lab = base + jnp.arange(vps, dtype=jnp.int32)
    mst = compat.vary(jnp.zeros(u.shape, bool), names)
    # psum outputs are axis-invariant, so the overflow accumulator, the
    # comm counters and the loop's ``go`` flag stay unvarying on both
    # JAX generations
    overflow = jnp.int32(0)
    stats = ExchangeStats.zeros()
    rounds = jnp.int32(0)
    mr = (math.ceil(math.log2(max(n, 2))) + 1) if max_rounds is None \
        else max_rounds

    if local_preprocessing:
        lab, pre_mst, dead, ovf, stats = _sharded_preprocess(
            u, v, w, eid, valid, n, vps, cap_label, names, schedule, stats)
        overflow += ovf
    else:
        pre_mst = compat.vary(jnp.zeros(u.shape, bool), names)
        dead = u == v  # self-loops can never be MSF candidates

    cap = u.shape[0]
    runs_v = None
    if ghost:
        # fused path: ghost tables sized at the safe static bound (one
        # entry per slot); the shrinking driver sizes them host-exactly
        gstate, vidx, runs_u, ovf, stats = _ghost_setup(
            u, v, valid, valid & ~dead, lab, None, n, vps, cap, cap,
            cap_lookup, cap_lookup, cap_label, names, schedule, stats,
            grid_push)
        overflow += ovf
    else:
        gstate = None
        runs_u = run_metadata(u) if (coalesce or src_only) else None
        vidx = _build_v_index(v, valid, n, names) \
            if (coalesce and vsorted) else None
        runs_v = run_metadata(v) if (coalesce and not vsorted) else None

    common = dict(n=n, vps=vps, axes=names, max_rounds=mr,
                  cap_edge=cap_edge, cap_label=cap_label,
                  cap_lookup=cap_lookup, cap_push=cap_push,
                  cap_push_col=cap_push_col,
                  schedule=schedule, coalesce=coalesce, src_only=src_only,
                  adaptive=adaptive, ghost=ghost,
                  relabel_skip=relabel_skip,
                  pallas_minedges=pallas_minedges, grid_push=grid_push)
    if algorithm == "boruvka":
        lab, mst, dead, gstate, overflow, stats, rounds = _sharded_rounds(
            u, v, w, eid, valid, lab, mst, dead, gstate, vidx, runs_u,
            runs_v, active=None, overflow=overflow, stats=stats,
            rounds=rounds, **common)
    elif algorithm == "filter_boruvka":
        pivots = _weight_pivots(w, valid, num_levels, names)
        lo = jnp.float32(-jnp.inf)
        for lvl in range(num_levels):
            hi = pivots[lvl] if lvl < num_levels - 1 else jnp.float32(jnp.inf)
            active = (w > lo) & (w <= hi)
            lab, mst, dead, gstate, overflow, stats, rounds = \
                _sharded_rounds(
                    u, v, w, eid, valid, lab, mst, dead, gstate, vidx,
                    runs_u, runs_v, active=active, overflow=overflow,
                    stats=stats, rounds=rounds, **common)
            lo = hi
    else:
        raise ValueError(algorithm)

    full_mask = mst | pre_mst
    weight = lax.psum(jnp.sum(jnp.where(full_mask, w, 0.0)), names)
    count = lax.psum(jnp.sum(full_mask.astype(jnp.int32)), names)
    comm = CommStats(stats.calls, stats.items, stats.bytes, rounds,
                     stats.hits, stats.misses, stats.pushed,
                     stats.injected)
    return full_mask, weight, count, lab, overflow, comm


@functools.lru_cache(maxsize=64)
def _build_sharded_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                      axes: Tuple[str, ...], algorithm: str,
                      num_levels: int, max_rounds: Optional[int],
                      cap_edge: int, cap_label: int, cap_lookup: int,
                      cap_push: int, cap_push_col: int, schedule: str,
                      local_preprocessing: bool, coalesce: bool,
                      src_only: bool, adaptive: bool, ghost: bool,
                      relabel_skip: bool, vsorted: bool,
                      pallas_minedges: bool, grid_push: bool):
    fn = partial(_sharded_shard_fn, n=n, vps=vps, axes=axes,
                 algorithm=algorithm, num_levels=num_levels,
                 max_rounds=max_rounds, cap_edge=cap_edge,
                 cap_label=cap_label, cap_lookup=cap_lookup,
                 cap_push=cap_push, cap_push_col=cap_push_col,
                 schedule=schedule,
                 local_preprocessing=local_preprocessing,
                 coalesce=coalesce, src_only=src_only, adaptive=adaptive,
                 ghost=ghost, relabel_skip=relabel_skip, vsorted=vsorted,
                 pallas_minedges=pallas_minedges, grid_push=grid_push)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, P(), P(), spec, P(), P())))


# --------------------------------------------------------------------------
# shrinking-capacity driver: one jitted step per round, host-bounded caps
# --------------------------------------------------------------------------

_STAT_FIELDS = 8  # calls/items/bytes/slots, hits/misses/pushed, injected


def _stat_leaves(st: ExchangeStats):
    return (st.calls, st.items, st.bytes, st.slots, st.hits, st.misses,
            st.pushed, st.injected)


def _sharded_prep_shard_fn(u, v, w, eid, n: int, vps: int,
                           axes: Tuple[str, ...], cap_label: int,
                           schedule: str):
    valid = jnp.isfinite(w)
    lab, pre_mst, dead0, ovf, st = _sharded_preprocess(
        u, v, w, eid, valid, n, vps, cap_label, tuple(axes), schedule,
        ExchangeStats.zeros())
    return (lab, pre_mst, dead0, ovf) + _stat_leaves(st)


@functools.lru_cache(maxsize=64)
def _build_sharded_prep_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                           axes: Tuple[str, ...], cap_label: int,
                           schedule: str):
    fn = partial(_sharded_prep_shard_fn, n=n, vps=vps, axes=axes,
                 cap_label=cap_label, schedule=schedule)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(spec, spec, spec, spec),
        out_specs=(spec, spec, spec) + (P(),) * (1 + _STAT_FIELDS)))


def _ghost_setup_shard_fn(u, v, w, dead, vperm, lab, n: int, vps: int,
                          Gu: int, Gv: int, cap_fill_u: int,
                          cap_fill_v: int, cap_sub: int,
                          axes: Tuple[str, ...], schedule: str,
                          grid_push: bool):
    valid = jnp.isfinite(w)
    gstate, _, _, ovf, st = _ghost_setup(
        u, v, valid, valid & ~dead, lab, vperm, n, vps, Gu, Gv,
        cap_fill_u, cap_fill_v, cap_sub, tuple(axes), schedule,
        ExchangeStats.zeros(), grid_push)
    gu, gv, rs_row, rs_col = gstate
    return (gu, gv, rs_row, rs_col, ovf) + _stat_leaves(st)


@functools.lru_cache(maxsize=64)
def _build_ghost_setup_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                          axes: Tuple[str, ...], Gu: int, Gv: int,
                          cap_fill_u: int, cap_fill_v: int, cap_sub: int,
                          schedule: str, grid_push: bool):
    fn = partial(_ghost_setup_shard_fn, n=n, vps=vps, Gu=Gu, Gv=Gv,
                 cap_fill_u=cap_fill_u, cap_fill_v=cap_fill_v,
                 cap_sub=cap_sub, axes=axes, schedule=schedule,
                 grid_push=grid_push)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * 6,
        out_specs=(spec, spec, spec, spec) + (P(),) * (1 + _STAT_FIELDS)))


def _sharded_round_shard_fn(u, v, w, eid, vperm, lab, mst, dead, gu, gv,
                            rs_row, rs_col, settled, lo, hi, n: int,
                            vps: int, axes: Tuple[str, ...],
                            cap_edge: int, cap_label: int,
                            cap_lookup: int, cap_contract: int,
                            cap_push: int, cap_push_col: int,
                            schedule: str, coalesce: bool,
                            src_only: bool, adaptive: bool, ghost: bool,
                            relabel_skip: bool, vsorted: bool,
                            pallas_minedges: bool, grid_push: bool):
    names = tuple(axes)
    valid = jnp.isfinite(w)
    live0 = valid & (w > compat.vary(lo, names)) \
        & (w <= compat.vary(hi, names))
    runs_u = run_metadata(u) if (coalesce or src_only or ghost) else None
    vidx = _build_v_index(v, valid, n, names, perm=vperm) \
        if ((coalesce and vsorted) or ghost) else None
    runs_v = run_metadata(v) if (coalesce and not vsorted) else None
    gstate = (gu, gv, rs_row, rs_col) if ghost else None
    lab, mst, dead, gstate, settled, go, ovf, st = _round_body(
        u, v, w, eid, live0, lab, mst, dead, runs_u, runs_v, vidx,
        gstate, settled, n, vps, names, cap_edge, cap_label, cap_lookup,
        cap_contract, cap_push, cap_push_col, schedule, coalesce,
        src_only, adaptive, ghost, relabel_skip, pallas_minedges,
        grid_push, ExchangeStats.zeros())
    if ghost:
        gu, gv, rs_row, rs_col = gstate
    return (lab, mst, dead, gu, gv, rs_row, rs_col, settled, go,
            ovf) + _stat_leaves(st)


@functools.lru_cache(maxsize=256)
def _build_sharded_round_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                            axes: Tuple[str, ...], cap_edge: int,
                            cap_label: int, cap_lookup: int,
                            cap_contract: int, cap_push: int,
                            cap_push_col: int, schedule: str,
                            coalesce: bool, src_only: bool,
                            adaptive: bool, ghost: bool,
                            relabel_skip: bool, vsorted: bool,
                            pallas_minedges: bool, grid_push: bool):
    fn = partial(_sharded_round_shard_fn, n=n, vps=vps, axes=axes,
                 cap_edge=cap_edge, cap_label=cap_label,
                 cap_lookup=cap_lookup, cap_contract=cap_contract,
                 cap_push=cap_push, cap_push_col=cap_push_col,
                 schedule=schedule, coalesce=coalesce,
                 src_only=src_only, adaptive=adaptive, ghost=ghost,
                 relabel_skip=relabel_skip, vsorted=vsorted,
                 pallas_minedges=pallas_minedges, grid_push=grid_push)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(spec,) * 13 + (P(), P()),
        out_specs=(spec,) * 8 + (P(),) * (2 + _STAT_FIELDS)))


def _host_weight_pivots(w_h: np.ndarray, valid_h: np.ndarray,
                        num_levels: int, p: int, cap: int) -> np.ndarray:
    """Host replica of ``_weight_pivots`` (identical sampling discipline:
    same per-shard stride-64 sample, same gather order, same quantile
    positions), so the shrinking driver buckets the filter levels exactly
    like the fused engine and the two paths stay bit-identical."""
    s = min(64, cap)
    idx = (np.arange(s) * cap) // s
    samp = []
    for sh in range(p):
        ws = w_h[sh * cap:(sh + 1) * cap]
        vs = valid_h[sh * cap:(sh + 1) * cap]
        samp.append(np.where(vs[idx], ws[idx], np.inf))
    all_samp = np.sort(np.concatenate(samp).astype(np.float32))
    nfin = max(int(np.isfinite(all_samp).sum()), 1)
    pos = (np.arange(1, num_levels) * nfin) // num_levels
    return all_samp[pos]


def minedges_buffer_bytes(p: int, capacity: int, hops: int,
                          src_only: bool) -> int:
    """Static buffer bytes one MINEDGES phase ships at ``capacity``.

    Mirrors comm/exchange.py's capacity-padded accounting: a candidate
    exchange ships four [p, C] payload buffers (i32/f32/i32/i32) plus
    the 1-byte validity mask, each hop; the confirmation reply ships one
    [p, C] bool buffer.  src-only pays that once, the 2-exchange
    baseline twice.  The shrinking-capacity driver uses this to expose
    the per-round MINEDGES buffer-bytes trajectory in ``round_trace``
    (the dominant term the schedule exists to shrink).
    """
    per_exchange = (4 * 4 + 1) * p * capacity * hops
    per_reply = 1 * p * capacity * hops
    k = 1 if src_only else 2
    return k * (per_exchange + per_reply)


def _per_pair_max(shard: np.ndarray, owner: np.ndarray, p: int) -> int:
    """Max count over (source shard, destination owner) pairs."""
    if owner.size == 0:
        return 0
    return int(np.bincount(shard * p + owner, minlength=p * p).max())


def _host_run_heads(a, num_shards: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host mirror of ``kernels/segmin run_metadata``: per-shard
    contiguous equal-value run structure of a shard-major array.

    Returns (heads [p * cap] bool — first slot of its run, with a head
    forced at every shard start, exactly like the device computes runs
    per shard — and rid [p * cap] int, globally numbered run ids).
    Shared by every host-side capacity bound so the run definition
    cannot diverge between them.
    """
    arr = np.asarray(a)
    cap = arr.shape[0] // num_shards
    a2 = arr.reshape(num_shards, cap)
    head = np.ones((num_shards, cap), bool)
    head[:, 1:] = a2[:, 1:] != a2[:, :-1]
    flat = head.reshape(-1)
    return flat, np.cumsum(flat) - 1


def _minedges_capacity_bound(ru: np.ndarray, rv: np.ndarray,
                             alive: np.ndarray, shard: np.ndarray,
                             heads: np.ndarray, rid: np.ndarray,
                             p: int, vps: int, src_only: bool) -> int:
    """Exact MINEDGES candidate-exchange capacity for the coming round.

    The host holds the full sharded label table between rounds, so the
    candidate set — live slots whose endpoint components differ — and
    its owner-keyed distribution are computable exactly: the capacity is
    the maximum number of candidates any shard sends any owner.  In
    src-only mode candidates are aggregated per source run
    (``_sharded_minedges_src``), so the count is over *alive runs* keyed
    by the run's component owner; the 2-exchange variant counts alive
    slots under both endpoint keys.  Exact means the smaller buffers
    stay overflow-free by construction, and the bound decays with the
    alive-run / cross-component structure instead of staying at
    edges/shard.  Returns 0 when no candidate exists (the round could
    choose nothing).
    """
    if not alive.any():
        return 0
    if src_only:
        run_alive = np.bincount(rid[alive],
                                minlength=int(rid[-1]) + 1) > 0
        cand = heads & run_alive[rid]
        return _per_pair_max(shard[cand], ru[cand] // vps, p)
    sa = shard[alive]
    return max(_per_pair_max(sa, ru[alive] // vps, p),
               _per_pair_max(sa, rv[alive] // vps, p))


def _endpoint_lookup_bound(u_h: np.ndarray, v_h: np.ndarray,
                           live_h: np.ndarray, shard: np.ndarray,
                           p: int, vps: int) -> int:
    """Exact per-(shard, owner) bound for the *uncoalesced* endpoint
    lookups: every live slot requests both its endpoints' owners."""
    sl = shard[live_h]
    if sl.size == 0:
        return 1
    return max(1, _per_pair_max(sl, u_h[live_h] // vps, p),
               _per_pair_max(sl, v_h[live_h] // vps, p))


def _host_v_perm(v_h: np.ndarray, valid_h: np.ndarray, n: int,
                 p: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host mirror of ``_build_v_index``: per-shard stable argsort of the
    big-keyed v column.  Returns (perm [p * cap] int32 — local indices
    per shard, skey [p * cap] — the sorted keys, padding = n at each
    shard's tail).  Any stable sort of the same keys yields the same run
    structure, so host and device indices are interchangeable."""
    cap = v_h.shape[0] // p
    key = np.where(valid_h, v_h, n).astype(np.int64).reshape(p, cap)
    perm = np.argsort(key, axis=1, kind="stable").astype(np.int32)
    skey = np.take_along_axis(key, perm, axis=1)
    return perm.reshape(-1), skey.reshape(-1)


def _host_run_count_max(heads: np.ndarray, p: int) -> int:
    """Max per-shard run count — the host-exact ghost-table size."""
    cap = heads.shape[0] // p
    return max(1, int(heads.reshape(p, cap).sum(axis=1).max()))


def _host_ghost_lists(u_h: np.ndarray, v_h: np.ndarray,
                      live_h: np.ndarray, p: int) -> List[np.ndarray]:
    """Per shard: the distinct endpoint vids of its live slots — the
    host mirror of each shard's filled ghost-entry set (live-gated:
    all-dead runs are never read again, so they are never filled or
    subscribed)."""
    out = []
    cap = u_h.shape[0] // p
    for s in range(p):
        sl = slice(s * cap, (s + 1) * cap)
        out.append(np.unique(np.concatenate([u_h[sl][live_h[sl]],
                                             v_h[sl][live_h[sl]]])))
    return out


def _subscribe_capacity_bound(lab_h: np.ndarray,
                              ghosts: List[np.ndarray], p: int,
                              vps: int) -> int:
    """Exact per-(shard, owner) row count of the setup root-subscribe
    exchange: one row per distinct cached component root per shard."""
    mx = 1
    for gh in ghosts:
        if gh.size:
            roots = np.unique(lab_h[gh])
            mx = max(mx, int(np.bincount(roots // vps,
                                         minlength=p).max()))
    return mx


def _ghost_fill_bounds(u_h: np.ndarray, live_h: np.ndarray,
                       vperm_h: np.ndarray, skey: np.ndarray, n: int,
                       p: int, vps: int) -> Tuple[int, int]:
    """Exact per-(shard, owner) request counts of the two ghost fills:
    one request per distinct endpoint value with >= 1 live slot (u in
    slot order, v through the sorted key column)."""
    cap = u_h.shape[0] // p
    shard = np.repeat(np.arange(p), cap)
    head_u, rid_u = _host_run_heads(u_h, p)
    run_live = np.bincount(rid_u[live_h],
                           minlength=int(rid_u[-1]) + 1) > 0
    send_u = head_u & run_live[rid_u]
    bu = max(1, _per_pair_max(shard[send_u], u_h[send_u] // vps, p))
    head_v, rid_v = _host_run_heads(skey, p)
    live_p = np.take_along_axis(live_h.reshape(p, cap),
                                vperm_h.reshape(p, cap), axis=1
                                ).reshape(-1)
    run_live_v = np.bincount(rid_v[live_p],
                             minlength=int(rid_v[-1]) + 1) > 0
    send_v = head_v & (skey < n) & run_live_v[rid_v]
    bv = max(1, _per_pair_max(shard[send_v],
                              (skey[send_v] // vps).astype(np.int64), p))
    return bu, bv


def _relabel_capacity_bound(lab_h: np.ndarray, settled_h: np.ndarray,
                            p: int, vps: int) -> int:
    """Exact per-(shard, owner) RELABEL request count under the
    settled-vertex skip: vertex x requests from ``owner(lab[x])`` iff it
    has not yet observed its component choose nothing.  ``settled_h`` is
    the host mirror of the device mask (identical update rule, so the
    request sets coincide at overflow 0)."""
    req = ~settled_h
    if not req.any():
        return 1
    x = np.nonzero(req)[0]
    return max(1, _per_pair_max(x // vps, lab_h[x] // vps, p))


def _push_capacity_bound(lab_h: np.ndarray, ghosts: List[np.ndarray],
                         choosing: np.ndarray, p: int, vps: int) -> int:
    """Upper bound on the round's root-delta push and forward rows.

    Only a root that chose an edge this round can merge (dirty roots ⊆
    choosing), and the device's ``root_subs`` at round start is exactly
    "shards whose cached entry set contains the root" — which the host
    reconstructs from the current label table over the static ghost
    lists, so no incremental mirror of the forwarding is needed.  The
    bound covers both leg shapes: push copies per (owner shard,
    subscriber) and forward rows per source shard (a forward's
    destination is the unknown surviving root's owner, so the per-source
    total bounds every (source, dest) pair).  Decays geometrically with
    the alive-component count — the whole point of keying the dirty set
    by root instead of by vertex."""
    per_pair = np.zeros((p, p), np.int64)  # [owner, subscriber]
    subscribed = []
    for s, gh in enumerate(ghosts):
        if gh.size == 0:
            continue
        roots = np.unique(lab_h[gh])
        roots = roots[choosing[roots]]
        if roots.size == 0:
            continue
        per_pair[:, s] = np.bincount(roots // vps, minlength=p)
        subscribed.append(roots)
    if not subscribed:
        return 1
    all_roots = np.unique(np.concatenate(subscribed))
    fw = int(np.bincount(all_roots // vps, minlength=p).max())
    return max(1, int(per_pair.max()), fw)


def _push_capacity_bound_grid(lab_h: np.ndarray, ghosts: List[np.ndarray],
                              choosing: np.ndarray, p: int, R: int,
                              C: int, vps: int) -> Tuple[int, int]:
    """Host-exact bounds for the two-level grid push (ISSUE 10).

    Same reconstruction discipline as ``_push_capacity_bound``, but the
    device state is now a (row mask, col mask) *pair* per owned root, so
    the two hops have distinct shapes to bound:

      * hop 1 (owner → deputy): copies per (owner shard, destination
        column) — one per dirty root whose col mask has that column's
        bit.  The forward leg (merged masks to the surviving root's
        owner) shares ``cap_row``, so its per-source row count folds in.
      * hop 2 (deputy → subscriber): copies per (deputy device,
        destination row) — a deputy at (ri, cc) relays exactly the dirty
        roots whose owner sits in row ri, whose col mask contains cc,
        and whose row mask contains the destination row.

    Over-delivery is part of the contract: the bounds count the *cross
    product* of the per-axis masks, exactly what the device ships.
    Returns ``(bound_row, bound_col)``, each >= 1.
    """
    nv = p * vps
    row_mask = np.zeros(nv, np.int64)
    col_mask = np.zeros(nv, np.int64)
    for s, gh in enumerate(ghosts):
        if gh.size == 0:
            continue
        roots = np.unique(lab_h[gh])
        roots = roots[choosing[roots]]
        if roots.size == 0:
            continue
        row_mask[roots] |= np.int64(1) << (s // C)
        col_mask[roots] |= np.int64(1) << (s % C)
    dirty = np.nonzero(row_mask)[0]
    if dirty.size == 0:
        return 1, 1
    owner = dirty // vps
    # hop 1: [owner shard, dest col] copy counts
    b_row = 1
    for cc in range(C):
        has = ((col_mask[dirty] >> cc) & 1) > 0
        if has.any():
            b_row = max(b_row, int(np.bincount(owner[has],
                                               minlength=p).max()))
    # forward leg shares cap_row: rows per source shard
    b_row = max(b_row, int(np.bincount(owner, minlength=p).max()))
    # hop 2: [deputy device, dest row] copy counts; deputy (ri, cc)
    # relays roots owned in row ri with col bit cc, per dest-row bit
    b_col = 1
    orow = owner // C
    for rr in range(R):
        to_rr = ((row_mask[dirty] >> rr) & 1) > 0
        if not to_rr.any():
            continue
        for cc in range(C):
            sel = to_rr & (((col_mask[dirty] >> cc) & 1) > 0)
            if sel.any():
                b_col = max(b_col, int(np.bincount(orow[sel],
                                                   minlength=R).max()))
    return b_row, b_col


def _contract_capacity_bound(ru: np.ndarray, rv: np.ndarray,
                             alive: np.ndarray, vps: int) -> int:
    """Max per-owner count of distinct components incident to candidate
    edges.

    Bounds the contract-phase exchange rows exactly: only a component
    with a chosen edge has a non-self parent (so only those slots
    request, see ``_sharded_contract``), a choosing component received
    at least one candidate, and the requesting set only shrinks as
    doubling converges.  ``ru``/``rv`` are the host-resolved endpoint
    components — the same values the device lookups will produce.
    """
    if not alive.any():
        return 1
    comp = np.unique(np.concatenate([ru[alive], rv[alive]]))
    return max(1, int(np.bincount(comp // vps).max()))


def _certified_checkpoint(graph, n, mesh, axes, p, cap, algorithm,
                          windows, rounds, lvl_next, r_next, plan_pos,
                          lab, mask_h, dead_h, settled_h, ghost_on, acc):
    """Invariant barrier + snapshot (ISSUE 9): run the on-device
    ``core/verify.py`` structural checks against the partial forest and
    only construct the ``MSFCheckpoint`` on a pass — labels are
    fixpoints at every round boundary and each chosen edge merges
    exactly two components, so the mid-run forest satisfies the same
    invariants as the final one.  A failing barrier returns ``None``
    (no checkpoint beats an uncertified one)."""
    from repro.core.verify import verify_forest
    rep = verify_forest(graph, n, mesh, jnp.asarray(mask_h), lab,
                        axis_names=axes, raise_on_fail=False)
    if not rep.ok:
        return None
    return MSFCheckpoint.create(
        n=n, num_shards=p, cap_per_shard=cap, algorithm=algorithm,
        round_index=rounds, level=lvl_next, round_in_level=r_next,
        plan_pos=plan_pos, level_bounds=windows,
        lab=np.asarray(lab), settled=settled_h, mask=mask_h,
        dead=dead_h, eid=np.asarray(graph.eid), ghost_on=ghost_on,
        stats_acc=acc)


def _shrinking_capacity_msf(graph: DistGraph, n: int,
                            mesh: jax.sharding.Mesh, axes: Tuple[str, ...],
                            algorithm: str, num_levels: int,
                            max_rounds: Optional[int], ce_full: int,
                            cl: int, lk_full: int, schedule: str,
                            local_preprocessing: bool, coalesce: bool,
                            src_only: bool, adaptive: bool, ghost: bool,
                            relabel_skip: bool, vsorted: bool,
                            push_capacity: Optional[int],
                            round_trace: Optional[List[dict]],
                            plan_out: Optional[dict] = None,
                            pallas_minedges: bool = False,
                            grid_push: bool = False,
                            ckpt_every: Optional[int] = None,
                            ckpt_out: Optional[List] = None,
                            resume_from: Optional[MSFCheckpoint] = None):
    """Host-orchestrated rounds with per-round shrinking capacities.

    Runs the same ``_round_body`` as the fused engine, one jitted step
    per round, sizing each round's exchanges from host-side bounds on
    the measured dead-edge mask (see module docstring).  Bounds are
    snapped up to the ``shrink_schedule`` ladder so the set of compiled
    step programs stays logarithmic and strictly reusable across rounds
    and solves.  At overflow 0 (guaranteed for default capacities — the
    bounds are exact by construction) the result is bit-identical to the
    flat-capacity engine; the only observable difference is that a level
    whose host bound hits zero skips its trailing empty round, which can
    only *reduce* the round count.

    Ghost additions (ISSUE 4): the ghost tables are sized host-exactly
    (max per-shard distinct-endpoint run count), the fills at the exact
    distinct-value bounds, the per-round root-delta push at the
    subscribed-choosing-root bound (reconstructed from the label table
    over the static ghost lists each round), and the RELABEL capacity at
    the unsettled-request bound (the host mirrors the device's monotone
    ``settled`` mask with the identical update rule).  A user-pinned
    ``push_capacity`` below the round's push bound triggers the
    **graceful exact fallback**: the driver abandons the cache and
    finishes with exact coalesced lookups — results stay exact at
    overflow 0, never silently wrong (the fused engine instead reports
    push overflow, same contract as every exchange).

    Planner backend (ISSUE 5): with ``plan_out`` (a dict) the driver
    doubles as the measurement pass of ``plan_sharded_msf`` — it
    records the one-off setup capacities, the level weight windows and
    one ``RoundSpec`` per round with exactly the ladder-snapped
    capacities it executed.  When a level ends because the host bound
    hit zero candidates, the driver skips that trailing empty round but
    records it as a **sentinel** spec at floor capacities: the unrolled
    executor runs it, and its ``go`` flag re-proves in-program — on
    every replay graph — what the zero bound proved on the host here.
    """
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    if grid_push and len(axes) != 2:
        raise ValueError(
            f"grid_push needs a 2-axis (row, col) mesh, got axes={axes}")
    R = mesh.shape[axes[0]] if len(axes) == 2 else p
    C = mesh.shape[axes[1]] if len(axes) == 2 else 1
    vps = vertices_per_shard(n, p)
    cap = graph.cap_total // p
    mr = (math.ceil(math.log2(max(n, 2))) + 1) if max_rounds is None \
        else max_rounds
    u_h = np.asarray(graph.u)
    v_h = np.asarray(graph.v)
    w_h = np.asarray(graph.w)
    valid_h = np.isfinite(w_h)
    hops = _hops(axes, schedule)

    if plan_out is not None and (resume_from is not None or ckpt_every):
        raise ValueError(
            "checkpointing is not supported during plan measurement; "
            "checkpoint the planned execution via execute_plan instead")

    overflow = 0
    acc = np.zeros(_STAT_FIELDS, np.float64)
    if resume_from is not None:
        # re-entry (ISSUE 9): the certified snapshot replaces the
        # preprocessing product wholesale — labels, masks and position
        # restore bit-exactly, and the ghost tables are rebuilt below
        # through the existing setup path from the restored (lab, dead)
        ck = resume_from.validate_for(n, p, cap)
        if ck.algorithm != algorithm:
            raise CheckpointError(
                f"checkpoint algorithm {ck.algorithm!r} does not match "
                f"this solve's {algorithm!r}")
        lab = jnp.asarray(ck.lab)
        pre_mst = jnp.zeros((p * cap,), bool)
        mst = jnp.asarray(ck.mask)
        dead = jnp.asarray(ck.dead)
        acc += ck.stats_acc
        ghost = ghost and ck.ghost_on
    elif local_preprocessing:
        prep = _build_sharded_prep_fn(n, vps, mesh, tuple(axes), cl,
                                      schedule)
        lab, pre_mst, dead, ovf, *st = prep(graph.u, graph.v, graph.w,
                                            graph.eid)
        overflow += int(ovf)
        acc += [float(x) for x in st]
        mst = jnp.zeros((p * cap,), bool)
    else:
        lab = jnp.arange(p * vps, dtype=jnp.int32)
        pre_mst = jnp.zeros((p * cap,), bool)
        dead = jnp.asarray(u_h == v_h)
        mst = jnp.zeros((p * cap,), bool)
    dead_h = np.asarray(dead)

    # static host structures: source-run heads (src-only aggregation +
    # u-side fill bound) and the v-sorted secondary index
    shard_of = np.repeat(np.arange(p), cap)
    heads, rid = _host_run_heads(u_h, p)
    vperm_h, skey = _host_v_perm(v_h, valid_h, n, p)
    vperm = jnp.asarray(vperm_h.astype(np.int32))

    ghost_on = ghost
    ghosts = None
    if ghost_on:
        live_setup = valid_h & ~dead_h
        Gu = _host_run_count_max(heads, p)
        Gv = _host_run_count_max(_host_run_heads(skey, p)[0], p)
        ghosts = _host_ghost_lists(u_h, v_h, live_setup, p)
        bu, bv = _ghost_fill_bounds(u_h, live_setup, vperm_h, skey, n,
                                    p, vps)
        bs = _subscribe_capacity_bound(np.asarray(lab), ghosts, p, vps)
        qfu = quantize_capacity(bu, lk_full)
        qfv = quantize_capacity(bv, lk_full)
        qsub = quantize_capacity(bs, vps)
        if plan_out is not None:
            plan_out["ghost"] = GhostPlan(Gu, Gv, qfu, qfv, qsub)
        setup = _build_ghost_setup_fn(
            n, vps, mesh, tuple(axes), Gu, Gv, qfu, qfv, qsub, schedule,
            grid_push)
        gu, gv, rsubs_dev, rsubc_dev, ovf, *st = setup(
            graph.u, graph.v, graph.w, dead, vperm, lab)
        overflow += int(ovf)
        acc += [float(x) for x in st]
    else:
        gu = gv = jnp.zeros((p,), jnp.int32)  # [1] per shard placeholder
        rsubs_dev = jnp.zeros((p,), jnp.int32)
        rsubc_dev = jnp.zeros((p,), jnp.int32)

    if algorithm == "boruvka":
        windows = [(-np.inf, np.inf)]
    elif algorithm == "filter_boruvka":
        piv = _host_weight_pivots(w_h, valid_h, num_levels, p, cap)
        edges_hi = [float(x) for x in piv]
        los = [-np.inf] + edges_hi
        his = edges_hi + [np.inf]
        windows = list(zip(los, his))
    else:
        raise ValueError(algorithm)
    if resume_from is not None:
        # the snapshot freezes the level windows: recomputing pivots on
        # a different mesh (elastic restore) could move them, and the
        # bit-identity contract needs the original partition of work
        windows = [(float(lo), float(hi))
                   for lo, hi in resume_from.level_bounds]
    if plan_out is not None:
        plan_out["level_bounds"] = [(float(lo), float(hi))
                                    for lo, hi in windows]
        plan_out["rounds"] = []

    rounds = 0
    start_lvl = start_r = 0
    settled_resume = None
    if resume_from is not None:
        rounds = resume_from.round_index
        start_lvl = resume_from.level
        start_r = resume_from.round_in_level
        settled_resume = resume_from.settled
    for lvl, (lo, hi) in enumerate(windows):
        if lvl < start_lvl:
            continue
        active_h = valid_h & (w_h > lo) & (w_h <= hi)
        # settled is per level: a new weight window revives edges
        if lvl == start_lvl and settled_resume is not None:
            settled_dev = jnp.asarray(settled_resume)
            settled_h = settled_resume.copy()
            r = start_r
        else:
            settled_dev = jnp.zeros((p * vps,), bool)
            settled_h = np.zeros(p * vps, bool)
            r = 0
        while r < mr:
            if overflow:
                # a user-undersized capacity already dropped items: the
                # result is unreliable by contract (caller must retry
                # larger), and garbage labels would poison the host
                # bounds — stop burning rounds and report
                break
            live_h = active_h & ~dead_h
            lab_h = np.asarray(lab)
            ru_h = lab_h[u_h]
            rv_h = lab_h[v_h]
            alive_h = live_h & (ru_h != rv_h)
            bound_e = _minedges_capacity_bound(ru_h, rv_h, alive_h,
                                               shard_of, heads, rid, p,
                                               vps, src_only)
            ce_r = quantize_capacity(bound_e, ce_full)
            choosing = np.zeros(p * vps, bool)
            choosing[np.unique(ru_h[alive_h])] = True
            ghost_round = ghost_on
            cp_r = 1
            cpc_r = 0
            pb_flat = 0
            if ghost_round:
                pb_flat = _push_capacity_bound(lab_h, ghosts, choosing,
                                               p, vps)
                if grid_push:
                    pb, pbc = _push_capacity_bound_grid(
                        lab_h, ghosts, choosing, p, R, C, vps)
                    # the deputy hop's ceiling is every owned root once
                    # per source column; C*vps always holds a rung >= pbc
                    cpc_r = quantize_capacity(pbc, C * vps)
                else:
                    pb = pb_flat
                cp_r = quantize_capacity(pb, vps) \
                    if push_capacity is None else int(push_capacity)
                if cp_r < pb:
                    # graceful exact fallback: a user-pinned push
                    # capacity that cannot hold the worst-case dirty set
                    # would leave stale ghost entries; abandon the cache
                    # and finish with exact coalesced lookups instead of
                    # risking a wrong (if reported) answer
                    ghost_on = ghost_round = False
                    cp_r = 1
                    cpc_r = 0
            coalesce_eff = coalesce or (ghost and not ghost_round)
            # after a ghost fallback the v-sorted machinery is already
            # built, so the fallback lookups always use it
            vsorted_eff = vsorted or (ghost and not ghost_round)
            if ghost_round:
                lk_r = 1  # no endpoint lookups are traced
            elif coalesce_eff:
                lk_r = quantize_capacity(
                    default_lookup_capacity(graph, p, n, alive=live_h,
                                            vsorted=vsorted_eff,
                                            vindex=(vperm_h, skey)),
                    lk_full)
            else:
                lk_r = quantize_capacity(
                    _endpoint_lookup_bound(u_h, v_h, live_h, shard_of,
                                           p, vps), lk_full)
            con_r = quantize_capacity(
                _contract_capacity_bound(ru_h, rv_h, alive_h, vps), cl)
            if relabel_skip:
                rl_r = quantize_capacity(
                    _relabel_capacity_bound(lab_h, settled_h, p, vps), cl)
            else:
                rl_r = cl
            if plan_out is not None:
                plan_out["rounds"].append(RoundSpec(
                    level=lvl, cap_edge=ce_r, cap_lookup=lk_r,
                    cap_contract=con_r, cap_relabel=rl_r, cap_push=cp_r,
                    ghost=bool(ghost_round), sentinel=(bound_e == 0),
                    cap_push_col=cpc_r))
            if bound_e == 0:
                break  # no candidate exists: go would come back False
            # publish the 1-based round for abort-kind fault specs
            # (no-op unless an abort spec is active)
            faults.set_round(rounds + 1)
            step = _build_sharded_round_fn(
                n, vps, mesh, tuple(axes), ce_r, rl_r, lk_r, con_r,
                cp_r, cpc_r, schedule, coalesce_eff, src_only, adaptive,
                ghost_round, relabel_skip, vsorted_eff, pallas_minedges,
                grid_push and ghost_round)
            (lab, mst, dead, gu, gv, rsubs_dev, rsubc_dev, settled_dev,
             go, ovf, *st) = step(
                graph.u, graph.v, graph.w, graph.eid, vperm, lab, mst,
                dead, gu, gv, rsubs_dev, rsubc_dev, settled_dev,
                jnp.float32(lo), jnp.float32(hi))
            overflow += int(ovf)
            acc += [float(x) for x in st]
            dead_h = np.asarray(dead)
            if relabel_skip:
                # mirror of the device's monotone settled update: a
                # requesting vertex settles iff its (pre-contraction)
                # component chose nothing this round
                settled_h = settled_h | ~choosing[lab_h]
            rounds += 1
            r += 1
            if round_trace is not None:
                round_trace.append({
                    "round": rounds, "level": lvl,
                    "cap_edge": ce_r, "cap_lookup": lk_r,
                    "cap_contract": con_r, "cap_relabel": rl_r,
                    "cap_push": cp_r, "cap_push_col": cpc_r,
                    "cap_push_flat": pb_flat,
                    "grid_push": bool(grid_push and ghost_round),
                    "ghost": bool(ghost_round),
                    "alive_bound": bound_e,
                    "minedges_buffer_bytes": minedges_buffer_bytes(
                        p, ce_r, hops, src_only),
                    "a2a_calls": int(st[0]),
                    "routed_items": float(st[1]),
                    "buffer_bytes": float(st[2]),
                    "buffer_slots": float(st[3]),
                    "cache_hits": float(st[4]),
                    "lookup_items": float(st[5]),
                    "pushed_items": float(st[6]),
                    "injected_items": float(st[7]),
                })
            if (ckpt_out is not None and ckpt_every
                    and rounds % ckpt_every == 0 and not overflow):
                # cadence boundary: certify, then snapshot the re-entry
                # position — mid-level if the level continues, else the
                # head of the next level with a fresh settled mask
                nxt_lvl, nxt_r = (lvl, r) if bool(go) else (lvl + 1, 0)
                sh = settled_h if bool(go) else np.zeros(p * vps, bool)
                mask_now = np.asarray(mst) | np.asarray(pre_mst)
                ck = _certified_checkpoint(
                    graph, n, mesh, axes, p, cap, algorithm, windows,
                    rounds, nxt_lvl, nxt_r, None, lab, mask_now,
                    dead_h, sh, ghost_on, acc)
                if ck is not None:
                    ckpt_out.append(ck)
            if not bool(go):
                break

    mask = np.asarray(mst) | np.asarray(pre_mst)
    weight = np.float32(np.sum(w_h[mask], dtype=np.float64))
    count = np.int32(int(mask.sum()))
    comm = CommStats(np.int32(acc[0]), np.float32(acc[1]),
                     np.float32(acc[2]), np.int32(rounds),
                     np.float32(acc[4]), np.float32(acc[5]),
                     np.float32(acc[6]), np.float32(acc[7]))
    return (jnp.asarray(mask), weight, count, lab, np.int32(overflow),
            comm)


# --------------------------------------------------------------------------
# plan / execute split (ISSUE 5): the shrinking schedule as a value
# --------------------------------------------------------------------------

def _planned_shard_fn(u, v, w, eid, n: int, vps: int,
                      axes: Tuple[str, ...], plan: RoundPlan):
    """The plan executor: a Python-unrolled multi-round program.

    One straight-line per-shard program for the whole solve — the same
    setup phases and the same ``_round_body`` as the fused engine, but
    with *per-round* static capacities read off the ``RoundPlan``
    instead of one flat worst case, so the program jits and AOT-lowers
    whole while its buffers follow the measured shrinking schedule.

    Replay safety (never silent): besides the usual per-exchange
    overflow accounting, two plan-specific hazards are surfaced —

      * **ghost table capacity**: a replay graph with more distinct
        endpoint runs than the planned tables would have fills
        silently dropped (``mode="drop"``) and later read a *clipped*
        table entry; the per-shard run counts are therefore compared
        against the planned sizes and any excess is charged to
        ``overflow``;
      * **residual rounds**: each level's final planned round (a
        sentinel at floor capacities when the measurement pass bounded
        the level to zero remaining candidates) re-computes ``go``; a
        level still choosing edges after its last planned round sets
        the ``residual`` output, which the host wrapper turns into a
        replan and the AOT path folds into ``overflow``.

    Returns (mask, weight, count, lab, overflow, residual, comm) —
    the fused engine's tuple plus the residual-level count.
    """
    names = tuple(axes)
    valid = jnp.isfinite(w)
    base = lax.axis_index(names) * vps
    lab = base + jnp.arange(vps, dtype=jnp.int32)
    mst = compat.vary(jnp.zeros(u.shape, bool), names)
    overflow = jnp.int32(0)
    stats = ExchangeStats.zeros()

    if plan.local_preprocessing:
        lab, pre_mst, dead, ovf, stats = _sharded_preprocess(
            u, v, w, eid, valid, n, vps, plan.cap_prep, names,
            plan.schedule, stats)
        overflow += ovf
    else:
        pre_mst = compat.vary(jnp.zeros(u.shape, bool), names)
        dead = u == v

    runs_v = None
    if plan.ghost is not None:
        gp = plan.ghost
        gstate, vidx, runs_u, ovf, stats = _ghost_setup(
            u, v, valid, valid & ~dead, lab, None, n, vps, gp.table_u,
            gp.table_v, gp.cap_fill_u, gp.cap_fill_v, gp.cap_subscribe,
            names, plan.schedule, stats, plan.grid_push)
        overflow += ovf
        # ghost-table structural guard (see docstring): excess distinct
        # runs over the planned table sizes are dropped fills — report
        nu = lax.pmax(jnp.sum(runs_u[0].astype(jnp.int32)), names)
        nv = lax.pmax(jnp.sum(vidx.runs[0].astype(jnp.int32)), names)
        overflow += jnp.maximum(nu - gp.table_u, 0) \
            + jnp.maximum(nv - gp.table_v, 0)
    else:
        gstate = None
        runs_u = run_metadata(u) if (plan.coalesce or plan.src_only) \
            else None
        vidx = _build_v_index(v, valid, n, names) \
            if (plan.coalesce and plan.vsorted_index) else None
        runs_v = run_metadata(v) \
            if (plan.coalesce and not plan.vsorted_index) else None

    residual = jnp.int32(0)
    for lvl, (lo, hi) in enumerate(plan.level_bounds):
        live0 = valid
        if len(plan.level_bounds) > 1:
            live0 = valid & (w > jnp.float32(lo)) & (w <= jnp.float32(hi))
        settled = compat.vary(jnp.zeros((vps,), bool), names)
        go = None
        for spec in plan.rounds:
            if spec.level != lvl:
                continue
            # the driver's effective-lever rules, frozen per round: a
            # non-ghost round of a ghost plan is the graceful fallback,
            # which always runs coalesced through the v-sorted index
            fallback = plan.ghost is not None and not spec.ghost
            coalesce_eff = plan.coalesce or fallback
            vidx_r = vidx if (spec.ghost
                              or (coalesce_eff and vidx is not None)) \
                else None
            lab, mst, dead, gstate, settled, go, o, stats = _round_body(
                u, v, w, eid, live0, lab, mst, dead, runs_u, runs_v,
                vidx_r, gstate, settled, n, vps, names, spec.cap_edge,
                spec.cap_relabel, spec.cap_lookup, spec.cap_contract,
                spec.cap_push, spec.cap_push_col, plan.schedule,
                coalesce_eff, plan.src_only, plan.adaptive_doubling,
                spec.ghost, plan.relabel_skip, plan.pallas_minedges,
                plan.grid_push and spec.ghost, stats)
            overflow += o
        if go is not None:
            # a level still choosing edges after its planned rounds has
            # residual work the plan did not provision
            residual += go.astype(jnp.int32)

    full_mask = mst | pre_mst
    weight = lax.psum(jnp.sum(jnp.where(full_mask, w, 0.0)), names)
    count = lax.psum(jnp.sum(full_mask.astype(jnp.int32)), names)
    comm = CommStats(stats.calls, stats.items, stats.bytes,
                     jnp.int32(plan.num_rounds), stats.hits,
                     stats.misses, stats.pushed, stats.injected)
    return full_mask, weight, count, lab, overflow, residual, comm


@functools.lru_cache(maxsize=32)
def _build_planned_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                      axes: Tuple[str, ...], plan: RoundPlan):
    fn = partial(_planned_shard_fn, n=n, vps=vps, axes=axes, plan=plan)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * 4,
        out_specs=(spec, P(), P(), spec, P(), P(), P())))


@functools.lru_cache(maxsize=32)
def _build_planned_batch_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                            axes: Tuple[str, ...], plan: RoundPlan):
    """The batched planned executor (ISSUE 6): one compiled program
    serving B same-shape graphs per dispatch.

    ``jax.vmap`` of the per-shard planned program over a leading batch
    axis, inside ``shard_map``: the mesh collectives (psum / pmax /
    all_to_all) operate over the *named* axes and batch elementwise
    over the unnamed vmap axis, so B graphs cost one compiled program
    and one collective sequence of B-fold payload.  Inputs are stacked
    ``[B, p * cap]`` edge arrays sharded on dim 1; outputs keep the
    per-request axis — ``mask``/``lab`` are ``[B, p * cap]`` /
    ``[B, p * vps]`` and every scalar (weight, count, **overflow,
    residual**) is a ``[B]`` vector, so one ill-fitting request is
    visible — and replannable — on its own, without poisoning its
    batchmates (``execute_plan_batched``).
    """
    fn = jax.vmap(partial(_planned_shard_fn, n=n, vps=vps, axes=axes,
                          plan=plan))
    spec = P(None, axes)
    rep = P(None)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * 4,
        out_specs=(spec, rep, rep, spec, rep, rep, rep)))


def _planned_segment_shard_fn(u, v, w, eid, lab0=None, mst0=None,
                              dead0=None, settled0=None, *, n: int,
                              vps: int, axes: Tuple[str, ...],
                              plan: RoundPlan, start: int, stop: int):
    """Plan-round segment [start, stop) of the unrolled executor
    (ISSUE 9: checkpointed / resumed planned execution).

    The same straight-line program as ``_planned_shard_fn``, cut at
    static plan-round indices so the host can interleave the certify +
    snapshot barrier between compiled segments, or skip ahead to a
    checkpoint's ``plan_pos`` with a restored carry.  ``start == 0``
    runs the setup phases (preprocessing, ghost fill); ``start > 0``
    takes the carry (lab / mask / dead / settled) instead — the
    checkpointed mask already folds the preprocessing picks in, and
    the ghost tables are rebuilt from the restored labels through the
    existing setup path.  A segment whose first round opens a new
    filter level ignores ``settled0`` (a new weight window revives
    edges, same rule as the driver).

    ``residual`` is charged only for levels whose *final* planned
    round executes inside this segment — earlier segments of a
    mid-level cut leave the judgement to the segment that runs the
    level's sentinel.

    Returns the 7-tuple of ``_planned_shard_fn`` plus the (dead,
    settled) carry the next segment or the checkpoint needs.
    """
    names = tuple(axes)
    valid = jnp.isfinite(w)
    overflow = jnp.int32(0)
    stats = ExchangeStats.zeros()

    if start == 0:
        base = lax.axis_index(names) * vps
        lab = base + jnp.arange(vps, dtype=jnp.int32)
        mst = compat.vary(jnp.zeros(u.shape, bool), names)
        if plan.local_preprocessing:
            lab, pre_mst, dead, ovf, stats = _sharded_preprocess(
                u, v, w, eid, valid, n, vps, plan.cap_prep, names,
                plan.schedule, stats)
            overflow += ovf
        else:
            pre_mst = compat.vary(jnp.zeros(u.shape, bool), names)
            dead = u == v
    else:
        lab, mst, dead = lab0, mst0, dead0
        pre_mst = compat.vary(jnp.zeros(u.shape, bool), names)

    runs_v = None
    if plan.ghost is not None:
        gp = plan.ghost
        gstate, vidx, runs_u, ovf, stats = _ghost_setup(
            u, v, valid, valid & ~dead, lab, None, n, vps, gp.table_u,
            gp.table_v, gp.cap_fill_u, gp.cap_fill_v, gp.cap_subscribe,
            names, plan.schedule, stats, plan.grid_push)
        overflow += ovf
        nu = lax.pmax(jnp.sum(runs_u[0].astype(jnp.int32)), names)
        nv = lax.pmax(jnp.sum(vidx.runs[0].astype(jnp.int32)), names)
        overflow += jnp.maximum(nu - gp.table_u, 0) \
            + jnp.maximum(nv - gp.table_v, 0)
    else:
        gstate = None
        runs_u = run_metadata(u) if (plan.coalesce or plan.src_only) \
            else None
        vidx = _build_v_index(v, valid, n, names) \
            if (plan.coalesce and plan.vsorted_index) else None
        runs_v = run_metadata(v) \
            if (plan.coalesce and not plan.vsorted_index) else None

    residual = jnp.int32(0)
    start_level = plan.rounds[start].level \
        if plan.rounds and start < len(plan.rounds) else 0
    fresh_level = (start == 0 or not plan.rounds
                   or plan.rounds[start].level
                   != plan.rounds[start - 1].level)
    settled = compat.vary(jnp.zeros((vps,), bool), names)
    for lvl, (lo, hi) in enumerate(plan.level_bounds):
        if lvl < start_level:
            continue
        idxs = [i for i, s in enumerate(plan.rounds) if s.level == lvl]
        run = [i for i in idxs if start <= i < stop]
        if not run:
            continue
        live0 = valid
        if len(plan.level_bounds) > 1:
            live0 = valid & (w > jnp.float32(lo)) & (w <= jnp.float32(hi))
        if lvl == start_level and not fresh_level:
            settled = settled0
        else:
            settled = compat.vary(jnp.zeros((vps,), bool), names)
        go = None
        for i in run:
            spec = plan.rounds[i]
            fallback = plan.ghost is not None and not spec.ghost
            coalesce_eff = plan.coalesce or fallback
            vidx_r = vidx if (spec.ghost
                              or (coalesce_eff and vidx is not None)) \
                else None
            lab, mst, dead, gstate, settled, go, o, stats = _round_body(
                u, v, w, eid, live0, lab, mst, dead, runs_u, runs_v,
                vidx_r, gstate, settled, n, vps, names, spec.cap_edge,
                spec.cap_relabel, spec.cap_lookup, spec.cap_contract,
                spec.cap_push, spec.cap_push_col, plan.schedule,
                coalesce_eff, plan.src_only, plan.adaptive_doubling,
                spec.ghost, plan.relabel_skip, plan.pallas_minedges,
                plan.grid_push and spec.ghost, stats)
            overflow += o
        if go is not None and idxs[-1] < stop:
            residual += go.astype(jnp.int32)

    full_mask = mst | pre_mst
    weight = lax.psum(jnp.sum(jnp.where(full_mask, w, 0.0)), names)
    count = lax.psum(jnp.sum(full_mask.astype(jnp.int32)), names)
    comm = CommStats(stats.calls, stats.items, stats.bytes,
                     jnp.int32(stop - start), stats.hits, stats.misses,
                     stats.pushed, stats.injected)
    return (full_mask, weight, count, lab, overflow, residual, comm,
            dead, settled)


@functools.lru_cache(maxsize=64)
def _build_planned_segment_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                              axes: Tuple[str, ...], plan: RoundPlan,
                              start: int, stop: int):
    fn = partial(_planned_segment_shard_fn, n=n, vps=vps, axes=axes,
                 plan=plan, start=start, stop=stop)
    spec = P(axes)
    nin = 4 if start == 0 else 8
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * nin,
        out_specs=(spec, P(), P(), spec, P(), P(), P(), spec, spec)))


@functools.lru_cache(maxsize=32)
def _build_planned_segment_batch_fn(n: int, vps: int,
                                    mesh: jax.sharding.Mesh,
                                    axes: Tuple[str, ...],
                                    plan: RoundPlan, start: int,
                                    stop: int):
    """Vmapped segment executor: B same-shape requests skip ahead to
    one shared ``plan_pos`` with stacked restored carries (the batched
    resume of ``execute_plan_batched``)."""
    fn = jax.vmap(partial(_planned_segment_shard_fn, n=n, vps=vps,
                          axes=axes, plan=plan, start=start, stop=stop))
    spec = P(None, axes)
    rep = P(None)
    nin = 4 if start == 0 else 8
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * nin,
        out_specs=(spec, rep, rep, spec, rep, rep, rep, spec, spec)))


# fault injection (comm/faults.py, ISSUE 7) must force a retrace when a
# plan activates/deactivates: every memoized builder of a program that
# routes through the exchanges registers its invalidator here
for _b in (_build_sharded_fn, _build_sharded_prep_fn,
           _build_ghost_setup_fn, _build_sharded_round_fn,
           _build_planned_fn, _build_planned_batch_fn,
           _build_planned_segment_fn, _build_planned_segment_batch_fn):
    faults.register_cache_clear(_b.cache_clear)
del _b


def _replan_with_plan(graph: DistGraph, n: int, mesh: jax.sharding.Mesh,
                      axes: Tuple[str, ...], plan: RoundPlan,
                      round_trace: Optional[List[dict]] = None,
                      ckpt_every: Optional[int] = None,
                      ckpt_out: Optional[List] = None,
                      resume_from: Optional[MSFCheckpoint] = None):
    """One fresh measured pass with the plan's frozen levers — the
    overflow/residual fallback shared by ``distributed_sharded_msf``'s
    plan path, ``execute_plan_batched`` and the serving gateway's
    strict-measured retry rung.  The checkpoint kwargs (ISSUE 9) pass
    through to the shrinking driver, which is how the gateway's ladder
    takes certified snapshots during — and resumes interrupted — rungs."""
    return distributed_sharded_msf(
        graph, n, mesh, algorithm=plan.algorithm, axis_names=axes,
        num_levels=len(plan.level_bounds), schedule=plan.schedule,
        local_preprocessing=plan.local_preprocessing,
        coalesce=plan.coalesce, src_only=plan.src_only,
        adaptive_doubling=plan.adaptive_doubling,
        shrink_capacities=True, ghost_cache=plan.ghost is not None,
        ghost_push=(("grid" if plan.grid_push else "flat")
                    if plan.ghost is not None else None),
        relabel_skip=plan.relabel_skip,
        vsorted_index=plan.vsorted_index,
        pallas_minedges=plan.pallas_minedges, round_trace=round_trace,
        ckpt_every=ckpt_every, ckpt_out=ckpt_out,
        resume_from=resume_from)


def execute_plan_batched(graphs: Sequence[DistGraph], n: int,
                         mesh: jax.sharding.Mesh, plan: RoundPlan, *,
                         axis_names: Optional[Sequence[str]] = None,
                         replan=True,
                         stack: bool = True,
                         verify: bool = False,
                         resume_from: Optional[
                             Sequence[MSFCheckpoint]] = None):
    """Replay one measured ``RoundPlan`` on B same-shape graphs at once.

    The batch is stacked to ``[B, p * cap]`` and served through the
    vmapped planned program (``_build_planned_batch_fn``) in a single
    dispatch.  Per-request overflow / residual accounting keeps the
    never-silent contract *independently per request*: requests the
    plan fits are returned from the batched run as-is; each request the
    plan does not fit is re-solved by its own fresh measured pass
    (``replan=True``, the serving default), the whole call raises
    naming the offending batch indices (``replan=False``), or the bad
    requests come back as ``None`` results for the caller to handle
    (``replan="defer"`` — the gateway's retry ladder, ISSUE 7, which
    must choose between retry, replan, and rejection itself).

    ``verify=True`` (ISSUE 7) self-checks every returned forest
    on-device at O(n/p) cost (``core/verify.py``: edge count = n −
    components, label pointer-chase convergence, psum'd weight
    checksum against the program's own reported scalars).  A forest
    failing verification is treated exactly like an ill-fitting
    request: replanned and re-verified strictly (``replan=True``),
    deferred to ``None`` (``replan="defer"``), or the typed
    ``VerifyFailure`` propagates (``replan=False``).

    Returns ``(results, flagged)``: ``results[i]`` is the engine's
    standard 6-tuple ``(mask, weight, count, labels, overflow, stats)``
    for ``graphs[i]`` (overflow 0 for every request, replanned or not),
    and ``flagged`` is the tuple of batch indices that fell back or
    deferred — the serving gateway's drift signal.

    ``stack=False`` asserts the caller already stacked the arrays
    (``graphs`` is then one ``DistGraph`` of ``[B, p * cap]`` arrays).

    ``resume_from`` (ISSUE 9) is one certified ``MSFCheckpoint`` per
    request, all sharing the same ``plan_pos``: the batch skips ahead
    to that plan round in one vmapped segment dispatch with the
    stacked restored carries, bit-identical to the full batched
    replay.  Checkpoints are *taken* per request via
    ``execute_plan(ckpt_every=...)`` — the batched program has no host
    between rounds to certify at.
    """
    axes = tuple(axis_names or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    vps = vertices_per_shard(n, p)
    if stack:
        for g in graphs:
            _validate_plan_shape(plan, n, p, g.cap_total // p)
        batch_size = len(graphs)
        batched = DistGraph(
            jnp.stack([g.u for g in graphs]),
            jnp.stack([g.v for g in graphs]),
            jnp.stack([g.w for g in graphs]),
            jnp.stack([g.eid for g in graphs]))

        def graph_at(i):
            return graphs[i]
    else:
        batched = graphs
        batch_size = int(batched.u.shape[0])
        _validate_plan_shape(plan, n, p, int(batched.u.shape[1]) // p)

        def graph_at(i):   # only materialized for replanned requests
            return DistGraph(batched.u[i], batched.v[i], batched.w[i],
                             batched.eid[i])
    if resume_from is None:
        fn = _build_planned_batch_fn(n, vps, mesh, axes, plan)
        out = fn(batched.u, batched.v, batched.w, batched.eid)
    else:
        cks = list(resume_from)
        if len(cks) != batch_size or any(c is None for c in cks):
            raise CheckpointError(
                f"batched resume needs one checkpoint per request "
                f"({batch_size}), got {len(cks)} "
                f"({sum(c is None for c in cks)} missing)")
        poss = {c.plan_pos for c in cks}
        if len(poss) != 1 or None in poss:
            raise CheckpointError(
                "batched resume needs every checkpoint at one shared "
                f"plan position (one compiled segment), got {poss}")
        cap_b = int(batched.u.shape[1]) // p
        for c in cks:
            c.validate_for(n, p, cap_b)
        pos = int(cks[0].plan_pos)
        if not 0 < pos <= len(plan.rounds):
            raise CheckpointError(
                f"checkpoint plan_pos={pos} is outside this plan's "
                f"{len(plan.rounds)} rounds — taken against a "
                "different plan")
        fn = _build_planned_segment_batch_fn(n, vps, mesh, axes, plan,
                                             pos, len(plan.rounds))
        out = fn(batched.u, batched.v, batched.w, batched.eid,
                 jnp.stack([jnp.asarray(c.lab) for c in cks]),
                 jnp.stack([jnp.asarray(c.mask) for c in cks]),
                 jnp.stack([jnp.asarray(c.dead) for c in cks]),
                 jnp.stack([jnp.asarray(c.settled) for c in cks]))
    mask, weight, count, lab, ovf, residual, comm = out[:7]
    ovf_h = np.asarray(ovf)
    res_h = np.asarray(residual)
    defer = replan == "defer"
    bad = tuple(int(i) for i in
                np.nonzero((ovf_h != 0) | (res_h != 0))[0])
    if bad and not replan:
        raise RuntimeError(
            f"plan replay does not fit batch requests {list(bad)} "
            f"(overflow={[int(ovf_h[i]) for i in bad]}, residual="
            f"{[int(res_h[i]) for i in bad]}); pad the plan, re-measure "
            "with plan_sharded_msf, or allow replan=True")
    results = []
    for i in range(batch_size):
        if i in bad:
            if defer:
                results.append(None)
            else:
                # this request alone falls back to one fresh measured
                # pass with the plan's frozen levers; batchmates keep
                # their batched results untouched
                results.append(_replan_with_plan(graph_at(i), n, mesh,
                                                 axes, plan))
        else:
            results.append((mask[i], weight[i], count[i], lab[i],
                            ovf[i], CommStats(*(f[i] for f in comm))))
    if verify:
        from repro.core.verify import VerifyFailure, verify_forest
        for i, res in enumerate(results):
            if res is None:
                continue
            try:
                verify_forest(graph_at(i), n, mesh, res[0], res[3],
                              axis_names=axes,
                              expected_weight=float(res[1]),
                              expected_count=int(res[2]))
            except VerifyFailure:
                if defer:
                    results[i] = None
                    if i not in bad:
                        bad = bad + (i,)
                elif replan and i not in bad:
                    # one strict rung: replan, re-verify, then propagate
                    g = graph_at(i)
                    r2 = _replan_with_plan(g, n, mesh, axes, plan)
                    verify_forest(g, n, mesh, r2[0], r2[3],
                                  axis_names=axes,
                                  expected_weight=float(r2[1]),
                                  expected_count=int(r2[2]))
                    results[i] = r2
                    bad = bad + (i,)
                else:
                    raise
    return results, bad


def _ghost_push_mode(ghost_cache: bool, mode: Optional[str],
                     axis_sizes: Tuple[int, ...],
                     limit: Optional[int]) -> Tuple[bool, bool]:
    """Select the ghost push implementation for this mesh (ISSUE 10).

    Returns ``(ghost_on, grid)`` down the fallback ladder:

      * **flat** (single whole-mesh bitmask, ``scatter_updates``) when
        the shard count fits one int32 mask — ``p <= min(limit, 31)``;
      * **grid** (per-axis mask pair, ``scatter_updates_grid``) when it
        does not but the mesh factors into exactly two axes of at most
        ``min(limit, 31)`` shards each — up to 961 shards;
      * **off** (exact coalesced lookups) beyond both.

    ``limit`` is the user's ``ghost_shard_limit`` (None → 31); it caps
    the *per-mask* width on both rungs, which is what makes the ladder
    testable on a small mesh (p=8 on (4, 2): limit 31 → flat, limit 7 →
    grid, limit 1 → off).  An explicit ``mode`` ("flat" / "grid") skips
    the auto ladder and raises loudly when the mesh cannot honor it —
    never a silent downgrade.
    """
    p = 1
    for s in axis_sizes:
        p *= s
    if not ghost_cache:
        return False, False
    lim = MAX_GHOST_SHARDS if limit is None else int(limit)
    width = min(lim, MAX_GHOST_SHARDS)
    if mode == "flat":
        if p > MAX_GHOST_SHARDS:
            raise ValueError(
                f"ghost_push='flat' needs p <= {MAX_GHOST_SHARDS} "
                f"(int32 subscriber bitmask), got p={p}")
        return True, False
    if mode == "grid":
        if len(axis_sizes) != 2:
            raise ValueError(
                "ghost_push='grid' needs a 2-axis (row, col) mesh, got "
                f"{len(axis_sizes)} axes {tuple(axis_sizes)}")
        if max(axis_sizes) > MAX_GHOST_SHARDS:
            raise ValueError(
                f"ghost_push='grid' needs every mesh axis <= "
                f"{MAX_GHOST_SHARDS}, got {tuple(axis_sizes)}")
        return True, True
    if mode is not None:
        raise ValueError(
            f"unknown ghost_push mode {mode!r}; one of None (auto), "
            "'flat', 'grid'")
    if p <= width:
        return True, False
    if len(axis_sizes) == 2 and max(axis_sizes) <= width:
        return True, True
    return False, False


def _validate_plan_shape(plan: RoundPlan, n: int, p: int,
                         cap: int) -> None:
    plan.validate()
    if (plan.n, plan.num_shards, plan.cap_per_shard) != (n, p, cap):
        raise ValueError(
            f"plan was measured for n={plan.n}, p={plan.num_shards}, "
            f"cap/shard={plan.cap_per_shard} but this solve has n={n}, "
            f"p={p}, cap/shard={cap}; plans only transfer across "
            "graphs built at the same shape")


def plan_sharded_msf(graph: DistGraph, n: int, mesh: jax.sharding.Mesh,
                     *, algorithm: str = "boruvka",
                     axis_names: Optional[Sequence[str]] = None,
                     num_levels: int = 4,
                     max_rounds: Optional[int] = None,
                     edge_capacity: Optional[int] = None,
                     label_capacity: Optional[int] = None,
                     lookup_capacity: Optional[int] = None,
                     schedule: str = "grid",
                     local_preprocessing: bool = True,
                     coalesce: bool = True, src_only: bool = True,
                     adaptive_doubling: bool = True,
                     ghost_cache: bool = True, relabel_skip: bool = True,
                     vsorted_index: bool = True,
                     pallas_minedges: bool = False,
                     ghost_push: Optional[str] = None,
                     ghost_shard_limit: Optional[int] = None,
                     push_capacity: Optional[int] = None,
                     round_trace: Optional[List[dict]] = None
                     ) -> RoundPlan:
    """Measure a ``RoundPlan`` for ``graph`` (one host-interleaved pass).

    Runs the shrinking-capacity driver as the measurement backend and
    freezes the schedule it chose — per-round exchange capacities
    (already snapped to the ``shrink_schedule`` ladder, so plans
    transfer across structurally similar graphs), the one-off
    preprocessing / ghost-setup capacities, the filter-level weight
    windows and one trailing sentinel round per level that ended on a
    zero host bound.  The returned plan drives the Python-unrolled
    executor: ``distributed_sharded_msf(..., plan=plan)`` (works under
    AOT tracing — ``make_sharded_mst_step(plan=...)``), ``plan.pad``
    for serving headroom, ``plan.to_json`` for persistence.

    Raises on nonzero measurement overflow (user-undersized explicit
    capacities): a plan recorded off a lossy pass would be garbage.

    ``round_trace`` passes through to the driver, so one call yields
    both the plan and the measured per-round comm table.
    """
    axes = tuple(axis_names or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    vps = vertices_per_shard(n, p)
    cap = graph.cap_total // p
    if isinstance(graph.u, jax.core.Tracer):
        raise ValueError("plan_sharded_msf measures exact host bounds "
                         "and needs a concrete graph, not tracers")
    ghost_cache, grid_push = _ghost_push_mode(
        ghost_cache, ghost_push,
        tuple(mesh.shape[a] for a in axes), ghost_shard_limit)
    ce = int(cap if edge_capacity is None else edge_capacity)
    cl = int(vps if label_capacity is None else label_capacity)
    if lookup_capacity is None:
        lk = default_lookup_capacity(
            graph, p, n, vsorted=vsorted_index or ghost_cache) \
            if (coalesce or ghost_cache) else ce
    else:
        lk = int(lookup_capacity)
    rec: dict = {}
    res = _shrinking_capacity_msf(
        graph, n, mesh, axes, algorithm, num_levels, max_rounds, ce, cl,
        lk, schedule, local_preprocessing, coalesce, src_only,
        adaptive_doubling, ghost_cache, relabel_skip, vsorted_index,
        push_capacity, round_trace, plan_out=rec,
        pallas_minedges=pallas_minedges, grid_push=grid_push)
    if int(res[4]):
        raise RuntimeError(
            f"measurement pass overflowed ({int(res[4])} items): a plan "
            "recorded off a lossy pass would be unreliable — retry with "
            "larger explicit capacities (or the exact defaults)")
    return RoundPlan(
        n=n, num_shards=p, cap_per_shard=cap, algorithm=algorithm,
        schedule=schedule, local_preprocessing=local_preprocessing,
        coalesce=coalesce, src_only=src_only,
        adaptive_doubling=adaptive_doubling, relabel_skip=relabel_skip,
        vsorted_index=vsorted_index, cap_prep=cl, edge_capacity_full=ce,
        label_capacity_full=cl, lookup_capacity_full=lk,
        ghost=rec.get("ghost"),
        level_bounds=tuple(rec["level_bounds"]),
        rounds=tuple(rec["rounds"]),
        pallas_minedges=pallas_minedges,
        grid_push=grid_push and rec.get("ghost") is not None).validate()


def execute_plan(graph: DistGraph, n: int, mesh: jax.sharding.Mesh,
                 plan: RoundPlan, *,
                 axis_names: Optional[Sequence[str]] = None,
                 replan: bool = True,
                 round_trace: Optional[List[dict]] = None,
                 verify: bool = False,
                 ckpt_every: Optional[int] = None,
                 ckpt_out: Optional[List] = None,
                 resume_from: Optional[MSFCheckpoint] = None):
    """Replay a measured ``RoundPlan`` on a same-shape graph.

    Alias for ``distributed_sharded_msf(graph, n, mesh, plan=plan)``:
    runs the compiled Python-unrolled program and — if the plan does
    not fit this graph (overflow, or residual rounds after a level's
    last planned round) — falls back to one fresh measured pass with
    the plan's levers (``replan=True``, the serving default) or raises
    (``replan=False``, the strict mode tests pin replay exactness
    with).  Never returns an unreliable result silently.

    ``round_trace`` is **replan-only** here: the unrolled program has
    no host between rounds to tabulate, so a fitting replay leaves the
    list empty — per-round numbers for a plan come from the plan
    itself (``launch/roofline.py: plan_summary``) or from the
    measurement pass (``plan_sharded_msf(round_trace=...)``).

    ``verify=True`` (ISSUE 7) self-checks the returned forest on-device
    (``core/verify.py``) against the structural MSF invariants and the
    program's own reported scalars, raising a typed ``VerifyFailure``
    instead of returning a silently wrong forest.  Concrete inputs
    only — under tracing the check is skipped (the AOT contract folds
    every hazard into ``overflow`` instead).

    Checkpointing (ISSUE 9): ``ckpt_every=k`` with ``ckpt_out`` cuts
    the unrolled program at plan-round cadence boundaries
    (``_planned_segment_shard_fn``) and runs the certify + snapshot
    barrier between compiled segments; ``resume_from=ck`` skips ahead
    to the checkpoint's ``plan_pos`` with the restored carry.  The
    interrupted-then-resumed result is bit-identical to the plain
    one-program replay.  Concrete inputs only (the barrier is a host
    step); plain calls keep the single-program fast path.
    """
    if ckpt_every is None and ckpt_out is None and resume_from is None:
        out = distributed_sharded_msf(graph, n, mesh, plan=plan,
                                      axis_names=axis_names,
                                      replan=replan,
                                      round_trace=round_trace)
        if verify and not isinstance(graph.u, jax.core.Tracer):
            from repro.core.verify import verify_forest
            verify_forest(graph, n, mesh, out[0], out[3],
                          axis_names=axis_names,
                          expected_weight=float(out[1]),
                          expected_count=int(out[2]))
        return out
    if isinstance(graph.u, jax.core.Tracer):
        raise ValueError(
            "checkpointed plan execution interleaves a host barrier "
            "between compiled segments and needs concrete inputs")
    axes = tuple(axis_names or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    vps = vertices_per_shard(n, p)
    cap = graph.cap_total // p
    _validate_plan_shape(plan, n, p, cap)
    R = len(plan.rounds)
    start = 0
    carry = None
    acc = np.zeros(_STAT_FIELDS, np.float64)
    total_ovf = total_res = 0
    if resume_from is not None:
        ck = resume_from.validate_for(n, p, cap)
        if ck.plan_pos is None:
            raise CheckpointError(
                "this checkpoint was taken by the host driver (no plan "
                "position); resume it via distributed_sharded_msf("
                "resume_from=...) instead")
        if not 0 < ck.plan_pos <= R:
            raise CheckpointError(
                f"checkpoint plan_pos={ck.plan_pos} is outside this "
                f"plan's {R} rounds — it was taken against a different "
                "plan")
        start = int(ck.plan_pos)
        carry = (jnp.asarray(ck.lab), jnp.asarray(ck.mask),
                 jnp.asarray(ck.dead), jnp.asarray(ck.settled))
        acc += ck.stats_acc
    stops = []
    if ckpt_every:
        k = int(ckpt_every)
        stops = list(range((start // k + 1) * k, R, k))
    stops.append(R)
    out = None
    for stop_i in stops:
        if stop_i <= start:
            continue
        fn = _build_planned_segment_fn(n, vps, mesh, axes, plan, start,
                                       stop_i)
        args = (graph.u, graph.v, graph.w, graph.eid)
        out = fn(*args) if start == 0 else fn(*args, *carry)
        (mask, weight, count, lab, ovf, residual, comm, dead,
         settled) = out
        total_ovf += int(ovf)
        total_res += int(residual)
        acc += [float(comm[0]), float(comm[1]), float(comm[2]), 0.0,
                float(comm[4]), float(comm[5]), float(comm[6]),
                float(comm[7])]
        if stop_i < R and not total_ovf:
            lvl_next = plan.rounds[stop_i].level
            fresh = lvl_next != plan.rounds[stop_i - 1].level
            settled_h = np.zeros(p * vps, bool) if fresh \
                else np.asarray(settled)
            r_next = sum(1 for j in range(stop_i)
                         if plan.rounds[j].level == lvl_next)
            ck2 = _certified_checkpoint(
                graph, n, mesh, axes, p, cap, plan.algorithm,
                plan.level_bounds, stop_i, lvl_next, r_next, stop_i,
                lab, np.asarray(mask), np.asarray(dead), settled_h,
                plan.ghost is not None, acc)
            if ck2 is not None and ckpt_out is not None:
                ckpt_out.append(ck2)
        carry = (lab, mask, dead, settled)
        start = stop_i
    if out is None:  # resume_from at plan end: nothing left to run
        mask, weight, count, lab = (jnp.asarray(ck.mask),
                                    None, None, jnp.asarray(ck.lab))
        w_h = np.asarray(graph.w)
        m_h = np.asarray(ck.mask)
        weight = np.float32(np.sum(w_h[m_h], dtype=np.float64))
        count = np.int32(int(m_h.sum()))
    comm_total = CommStats(np.int32(acc[0]), np.float32(acc[1]),
                           np.float32(acc[2]),
                           np.int32(plan.num_rounds),
                           np.float32(acc[4]), np.float32(acc[5]),
                           np.float32(acc[6]), np.float32(acc[7]))
    if total_ovf or total_res:
        if not replan:
            raise RuntimeError(
                f"plan replay does not fit this graph (overflow="
                f"{total_ovf}, residual levels={total_res}); pad the "
                "plan, re-measure with plan_sharded_msf, or allow "
                "replan=True")
        return _replan_with_plan(graph, n, mesh, axes, plan,
                                 round_trace=round_trace,
                                 ckpt_every=ckpt_every,
                                 ckpt_out=ckpt_out)
    result = (mask, weight, count, lab, np.int32(total_ovf), comm_total)
    if verify:
        from repro.core.verify import verify_forest
        verify_forest(graph, n, mesh, result[0], result[3],
                      axis_names=axes,
                      expected_weight=float(result[1]),
                      expected_count=int(result[2]))
    return result


def vertices_per_shard(n: int, num_shards: int) -> int:
    return max(1, -(-n // num_shards))


def default_lookup_capacity(graph: DistGraph, num_shards: int, n: int,
                            alive: Optional[np.ndarray] = None,
                            vsorted: bool = True,
                            vindex: Optional[Tuple[np.ndarray,
                                                   np.ndarray]] = None
                            ) -> int:
    """Exact-by-construction capacity for the coalesced endpoint lookups.

    One host-side pass over the (already host-built) edge arrays counts,
    per (shard, owner) pair, the coalesced requests each endpoint column
    can send: the u column's contiguous equal-value runs in slot order
    (u is the lexicographic sort's major key), and — since ISSUE 4 —
    the v column's runs through the **v-sorted secondary index**
    (``_host_v_perm``), i.e. one request per distinct v per shard, which
    is what makes high-locality graphs' lookup buffers shrink on the v
    side too (the rgg2d gap PR 3 left open).  Typically
    ~edges/(shard·avg_degree) instead of edges/shard.

    With ``alive`` (a [p * cap] bool mask of slots still live) only runs
    containing at least one live slot count — exactly the runs the
    engine's coalesced lookup will send a request for, so the bound
    stays exact.  The shrinking-capacity driver calls this once per
    round with the current dead-edge mask folded in.
    ``vsorted=False`` bounds the v side by its slot-order runs instead —
    the PR 3 comparator path (``vsorted_index=False``).  ``vindex``
    optionally supplies a precomputed ``_host_v_perm`` result — the
    per-round caller (the shrinking driver) computes it once per solve
    instead of re-sorting the static v column every round.
    """
    vps = vertices_per_shard(n, num_shards)
    cap = graph.cap_total // num_shards
    shard = np.repeat(np.arange(num_shards), cap)
    live = None if alive is None else np.asarray(alive)
    u_h = np.asarray(graph.u)
    head, rid = _host_run_heads(u_h, num_shards)
    send = head
    if live is not None:
        run_live = np.bincount(rid[live],
                               minlength=int(rid[-1]) + 1) > 0
        send = head & run_live[rid]
    mx = max(1, _per_pair_max(shard[send], u_h[send] // vps, num_shards))
    v_h = np.asarray(graph.v)
    if not vsorted:
        head_v, rid_v = _host_run_heads(v_h, num_shards)
        send_v = head_v
        if live is not None:
            run_live_v = np.bincount(rid_v[live],
                                     minlength=int(rid_v[-1]) + 1) > 0
            send_v = head_v & run_live_v[rid_v]
        return max(mx, _per_pair_max(shard[send_v], v_h[send_v] // vps,
                                     num_shards))
    if vindex is None:
        valid_h = np.isfinite(np.asarray(graph.w))
        perm, skey = _host_v_perm(v_h, valid_h, n, num_shards)
    else:
        perm, skey = vindex
    head_v, rid_v = _host_run_heads(skey, num_shards)
    send_v = head_v & (skey < n)
    if live is not None:
        live_p = np.take_along_axis(live.reshape(num_shards, cap),
                                    perm.reshape(num_shards, cap),
                                    axis=1).reshape(-1)
        run_live_v = np.bincount(rid_v[live_p],
                                 minlength=int(rid_v[-1]) + 1) > 0
        send_v = send_v & run_live_v[rid_v]
    mx = max(mx, _per_pair_max(shard[send_v],
                               (skey[send_v] // vps).astype(np.int64),
                               num_shards))
    return mx


def distributed_sharded_msf(graph: DistGraph, n: int,
                            mesh: jax.sharding.Mesh, *,
                            algorithm: str = "boruvka",
                            axis_names: Optional[Sequence[str]] = None,
                            num_levels: int = 4,
                            max_rounds: Optional[int] = None,
                            edge_capacity: Optional[int] = None,
                            label_capacity: Optional[int] = None,
                            lookup_capacity: Optional[int] = None,
                            schedule: str = "grid",
                            local_preprocessing: bool = True,
                            coalesce: bool = True,
                            src_only: bool = True,
                            adaptive_doubling: bool = True,
                            shrink_capacities: bool = True,
                            ghost_cache: bool = True,
                            relabel_skip: bool = True,
                            vsorted_index: bool = True,
                            pallas_minedges: bool = False,
                            ghost_push: Optional[str] = None,
                            push_capacity: Optional[int] = None,
                            round_trace: Optional[List[dict]] = None,
                            plan: Optional[RoundPlan] = None,
                            replan: bool = True,
                            ghost_shard_limit: Optional[int] = None,
                            ckpt_every: Optional[int] = None,
                            ckpt_out: Optional[List] = None,
                            resume_from: Optional[MSFCheckpoint] = None):
    """Run the sharded-label distributed MSF on a mesh.

    Returns (mask, weight, count, labels, overflow, stats):
      * ``mask`` is aligned with ``graph`` slots, exactly one directed
        copy per MSF edge (the canonical u < v copy when
        ``src_only=False``);
      * ``labels`` is the *sharded* label vector laid out shard-major
        ([p * vertices_per_shard], slice [:n] for the per-vertex view);
      * ``overflow`` counts exchange items that exceeded capacity summed
        over all rounds — results are exact iff it is 0 (guaranteed with
        the default capacities); callers passing smaller capacities must
        retry larger on a positive count;
      * ``stats`` is a ``CommStats`` (all-to-all invocations, routed
        items, buffer bytes, rounds, plus the ghost cache's
        hits / misses / pushed triple) — the honest comm metric the
        optimization flags move (benchmarks/sharded_scaling.py).

    ``shrink_capacities=True`` (default) runs the host-orchestrated
    per-round capacity schedule: each round's MINEDGES / lookup /
    contract / RELABEL / push exchanges are sized from host bounds on
    the measured dead-edge mask, snapped to the geometric ladder of
    ``core/distributed.py: shrink_schedule`` — bit-identical results,
    geometrically decaying buffer bytes.  ``round_trace`` (a caller
    list) then receives one dict per round with the chosen capacities
    and measured comm deltas.  Under AOT lowering (tracer inputs,
    ``make_sharded_mst_step``) and with ``shrink_capacities=False`` the
    fused single-program engine with flat capacities runs instead.

    ``ghost_cache=True`` (default, ISSUE 4) keeps per-shard ghost
    tables of remote endpoint labels: one coalesced fill at setup
    (through the v-sorted secondary index, so both endpoint columns
    coalesce to one request per distinct vertex), local reads every
    round, and a dirty-label push from the owners after each
    contraction — steady-state lookup traffic is O(Δlabels).
    ``ghost_push`` selects the push implementation (ISSUE 10): None
    (default) walks the auto ladder — **flat** single-bitmask
    ``scatter_updates`` up to ``MAX_GHOST_SHARDS`` (31) shards, then
    the **two-level grid** ``scatter_updates_grid`` on 2-axis meshes
    whose axes each fit a mask (up to 961 shards, O(√p) fan-out), then
    cache off; ``"flat"``/``"grid"`` pin one rung and raise when the
    mesh cannot honor it.  ``push_capacity`` pins the push exchange
    (diagnostics): the shrinking driver falls back to exact coalesced
    lookups when the pinned value cannot hold a round's dirty bound,
    the fused engine reports push overflow.  ``relabel_skip=True``
    stops settled vertices (their component chose no edge — final
    forever) from re-requesting in RELABEL.  ``vsorted_index=False``
    restores the slot-order v coalescing of PR 3 (the measured
    comparator in benchmarks/sharded_scaling.py; no effect with the
    ghost cache on, which always builds the sorted index).

    ``pallas_minedges=True`` (ISSUE 8) routes both MINEDGES reductions
    — the pre-routing per-run combine and the owner-side scatter-min —
    through the fused ``kernels/segmin`` Pallas kernel
    (``owner_scatter_min``: compiled on TPU, interpreted elsewhere via
    ``default_interpret``) instead of the jnp scatter path; results are
    bit-identical (tests/test_kernels_fuzz.py pins the kernel, the
    equivalence matrix pins the engine) and the jnp path stays the
    measured comparator (benchmarks/kernels_bench.py).

    ``plan`` (ISSUE 5) replays a measured ``RoundPlan`` instead: the
    schedule's per-round capacities become static arguments of one
    Python-unrolled program that jits — and, uniquely among the
    shrinking paths, **AOT-lowers** (tracer inputs are fine).  The
    plan's frozen levers override this call's lever flags.  A plan that
    does not fit the graph is never silent: with concrete inputs the
    call replans (one fresh measured pass; ``replan=False`` raises
    instead), under tracing the residual-round count is folded into the
    returned ``overflow``.  See ``plan_sharded_msf`` / ``execute_plan``
    / ``core/plan.py``.

    ``ghost_shard_limit`` (tests/diagnostics) overrides the
    ``MAX_GHOST_SHARDS`` per-mask width on both ladder rungs, so the
    whole flat → grid → off ladder is exercisable on small meshes
    (p=8 on a (4, 2) mesh: limit 31 → flat, 7 → grid, 1 → off).

    Checkpointing (ISSUE 9, shrinking-capacity path only):
    ``ckpt_every=k`` with ``ckpt_out`` (a caller list) makes the host
    driver run the ``core/verify.py`` invariant barrier every k
    executed rounds and append a certified ``MSFCheckpoint`` on a pass.
    ``resume_from=ck`` re-enters at the snapshot's (level, round):
    the resumed run is **bit-identical** to the uninterrupted one on
    the same mesh, and a ``ck.remap(...)``'d checkpoint restores onto
    a different shard count (elastic restore — pass the re-partitioned
    graph).  The fused and planned paths reject these kwargs loudly;
    checkpointed plan replay lives in ``execute_plan``.

    The flags default to the optimized engine; passing
    ``local_preprocessing=False, coalesce=False, src_only=False,
    adaptive_doubling=False, shrink_capacities=False, ghost_cache=False,
    relabel_skip=False`` reproduces the PR 1 baseline exactly, and
    additionally ``ghost_cache=False, vsorted_index=False`` on top of
    the defaults reproduces the PR 3 optimized engine.
    """
    axes = tuple(axis_names or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    vps = vertices_per_shard(n, p)
    cap = graph.cap_total // p
    wants_ckpt = (ckpt_every is not None or ckpt_out is not None
                  or resume_from is not None)
    if plan is not None:
        if wants_ckpt:
            raise ValueError(
                "checkpointing a plan replay goes through execute_plan("
                "ckpt_every=..., resume_from=...), which segments the "
                "unrolled program at cadence boundaries")
        _validate_plan_shape(plan, n, p, cap)
        if plan.grid_push and len(axes) != 2:
            raise ValueError(
                "plan was measured with the two-level grid push and "
                f"needs a 2-axis (row, col) mesh, got axes={axes}")
        fn = _build_planned_fn(n, vps, mesh, axes, plan)
        out = fn(graph.u, graph.v, graph.w, graph.eid)
        mask, weight, count, lab, ovf, residual, comm = out
        if isinstance(graph.u, jax.core.Tracer):
            # AOT lowering: no host to replan on — fold the residual
            # signal into overflow (results exact iff 0, the standard
            # contract) and keep the engine's 6-tuple arity
            return mask, weight, count, lab, ovf + residual, comm
        if int(ovf) == 0 and int(residual) == 0:
            return mask, weight, count, lab, ovf, comm
        if not replan:
            raise RuntimeError(
                f"plan replay does not fit this graph (overflow="
                f"{int(ovf)}, residual levels={int(residual)}); pad the "
                "plan, re-measure with plan_sharded_msf, or allow "
                "replan=True")
        # overflow -> replan fallback: one fresh measured pass with the
        # plan's frozen levers — never a silently unreliable result
        return _replan_with_plan(graph, n, mesh, axes, plan,
                                 round_trace=round_trace)
    ghost_cache, grid_push = _ghost_push_mode(
        ghost_cache, ghost_push,
        tuple(mesh.shape[a] for a in axes), ghost_shard_limit)
    # is-None (not falsy) checks: an explicit 0 must be honored — it
    # yields all-overflow results, which the overflow count reports
    ce = int(cap if edge_capacity is None else edge_capacity)
    cl = int(vps if label_capacity is None else label_capacity)
    # the exact host-side bounds need concrete edge arrays; under AOT
    # lowering (make_sharded_mst_step) fall back to the safe flat bound
    concrete = not isinstance(graph.u, jax.core.Tracer)
    if shrink_capacities and not concrete:
        # no longer a docstring-only caveat (ISSUE 5): the host loop
        # cannot run on tracers, so say so — a RoundPlan is the way to
        # keep the schedule under AOT
        warnings.warn(
            "shrink_capacities is ignored under tracing (host bounds "
            "need concrete inputs): lowering the fused flat-capacity "
            "engine; pass plan=plan_sharded_msf(...) to AOT-lower the "
            "shrinking schedule", stacklevel=2)
    if lookup_capacity is None:
        lk = default_lookup_capacity(
            graph, p, n, vsorted=vsorted_index or ghost_cache) \
            if ((coalesce or ghost_cache) and concrete) else ce
    else:
        lk = int(lookup_capacity)
    if shrink_capacities and concrete:
        return _shrinking_capacity_msf(
            graph, n, mesh, axes, algorithm, num_levels, max_rounds, ce,
            cl, lk, schedule, local_preprocessing, coalesce, src_only,
            adaptive_doubling, ghost_cache, relabel_skip, vsorted_index,
            push_capacity, round_trace, pallas_minedges=pallas_minedges,
            grid_push=grid_push, ckpt_every=ckpt_every,
            ckpt_out=ckpt_out, resume_from=resume_from)
    if wants_ckpt:
        raise ValueError(
            "checkpointing needs the host-driven shrinking-capacity "
            "path (shrink_capacities=True, concrete inputs): the fused "
            "single-program engine has no round boundary to snapshot at")
    cp = int(vps if push_capacity is None else push_capacity)
    # fused path: the deputy hop has no host bound, so take the safe
    # worst case — a deputy relays at most one full hop-1 buffer per
    # source column (overflow still reported, like every flat capacity)
    cpc = cp * mesh.shape[axes[1]] if grid_push else 0
    shard_fn = _build_sharded_fn(n, vps, mesh, axes, algorithm, num_levels,
                                 max_rounds, ce, cl, lk, cp, cpc, schedule,
                                 local_preprocessing, coalesce, src_only,
                                 adaptive_doubling, ghost_cache,
                                 relabel_skip, vsorted_index,
                                 pallas_minedges, grid_push)
    return shard_fn(graph.u, graph.v, graph.w, graph.eid)


def make_sharded_mst_step(n: int, cap_total: int, mesh: jax.sharding.Mesh,
                          algorithm: str = "boruvka",
                          plan: Optional[RoundPlan] = None, **kw):
    """AOT-lowerable sharded MSF step (dry-run/roofline harness parity).

    With ``plan`` (a ``RoundPlan`` from ``plan_sharded_msf`` or
    ``core/plan.py: synthetic_plan``) the step lowers the
    **Python-unrolled shrinking-schedule program**: per-round measured
    capacities as static arguments, one compiled artifact for the whole
    solve — the serving-replay path, costable by dry-run/roofline
    without running.  The plan's frozen levers override ``algorithm``
    and the lever kwargs; residual-round signals fold into the returned
    ``overflow`` (exact iff 0, the standard contract).

    Without a plan, traced inputs cannot drive the host-orchestrated
    shrinking schedule, so the step lowers the fused flat-capacity
    engine.  Passing ``shrink_capacities=True`` explicitly here is
    therefore an error (it used to be silently ignored); omitting it
    warns once and lowers flat — pass ``shrink_capacities=False`` to
    opt into the flat engine silently.
    """
    if plan is not None:
        p = 1
        for a in tuple(kw.get("axis_names") or mesh.axis_names):
            p *= mesh.shape[a]
        if (cap_total != plan.cap_per_shard * p or n != plan.n
                or p != plan.num_shards):
            raise ValueError(
                f"plan shape (n={plan.n}, p={plan.num_shards}, "
                f"cap/shard={plan.cap_per_shard}) does not match the "
                f"step shape (n={n}, p={p}, "
                f"cap/shard={cap_total // max(p, 1)})")

        def step(u, v, w, eid):
            g = DistGraph(u, v, w, eid)
            return distributed_sharded_msf(
                g, n, mesh, plan=plan,
                axis_names=kw.get("axis_names"))
    else:
        if kw.get("shrink_capacities"):
            raise ValueError(
                "shrink_capacities=True cannot drive the host-"
                "orchestrated schedule under AOT tracing; measure a "
                "RoundPlan once (plan_sharded_msf) and pass plan=..., "
                "or request the flat-capacity engine explicitly with "
                "shrink_capacities=False")
        if "shrink_capacities" not in kw:
            warnings.warn(
                "make_sharded_mst_step without a plan lowers the fused "
                "flat-capacity engine (worst-case buffers every round); "
                "pass plan=plan_sharded_msf(...) to AOT-lower the "
                "shrinking schedule, or shrink_capacities=False to "
                "silence this", stacklevel=2)
            kw = dict(kw, shrink_capacities=False)

        def step(u, v, w, eid):
            g = DistGraph(u, v, w, eid)
            return distributed_sharded_msf(g, n, mesh,
                                           algorithm=algorithm, **kw)

    specs = (
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.float32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
    )
    return step, specs
