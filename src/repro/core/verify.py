"""On-device self-verification of a served MSF (ISSUE 7).

The engines' exactness argument rests on "overflow never silent" — but a
fault *past* the transport layer (a corrupted in-flight candidate, a
dropped receive slot, a stalled shard) can produce a structurally
plausible forest with overflow 0.  This module checks the returned
(mask, labels) pair against the algebraic invariants any correct MSF
run must satisfy, at O(n/p) cost per shard:

  * **pointer-chase convergence** — the label vector is a fixpoint:
    ``lab[lab[x]] == lab[x]`` for every real vertex (one owner-routed
    request/reply at capacity ``vps``, which cannot overflow: a shard
    sends at most ``vps`` requests total);
  * **range** — every real vertex's label is a real vertex id;
  * **forest size** — ``count == n - components`` with components
    counted as label fixpoints (``lab[x] == x``): a forest on ``n``
    vertices with ``c`` trees has exactly ``n - c`` edges, so a mask
    that lost or gained edges relative to the label partition is caught
    even when each edge looks locally fine;
  * **edge sanity** — no masked slot is a padding slot (non-finite
    weight) or a self-loop;
  * **weight checksum** — the psum'd recomputed ``sum(w[mask])``
    must match the caller-supplied expectation (the program's own
    reported scalar in ``execute_plan(verify=True)``; the Kruskal
    oracle's total in the chaos harness) — the check that catches a
    *wrong-but-well-formed* forest, e.g. a stalled MINEDGES shard
    yielding a valid smaller forest of the surviving candidates.

The verifier's own exchange is labelled ``site="verify"``, which the
fault-injection harness (``comm/faults.py``) deliberately excludes from
blanket ``site=""`` plans — a verifier that can be silently faulted
could never classify a chaos outcome.  Failures surface as the typed
``VerifyFailure`` carrying the full ``VerifyReport``; serving code
(``serve/msf_gateway.py``) maps it to its retry/breaker ladder instead
of returning a silently wrong MSF.
"""
from __future__ import annotations

import functools
from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import faults
from repro.comm.exchange import reply, routed_exchange
from repro.core.distributed import DistGraph


class VerifyReport(NamedTuple):
    """Host-side verdict of one ``verify_forest`` pass.  ``reasons`` is
    empty iff ``ok``; every failed invariant contributes one line."""
    ok: bool
    reasons: Tuple[str, ...]
    count: int            # masked edges
    components: int       # label fixpoints among real vertices
    weight: float         # recomputed psum'd sum(w[mask])
    converged_bad: int    # real vertices with lab[lab[x]] != lab[x]
    range_bad: int        # real vertices with lab[x] outside [0, n)
    edge_bad: int         # masked slots that are padding or self-loops
    overflow: int         # verify-exchange overflow (0 by construction)


class VerifyFailure(RuntimeError):
    """A served forest failed self-verification.  ``report`` carries the
    full invariant-by-invariant breakdown."""

    def __init__(self, report: VerifyReport):
        self.report = report
        super().__init__(
            "forest failed verification: " + "; ".join(report.reasons))


def _verify_shard_fn(u, v, w, mask, lab, n: int, vps: int,
                     axes: Tuple[str, ...], schedule: str):
    names = tuple(axes)
    base = lax.axis_index(names) * vps
    vid = base + jnp.arange(vps, dtype=jnp.int32)
    real = vid < n
    # range first: out-of-range labels are counted, then clipped so the
    # fixpoint request still routes to a real owner
    range_bad = lax.psum(jnp.sum((real & ((lab < 0) | (lab >= n))
                                  ).astype(jnp.int32)), names)
    labq = jnp.clip(lab, 0, n - 1)
    ex = routed_exchange(labq, labq // vps, real, vps, names, schedule,
                         site="verify")
    off = jnp.clip(ex.recv - base, 0, vps - 1)
    answers = jnp.where(ex.recv_ok, lab[off], jnp.int32(-1))
    lab2 = reply(ex, answers, names, schedule)
    ok_req = real & ex.sent_ok
    converged_bad = lax.psum(
        jnp.sum((ok_req & (lab2 != labq)).astype(jnp.int32))
        + jnp.sum((real & ~ex.sent_ok).astype(jnp.int32)), names)
    components = lax.psum(jnp.sum((real & (lab == vid)
                                   ).astype(jnp.int32)), names)
    count = lax.psum(jnp.sum(mask.astype(jnp.int32)), names)
    edge_bad = lax.psum(jnp.sum((mask & (~jnp.isfinite(w) | (u == v))
                                 ).astype(jnp.int32)), names)
    weight = lax.psum(jnp.sum(jnp.where(mask, w, 0.0)), names)
    return (converged_bad, range_bad, edge_bad, components, count,
            weight, ex.overflow)


@functools.lru_cache(maxsize=32)
def _build_verify_fn(n: int, vps: int, mesh: jax.sharding.Mesh,
                     axes: Tuple[str, ...], schedule: str):
    fn = partial(_verify_shard_fn, n=n, vps=vps, axes=axes,
                 schedule=schedule)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * 5, out_specs=(P(),) * 7))


# a FaultSpec may target site="verify" explicitly (harness self-tests);
# the compiled verifier must retrace across inject boundaries like
# every other routed program
faults.register_cache_clear(_build_verify_fn.cache_clear)


def verify_forest(graph: DistGraph, n: int, mesh: jax.sharding.Mesh,
                  mask: jax.Array, lab: jax.Array, *,
                  axis_names: Optional[Sequence[str]] = None,
                  expected_weight: Optional[float] = None,
                  expected_count: Optional[int] = None,
                  rel_tol: float = 1e-5,
                  raise_on_fail: bool = True) -> VerifyReport:
    """Check ``(mask, lab)`` as an MSF of ``graph`` on-device.

    ``mask`` is the engine's per-slot MSF mask ([p * cap], one directed
    copy per edge), ``lab`` the sharded label vector ([p * vps]).  The
    structural invariants (convergence, range, forest size, edge
    sanity) always run; the weight / count cross-checks run when the
    caller supplies expectations — the executing program's own reported
    scalars in ``execute_plan(verify=True)`` (internal consistency), or
    an external oracle's in the chaos harness (ground truth).
    ``rel_tol`` tolerates reduction-order noise in the float32 weight
    psum; wrong-edge deltas are orders of magnitude larger.

    Returns the ``VerifyReport``; with ``raise_on_fail`` (default) a
    failing report raises the typed ``VerifyFailure`` instead.
    """
    axes = tuple(axis_names or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    vps = max(1, -(-n // p))
    fn = _build_verify_fn(n, vps, mesh, axes, "grid")
    (converged_bad, range_bad, edge_bad, components, count, weight,
     overflow) = (int(x) if i < 5 or i == 6 else float(x)
                  for i, x in enumerate(fn(graph.u, graph.v, graph.w,
                                           mask, lab)))
    reasons = []
    if overflow:
        reasons.append(f"verify exchange overflowed ({overflow} items)")
    if range_bad:
        reasons.append(f"{range_bad} labels outside [0, {n})")
    if converged_bad:
        reasons.append(f"{converged_bad} labels not a fixpoint "
                       "(lab[lab[x]] != lab[x])")
    if edge_bad:
        reasons.append(f"{edge_bad} masked slots are padding or "
                       "self-loops")
    if count != n - components:
        reasons.append(f"edge count {count} != n - components = "
                       f"{n} - {components} = {n - components}")
    if expected_count is not None and count != int(expected_count):
        reasons.append(f"edge count {count} != expected "
                       f"{int(expected_count)}")
    if expected_weight is not None:
        exp = float(expected_weight)
        if abs(weight - exp) > rel_tol * max(1.0, abs(exp)):
            reasons.append(f"weight checksum {weight!r} != expected "
                           f"{exp!r} (rel_tol={rel_tol})")
    report = VerifyReport(ok=not reasons, reasons=tuple(reasons),
                          count=count, components=components,
                          weight=weight, converged_bad=converged_bad,
                          range_bad=range_bad, edge_bad=edge_bad,
                          overflow=overflow)
    if reasons and raise_on_fail:
        raise VerifyFailure(report)
    return report
