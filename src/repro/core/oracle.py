"""Sequential MSF oracle (Kruskal + union-find), host-side numpy.

Used as the ground truth for every correctness test and to validate the
distributed/jittable engines.  Tie-breaking matches the JAX engines:
lexicographic on (weight, edge index) which yields a unique MSF.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


class UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n)

    def find(self, x: int) -> int:
        root = x
        p = self.parent
        while p[root] != root:
            root = p[root]
        while p[x] != root:  # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        self.parent[max(ra, rb)] = min(ra, rb)
        return True


def kruskal(u: np.ndarray, v: np.ndarray, w: np.ndarray, n: int
            ) -> Tuple[np.ndarray, float]:
    """Return (mask over input edges, total MSF weight)."""
    m = len(u)
    finite = np.isfinite(w)
    idx = np.arange(m)
    order = np.lexsort((idx, w))  # (w, idx) lexicographic
    uf = UnionFind(n)
    mask = np.zeros(m, bool)
    total = 0.0
    for e in order:
        if not finite[e] or u[e] == v[e]:
            continue
        if uf.union(int(u[e]), int(v[e])):
            mask[e] = True
            total += float(w[e])
    return mask, total


def msf_weight(u: np.ndarray, v: np.ndarray, w: np.ndarray, n: int) -> float:
    return kruskal(u, v, w, n)[1]


def component_labels(u: np.ndarray, v: np.ndarray, n: int) -> np.ndarray:
    """Connected-component representative for each vertex (min vertex id)."""
    uf = UnionFind(n)
    for a, b in zip(u, v):
        uf.union(int(a), int(b))
    return np.array([uf.find(i) for i in range(n)], np.int32)


def is_forest(u: np.ndarray, v: np.ndarray, n: int) -> bool:
    uf = UnionFind(n)
    for a, b in zip(u, v):
        if not uf.union(int(a), int(b)):
            return False
    return True
