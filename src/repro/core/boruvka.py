"""Fully-jittable Borůvka MSF with dense component labels.

This is the workhorse shared by every engine in the framework:

* the single-device reference algorithm,
* the per-bucket base case of Filter-Borůvka (Section V of the paper),
* the replicated-vertex base case of the distributed algorithm
  (Section IV-D, Adler et al.), where the per-vertex min-edge reduction
  becomes a cross-device ``allReduce(min)`` over dense vertex vectors,
* the local-preprocessing contraction (Section IV-A) via the
  ``contractible`` restriction hook.

Design notes (TPU adaptation):
  The paper's pointer-doubling exchanges request/reply messages between
  PEs.  On a TPU mesh the natural representation of the vertex->component
  mapping is a dense vector indexed by vertex id (exactly the paper's own
  base-case representation), on which pointer doubling is ``labels =
  labels[labels]`` — a gather that XLA turns into the appropriate
  collective when the vector is sharded.  All shapes are static; padding
  edges carry weight +inf and never win a min-reduction.

Tie-breaking: the effective weight order is lexicographic ``(w, edge_id)``
which is a total order, so the chosen edge set is cycle-free and the MSF
is unique.  This matches the oracle in ``core/oracle.py``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import EdgeList


class BoruvkaState(NamedTuple):
    labels: jax.Array    # int32 [n] vertex -> component representative
    mst: jax.Array       # bool  [m] chosen MSF edges
    changed: jax.Array   # bool  []  did the last round contract anything
    rounds: jax.Array    # int32 []  rounds executed


def _doubling_iters(n: int) -> int:
    return max(1, math.ceil(math.log2(max(n, 2))))


def min_edge_per_component(ru: jax.Array, rv: jax.Array, w: jax.Array,
                           n: int) -> Tuple[jax.Array, jax.Array]:
    """Segmented min-edge reduction (the paper's MINEDGES).

    Args: component labels of both endpoints and weights, for m edges.
    Returns (wmin[n], emin[n]): per-component min incident weight and the
    index of the lexicographically-(w, idx)-smallest achieving edge.
    ``emin == m`` (sentinel) where a component has no alive incident edge.
    """
    m = w.shape[0]
    alive = ru != rv
    wk = jnp.where(alive & jnp.isfinite(w), w, jnp.inf)
    wmin = jnp.full((n,), jnp.inf, w.dtype)
    wmin = wmin.at[ru].min(wk)
    wmin = wmin.at[rv].min(wk)
    eidx = jnp.arange(m, dtype=jnp.int32)
    sent = jnp.int32(m)
    cand_u = jnp.where(jnp.isfinite(wk) & (wk == wmin[ru]), eidx, sent)
    cand_v = jnp.where(jnp.isfinite(wk) & (wk == wmin[rv]), eidx, sent)
    emin = jnp.full((n,), sent, jnp.int32)
    emin = emin.at[ru].min(cand_u)
    emin = emin.at[rv].min(cand_v)
    return wmin, emin


def contract_components(emin: jax.Array, u: jax.Array, v: jax.Array,
                        labels: jax.Array, n: int,
                        root_mask: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Pseudo-tree -> rooted-star contraction by pointer doubling.

    Returns (roots[n], has[n]): the new representative of every current
    component label, and whether the component chose an edge this round.
    ``root_mask`` forces components to stay roots (used for shared
    vertices in the distributed algorithm, Section IV-B).
    """
    m = u.shape[0]
    sent = jnp.int32(m)
    has = emin < sent
    ce = jnp.clip(emin, 0, m - 1)
    cids = jnp.arange(n, dtype=jnp.int32)
    cu = labels[u[ce]]
    cv = labels[v[ce]]
    other = cu + cv - cids  # the endpoint-component that is not `cids`
    parent = jnp.where(has, other, cids)
    if root_mask is not None:
        parent = jnp.where(root_mask, cids, parent)
    # Break 2-cycles: the smaller label of the pair becomes the root.
    gp = parent[parent]
    parent = jnp.where((gp == cids) & (cids < parent), cids, parent)
    # Pointer doubling (Section IV-B / Chung & Condon).
    def double(_, p):
        return p[p]
    roots = jax.lax.fori_loop(0, _doubling_iters(n), double, parent)
    return roots, has


def boruvka_round(u: jax.Array, v: jax.Array, w: jax.Array,
                  labels: jax.Array, mst: jax.Array, n: int,
                  root_mask: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One Borůvka round on dense labels. Returns (labels', mst', changed)."""
    m = u.shape[0]
    ru = labels[u]
    rv = labels[v]
    _, emin = min_edge_per_component(ru, rv, w, n)
    roots, has = contract_components(emin, u, v, labels, n, root_mask)
    ce = jnp.clip(emin, 0, m - 1)
    mst_i = mst.astype(jnp.int32).at[ce].max(has.astype(jnp.int32))
    labels = roots[labels]
    return labels, mst_i.astype(bool), jnp.any(has)


@partial(jax.jit, static_argnames=("n", "max_rounds"))
def boruvka_msf(u: jax.Array, v: jax.Array, w: jax.Array, n: int,
                max_rounds: Optional[int] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Jittable Borůvka. Returns (mst_mask[m] bool, labels[n] int32)."""
    m = u.shape[0]
    if max_rounds is None:
        # each round at least halves #non-isolated components; a run over
        # k edges touches <= 2k components.
        max_rounds = max(1, math.ceil(math.log2(max(min(n, 2 * m), 2))) + 1)
    init = BoruvkaState(
        labels=jnp.arange(n, dtype=jnp.int32),
        mst=jnp.zeros((m,), bool),
        changed=jnp.array(True),
        rounds=jnp.int32(0),
    )

    def cond(s: BoruvkaState):
        return s.changed & (s.rounds < max_rounds)

    def body(s: BoruvkaState):
        labels, mst, changed = boruvka_round(u, v, w, s.labels, s.mst, n)
        return BoruvkaState(labels, mst, changed, s.rounds + 1)

    final = jax.lax.while_loop(cond, body, init)
    return final.mst, final.labels


def boruvka_msf_on(edges: EdgeList, max_rounds: Optional[int] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    return boruvka_msf(edges.u, edges.v, edges.w, edges.n, max_rounds)
