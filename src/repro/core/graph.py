"""Distributed graph representation: padded edge lists, 1D partition.

The paper represents the graph as a lexicographically sorted sequence of
directed edges, 1D-partitioned over PEs.  We mirror that:

* ``EdgeList`` — a padded struct-of-arrays (u, v, w).  Invalid (padding)
  slots carry ``w == +inf`` and ``u == v == 0`` so they behave as
  infinitely heavy self-loops and are ignored by every algorithm.
* ``partition_edges`` — equal-size 1D split of the sorted directed edge
  sequence (the paper's input format; "shared vertices" arise when a
  vertex's edge run straddles a shard boundary).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

INVALID_W = np.float32(np.inf)


class CapacityError(ValueError):
    """A fixed-capacity edge layout cannot hold the given edges.

    Raised loudly (ISSUE 7) wherever a ``cap``/``pad_to`` argument used
    to be silently trusted: dropping edges past capacity would produce a
    *wrong MSF with no signal*, the exact failure mode the exchange
    layer's overflow accounting exists to prevent.  ``dropped`` is the
    number of edges the requested capacity cannot hold; the serving
    gateway maps this to a typed admission rejection.
    """

    def __init__(self, message: str, dropped: int = 0):
        super().__init__(message)
        self.dropped = int(dropped)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EdgeList:
    """Padded edge list. ``n`` is static (aux) metadata."""

    u: jax.Array  # int32 [m]
    v: jax.Array  # int32 [m]
    w: jax.Array  # float32 [m]; +inf marks padding
    n: int  # number of vertices (static)

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.u, self.v, self.w), self.n

    @classmethod
    def tree_unflatten(cls, n, arrays):
        u, v, w = arrays
        return cls(u=u, v=v, w=w, n=n)

    # -- helpers ----------------------------------------------------------
    @property
    def m(self) -> int:
        return int(self.u.shape[0])

    @property
    def valid(self) -> jax.Array:
        return jnp.isfinite(self.w)

    def num_valid(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


def from_numpy(u: np.ndarray, v: np.ndarray, w: np.ndarray, n: int,
               pad_to: int | None = None) -> EdgeList:
    """Build a (optionally padded) EdgeList from host arrays.

    ``pad_to`` must hold every edge — a short capacity raises a
    ``CapacityError`` with the dropped count instead of silently
    truncating (ISSUE 7: lost edges are a wrong MSF with no signal).
    """
    m = len(u)
    cap = m if pad_to is None else int(pad_to)
    if cap < m:
        raise CapacityError(
            f"pad_to={cap} cannot hold {m} edges ({m - cap} would be "
            "silently dropped)", dropped=m - cap)
    uu = np.zeros(cap, np.int32)
    vv = np.zeros(cap, np.int32)
    ww = np.full(cap, INVALID_W, np.float32)
    uu[:m] = u
    vv[:m] = v
    ww[:m] = w
    return EdgeList(jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ww), int(n))


def canonicalize_undirected(u: np.ndarray, v: np.ndarray, w: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Keep one canonical direction (u < v); drop self-loops."""
    lo = np.minimum(u, v)
    hi = np.maximum(u, v)
    keep = lo != hi
    return lo[keep].astype(np.int32), hi[keep].astype(np.int32), w[keep].astype(np.float32)


def dedup_parallel(u: np.ndarray, v: np.ndarray, w: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Keep the lightest among parallel edges (host-side preprocessing)."""
    order = np.lexsort((w, v, u))
    u, v, w = u[order], v[order], w[order]
    first = np.ones(len(u), bool)
    if len(u) > 1:
        first[1:] = (u[1:] != u[:-1]) | (v[1:] != v[:-1])
    return u[first], v[first], w[first]


def to_directed_sorted(u: np.ndarray, v: np.ndarray, w: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Both directions of every undirected edge, lexicographically sorted.

    This is the paper's on-PE input format (Section II-B).
    """
    du = np.concatenate([u, v])
    dv = np.concatenate([v, u])
    dw = np.concatenate([w, w])
    order = np.lexsort((dw, dv, du))
    return du[order].astype(np.int32), dv[order].astype(np.int32), dw[order].astype(np.float32)


def partition_edges(u: np.ndarray, v: np.ndarray, w: np.ndarray, n: int,
                    num_shards: int, cap: int | None = None) -> EdgeList:
    """1D-partition a sorted directed edge list into equal padded shards.

    Returns an EdgeList whose arrays have shape [num_shards * cap] laid out
    shard-major, ready to feed a shard_map over a 1D mesh axis.

    ``cap`` optionally pins the per-shard slot count (capacity-ladder
    callers); it must hold ``ceil(m / num_shards)`` — a short pin raises
    ``CapacityError`` with the dropped count instead of truncating.
    """
    m = len(u)
    need = -(-m // num_shards)  # ceil
    if cap is None:
        cap = need
    elif cap < need:
        raise CapacityError(
            f"cap={cap} cannot hold ceil(m/p)={need} edge slots per "
            f"shard (m={m}, p={num_shards}; "
            f"{m - cap * num_shards} edges would be silently dropped)",
            dropped=m - cap * num_shards)
    uu = np.zeros(num_shards * cap, np.int32)
    vv = np.zeros(num_shards * cap, np.int32)
    ww = np.full(num_shards * cap, INVALID_W, np.float32)
    for s in range(num_shards):
        lo, hi = s * cap, min((s + 1) * cap, m)
        if hi > lo:
            uu[s * cap: s * cap + (hi - lo)] = u[lo:hi]
            vv[s * cap: s * cap + (hi - lo)] = v[lo:hi]
            ww[s * cap: s * cap + (hi - lo)] = w[lo:hi]
    return EdgeList(jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ww), int(n))


def forest_weight(edges: EdgeList, mask: jax.Array) -> jax.Array:
    """Total weight of the selected (valid) edges."""
    sel = mask & edges.valid
    return jnp.sum(jnp.where(sel, edges.w, 0.0))
