"""Public MSF API.

``minimum_spanning_forest`` dispatches between:
  * algorithm: "boruvka" (Section IV) | "filter_boruvka" (Section V)
  * engine: "static" (fully jittable) | "dynamic" (host-orchestrated
    recursion with compaction) | "distributed" (shard_map over a device
    mesh, replicated labels; see core/distributed.py) |
    "distributed_sharded" (shard_map with 1D-sharded labels and routed
    label exchange, the paper's scalable path; see
    core/distributed_sharded.py and EXPERIMENTS.md §Sharded-label engine)

Mesh-engine knobs pass through ``**kw``: ``axis_names``, ``max_rounds``,
``local_preprocessing``, and for the sharded engine the capacity knobs
(``edge_capacity`` / ``label_capacity`` / ``lookup_capacity`` /
``push_capacity`` — explicit undersized values surface as the overflow
error below), the comm levers (``coalesce``, ``src_only``,
``adaptive_doubling``, ``ghost_cache``, ``relabel_skip``), and
``shrink_capacities`` (default on: per-round shrinking exchange
capacities from host bounds on the dead-edge mask; pass False for the
fused flat-capacity program, e.g. to compare counters).  ``ghost_cache``
(default on) replaces the per-round endpoint lookups with per-shard
ghost-label tables maintained by a dirty-label push from the owners —
see core/distributed_sharded.py.  ``plan`` (ISSUE 5) replays a measured
``core/plan.py: RoundPlan`` as one Python-unrolled program — the
shrinking schedule without the host in the loop, AOT-lowerable; an
ill-fitting plan replans, never silently degrades (see
docs/ARCHITECTURE.md §Round plans).  The engine matrix with
when-to-use guidance is in README.md; docs/ARCHITECTURE.md maps the
knobs to the paper's phases.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boruvka import boruvka_msf
from repro.core.filter_boruvka import (boruvka_dynamic, filter_boruvka_dynamic,
                                       filter_boruvka_msf)
from repro.core.graph import EdgeList


def _distributed_dispatch(edges: EdgeList, mesh: jax.sharding.Mesh,
                          engine: str, algorithm: str,
                          **kw) -> Tuple[jax.Array, jax.Array]:
    """Bridge the single-array public API onto the mesh engines.

    Host-side: drop padding, double + sort + 1D-partition the edges
    (the engines' on-PE input format), run, then reduce the slot mask
    back to the caller's edge positions via the undirected edge ids.
    The rebuild is O(m log m) numpy work *per call*; repeated solves of
    the same graph should build a ``DistGraph`` once and call
    ``distributed_msf`` / ``distributed_sharded_msf`` directly (those
    cache their compiled programs).
    """
    from repro.core.distributed import build_dist_graph, distributed_msf
    from repro.core.distributed_sharded import distributed_sharded_msf

    u = np.asarray(edges.u)
    v = np.asarray(edges.v)
    w = np.asarray(edges.w)
    idx = np.nonzero(np.isfinite(w))[0]
    axes = tuple(kw.get("axis_names") or mesh.axis_names)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    g, _ = build_dist_graph(u[idx], v[idx], w[idx], edges.n, p)
    run = (distributed_msf if engine == "distributed"
           else distributed_sharded_msf)
    res = run(g, edges.n, mesh, algorithm=algorithm, **kw)
    # res: (mask, weight, count, labels, stats) for distributed, plus an
    # overflow count at [4] (stats moves to [5]) for distributed_sharded
    mask_slots = np.asarray(res[0])
    if engine == "distributed_sharded":
        overflow = int(res[4])
        if overflow:  # hard error, not assert: must survive python -O
            raise RuntimeError(
                f"exchange overflow ({overflow} items): retry with larger "
                "edge_capacity/label_capacity")
    sel = np.unique(np.asarray(g.eid)[mask_slots])
    out = np.zeros(edges.m, bool)
    out[idx[sel]] = True
    return jnp.asarray(out), res[1]


def minimum_spanning_forest(edges: EdgeList, *, algorithm: str = "boruvka",
                            engine: str = "static",
                            num_buckets: Optional[int] = None,
                            mesh: Optional[jax.sharding.Mesh] = None,
                            **kw) -> Tuple[jax.Array, jax.Array]:
    """Compute an MSF. Returns (mask over edges, total weight).

    ``num_buckets`` controls filter_boruvka's weight bucketing; each
    engine keeps its own default when it is not given (static: 8,
    distributed engines: 4 levels).
    """
    if num_buckets is not None and num_buckets < 1:
        raise ValueError(f"num_buckets must be >= 1, got {num_buckets}")
    if engine in ("distributed", "distributed_sharded"):
        if mesh is None:  # hard error, not assert: must survive python -O
            raise ValueError(f"{engine} engine needs a mesh")
        if num_buckets is not None:
            # the mesh engines call their filter knob num_levels
            kw.setdefault("num_levels", num_buckets)
        return _distributed_dispatch(edges, mesh, engine, algorithm, **kw)
    if engine == "static":
        if algorithm == "boruvka":
            mask, _ = boruvka_msf(edges.u, edges.v, edges.w, edges.n)
        elif algorithm == "filter_boruvka":
            mask, _ = filter_boruvka_msf(
                edges.u, edges.v, edges.w, edges.n,
                num_buckets=8 if num_buckets is None else num_buckets)
        else:
            raise ValueError(algorithm)
        weight = jnp.sum(jnp.where(mask & edges.valid, edges.w, 0.0))
        return mask, weight
    if engine == "dynamic":
        u = np.asarray(edges.u)
        v = np.asarray(edges.v)
        w = np.asarray(edges.w)
        if algorithm == "boruvka":
            mask, wt = boruvka_dynamic(u, v, w, edges.n)
        elif algorithm == "filter_boruvka":
            mask, wt = filter_boruvka_dynamic(u, v, w, edges.n, **kw)
        else:
            raise ValueError(algorithm)
        return jnp.asarray(mask), jnp.asarray(wt, jnp.float32)
    raise ValueError(engine)
