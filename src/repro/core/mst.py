"""Public MSF API.

``minimum_spanning_forest`` dispatches between:
  * algorithm: "boruvka" (Section IV) | "filter_boruvka" (Section V)
  * engine: "static" (fully jittable) | "dynamic" (host-orchestrated
    recursion with compaction) | "distributed" (shard_map over a device
    mesh; see core/distributed.py)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boruvka import boruvka_msf
from repro.core.filter_boruvka import (boruvka_dynamic, filter_boruvka_dynamic,
                                       filter_boruvka_msf)
from repro.core.graph import EdgeList


def minimum_spanning_forest(edges: EdgeList, *, algorithm: str = "boruvka",
                            engine: str = "static",
                            num_buckets: int = 8,
                            mesh: Optional[jax.sharding.Mesh] = None,
                            **kw) -> Tuple[jax.Array, jax.Array]:
    """Compute an MSF. Returns (mask over edges, total weight)."""
    if engine == "distributed":
        from repro.core.distributed import distributed_msf
        assert mesh is not None, "distributed engine needs a mesh"
        return distributed_msf(edges, mesh=mesh, algorithm=algorithm, **kw)
    if engine == "static":
        if algorithm == "boruvka":
            mask, _ = boruvka_msf(edges.u, edges.v, edges.w, edges.n)
        elif algorithm == "filter_boruvka":
            mask, _ = filter_boruvka_msf(edges.u, edges.v, edges.w, edges.n,
                                         num_buckets=num_buckets)
        else:
            raise ValueError(algorithm)
        weight = jnp.sum(jnp.where(mask & edges.valid, edges.w, 0.0))
        return mask, weight
    if engine == "dynamic":
        u = np.asarray(edges.u)
        v = np.asarray(edges.v)
        w = np.asarray(edges.w)
        if algorithm == "boruvka":
            mask, wt = boruvka_dynamic(u, v, w, edges.n)
        elif algorithm == "filter_boruvka":
            mask, wt = filter_boruvka_dynamic(u, v, w, edges.n, **kw)
        else:
            raise ValueError(algorithm)
        return jnp.asarray(mask), jnp.asarray(wt, jnp.float32)
    raise ValueError(engine)
