"""First-class round plans for the sharded MSF engine (ISSUE 5).

The shrinking capacity schedule (``distributed_sharded.py:
_shrinking_capacity_msf``) sizes every round's exchanges from exact
host bounds on the measured dead-edge mask — but only host-interleaved:
a traced input cannot drive the host loop, so the AOT / dry-run /
serving path used to pay flat worst-case capacities.  A ``RoundPlan``
closes that gap by making the schedule a *value*:

  * ``plan_sharded_msf`` (the planner, in ``distributed_sharded.py``)
    runs the host-interleaved driver once as its **measurement
    backend** and records, per round, the ladder-snapped capacities the
    driver chose — plus the one-off preprocessing / ghost-setup
    capacities and the filter-level weight windows.
  * The **executor** (``distributed_sharded.py: _build_planned_fn``)
    consumes the plan as static arguments and emits a Python-unrolled
    multi-round program that jits and AOT-lowers whole — the shrinking
    schedule without a host in the loop.
  * ``pad(margin)`` returns a serving copy with capacity headroom
    (still snapped to the shared ``shrink_schedule`` ladder, so padded
    plans reuse compiled programs), and ``to_json``/``from_json`` make
    plans durable: measure once, replay across processes.

Replay contract (the capacity/overflow contract of
``docs/ARCHITECTURE.md`` extended to plans): executing a plan on a
graph it does not fit is **never silent** — undersized capacities
surface through the usual overflow count, a plan with too few rounds
surfaces through the executor's residual-work flag, and the public
entry points either *replan* (one fresh measured pass) or raise.

Everything in this module is host-side plain data: no jax imports, so
the launch layer (dry-run / roofline) can cost plans without touching
an accelerator.
"""
from __future__ import annotations

import json
import math
from typing import NamedTuple, Optional, Tuple


class RoundSpec(NamedTuple):
    """Static capacities for one Borůvka round of the planned program.

    ``cap_edge`` bounds the MINEDGES candidate exchange, ``cap_lookup``
    the endpoint-label lookups, ``cap_contract`` the pointer-doubling
    hops, ``cap_relabel`` the RELABEL requests and ``cap_push`` the
    ghost root-delta push — the same five knobs the host-interleaved
    driver re-derives every round, frozen.  ``ghost`` records whether
    the round ran on the ghost-label cache (the driver's graceful
    fallback can switch it off mid-solve).  ``sentinel`` marks a round
    the measurement pass *bounded to zero candidates* and therefore
    skipped: the executor still runs it (at floor capacities, a no-op
    on the measured graph) so its ``go`` flag re-proves on every replay
    graph that the level really is finished — the in-program equivalent
    of the driver's host-side zero-bound check.

    ``cap_push_col`` sizes the deputy→subscriber hop of the two-level
    grid push (ISSUE 10) and is only meaningful when the plan's
    ``grid_push`` lever is set; 0 (the default, and the only legal
    value on flat-push plans) keeps version-1 JSON round-tripping.
    """
    level: int
    cap_edge: int
    cap_lookup: int
    cap_contract: int
    cap_relabel: int
    cap_push: int
    ghost: bool
    sentinel: bool = False
    cap_push_col: int = 0


class GhostPlan(NamedTuple):
    """One-off ghost-cache setup sizes: the two per-shard table sizes
    (distinct-endpoint run counts, host-measured) and the fill /
    root-subscribe exchange capacities."""
    table_u: int
    table_v: int
    cap_fill_u: int
    cap_fill_v: int
    cap_subscribe: int


_CAP_FIELDS = ("cap_edge", "cap_lookup", "cap_contract", "cap_relabel",
               "cap_push")


class RoundPlan(NamedTuple):
    """A serializable, mesh-shape-bound schedule for one sharded solve.

    Shape binding: a plan is valid for any graph built with the same
    ``n``, shard count and per-shard edge capacity (``build_dist_graph``
    with the same inputs' sizes) — the capacities inside were measured
    on one such graph and *transfer* to structurally similar ones
    because they are snapped up to the geometric
    ``core/distributed.py: shrink_schedule`` ladder.  Whether a
    transfer actually fits is re-proved on every execution by the
    overflow / residual accounting; ``pad`` buys headroom first.

    The engine levers (``coalesce`` … ``vsorted_index``) are frozen
    into the plan because the capacities are only meaningful for the
    exchange pattern they were measured on; the executor follows the
    plan, not the caller's flags.  ``ghost is None`` means the cache
    was off (or auto-disabled) at plan time.
    """
    n: int
    num_shards: int
    cap_per_shard: int
    algorithm: str
    schedule: str
    local_preprocessing: bool
    coalesce: bool
    src_only: bool
    adaptive_doubling: bool
    relabel_skip: bool
    vsorted_index: bool
    cap_prep: int
    edge_capacity_full: int
    label_capacity_full: int
    lookup_capacity_full: int
    ghost: Optional[GhostPlan]
    level_bounds: Tuple[Tuple[float, float], ...]
    rounds: Tuple[RoundSpec, ...]
    # trailing with defaults so version-1 JSON written before the levers
    # existed still round-trips (absent key -> jnp comparator path /
    # flat push)
    pallas_minedges: bool = False
    grid_push: bool = False

    # -- structure ---------------------------------------------------------

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    def validate(self) -> "RoundPlan":
        """Raise ValueError on structurally broken plans (hand-edited
        JSON, truncation bugs) before they reach the executor."""
        if self.n < 1 or self.num_shards < 1 or self.cap_per_shard < 1:
            raise ValueError(f"bad plan dims: n={self.n} "
                             f"p={self.num_shards} cap={self.cap_per_shard}")
        if not self.level_bounds or not self.rounds:
            raise ValueError("plan has no levels or no rounds")
        levels = [r.level for r in self.rounds]
        if levels != sorted(levels):
            raise ValueError("plan rounds are not grouped by level")
        if set(levels) != set(range(len(self.level_bounds))):
            raise ValueError(
                f"plan levels {sorted(set(levels))} do not cover the "
                f"{len(self.level_bounds)} level windows (every level "
                "needs >= 1 round, sentinel included)")
        for r in self.rounds:
            for f in _CAP_FIELDS:
                if getattr(r, f) < 1:
                    raise ValueError(f"round {r} has {f} < 1")
        if self.ghost is not None and min(self.ghost) < 1:
            raise ValueError(f"bad ghost sizes: {self.ghost}")
        return self

    # -- serving headroom --------------------------------------------------

    def pad(self, margin: float = 0.25) -> "RoundPlan":
        """Return a copy with every exchange capacity scaled by
        ``1 + margin`` and re-snapped **up** to the shared capacity
        ladder (never past the flat full), for replaying one measured
        plan across structurally similar serving graphs.  Ghost table
        sizes are padded too (bounded by the per-shard slot count, the
        fused engine's safe size).  Round count and weight windows are
        unchanged — a graph needing more rounds is caught by the
        executor's residual flag, not papered over.
        """
        from repro.core.distributed import quantize_capacity
        if margin < 0:
            raise ValueError(f"margin must be >= 0, got {margin}")

        def up(c: int, full: int) -> int:
            return quantize_capacity(
                min(int(math.ceil(c * (1.0 + margin))), full), full)

        fulls = {"cap_edge": self.edge_capacity_full,
                 "cap_lookup": self.lookup_capacity_full,
                 "cap_contract": self.label_capacity_full,
                 "cap_relabel": self.label_capacity_full,
                 "cap_push": self.label_capacity_full}
        # the deputy-hop capacity's ceiling is one copy of every owned
        # root per source column; the plan does not know the mesh's
        # column count, so label_full * num_shards is the safe ceiling
        col_full = self.label_capacity_full * self.num_shards
        rounds = tuple(
            r._replace(**{f: up(getattr(r, f), fulls[f])
                          for f in _CAP_FIELDS},
                       cap_push_col=(up(r.cap_push_col, col_full)
                                     if r.cap_push_col > 0 else 0))
            for r in self.rounds)
        ghost = self.ghost
        if ghost is not None:
            # table sizes are exact measured counts, not ladder rungs:
            # scale and clamp to the per-shard slot count (the fused
            # engine's always-safe size) without snapping
            def up_table(c: int) -> int:
                return min(int(math.ceil(c * (1.0 + margin))),
                           self.cap_per_shard)

            ghost = GhostPlan(
                table_u=up_table(ghost.table_u),
                table_v=up_table(ghost.table_v),
                cap_fill_u=up(ghost.cap_fill_u, self.lookup_capacity_full),
                cap_fill_v=up(ghost.cap_fill_v, self.lookup_capacity_full),
                cap_subscribe=up(ghost.cap_subscribe,
                                 self.label_capacity_full))
        return self._replace(rounds=rounds, ghost=ghost)

    # -- serving cache identity --------------------------------------------

    def cache_key(self, family: str = "") -> str:
        """The stable serving-cache identity of this plan (ISSUE 6).

        Delegates to :func:`plan_cache_key` with the plan's own shape /
        algorithm / lever fields, so a gateway can compute the same key
        *before* a plan exists (from the request's family, shape and
        lever flags) and after measurement (from the plan itself) and
        get one cache slot.  ``family`` is the traffic label the plan
        was measured under — it is not a plan field because capacity
        schedules, not plans, differ per family.
        """
        return plan_cache_key(
            family, self.n, self.num_shards, self.cap_per_shard,
            self.algorithm, schedule=self.schedule,
            local_preprocessing=self.local_preprocessing,
            coalesce=self.coalesce, src_only=self.src_only,
            adaptive_doubling=self.adaptive_doubling,
            relabel_skip=self.relabel_skip,
            vsorted_index=self.vsorted_index,
            pallas_minedges=self.pallas_minedges,
            grid_push=self.grid_push)

    # -- serialization -----------------------------------------------------

    def to_json(self, indent: Optional[int] = None) -> str:
        d = self._asdict()
        d["ghost"] = None if self.ghost is None else self.ghost._asdict()
        d["level_bounds"] = [[_enc(lo), _enc(hi)]
                             for lo, hi in self.level_bounds]
        d["rounds"] = [r._asdict() for r in self.rounds]
        return json.dumps({"version": 1, **d}, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "RoundPlan":
        d = json.loads(text)
        ver = d.pop("version", None)
        if ver != 1:
            raise ValueError(f"unsupported RoundPlan version: {ver!r}")
        d["ghost"] = None if d["ghost"] is None else GhostPlan(**d["ghost"])
        d["level_bounds"] = tuple((_dec(lo), _dec(hi))
                                  for lo, hi in d["level_bounds"])
        d["rounds"] = tuple(RoundSpec(**r) for r in d["rounds"])
        return cls(**d).validate()


def plan_cache_key(family: str, n: int, num_shards: int,
                   cap_per_shard: int, algorithm: str = "boruvka", *,
                   schedule: str = "grid",
                   local_preprocessing: bool = True,
                   coalesce: bool = True, src_only: bool = True,
                   adaptive_doubling: bool = True,
                   relabel_skip: bool = True,
                   vsorted_index: bool = True,
                   pallas_minedges: bool = False,
                   grid_push: bool = False) -> str:
    """Stable plan-cache key: (family, n, edge-cap rung, algorithm,
    levers).

    ``cap_per_shard`` should already be a ``shrink_schedule`` ladder
    rung (the serving gateway pads every admitted graph's per-shard
    edge capacity up to a rung via ``quantize_capacity`` before
    building it), so structurally similar graphs of one family land on
    one key → one measured plan → one compiled program.  The ghost
    cache is deliberately absent: whether a plan carries ghost tables
    is derived deterministically from these inputs and the mesh
    (``ghost_cache`` auto-disable above the ghost shard limit), so
    including it would only split cache slots that execute identically;
    ``grid_push`` *is* a key bit because the flat and two-level pushes
    compile to different collectives at the same shape (ISSUE 10).
    """
    levers = "".join(
        "1" if f else "0"
        for f in (local_preprocessing, coalesce, src_only,
                  adaptive_doubling, relabel_skip, vsorted_index,
                  pallas_minedges, grid_push))
    return (f"{family}|n{int(n)}|p{int(num_shards)}|c{int(cap_per_shard)}"
            f"|{algorithm}|{schedule}|{levers}")


def _enc(x: float):
    """±inf-safe JSON encoding for the level weight windows."""
    if math.isinf(x):
        return "inf" if x > 0 else "-inf"
    return float(x)


def _dec(x) -> float:
    return float(x)


# Per-family MINEDGES decay models, fit to the measured schedules of
# EXPERIMENTS §Shrinking capacity schedule (n=4096, p=8, seed 3):
#   gnm:   the candidate exchange is bounded by one item per source
#          vertex per shard, so cap_edge *plateaus* at the
#          vertices-per-shard rung (measured: 512 every round);
#   rgg2d: locality-ordered geometric graphs contract geometrically,
#          so cap_edge starts at the cap/p rung and *halves* each
#          round (measured: 500 250 125 63 63 32).
# (start, step) = (rung of the first round, rungs descended per round).
_FAMILY_EDGE_DECAY = {
    "gnm": ("vps", 0),
    "rgg2d": ("cap_over_p", 1),
}


def synthetic_plan(n: int, cap_total: int, num_shards: int, *,
                   algorithm: str = "boruvka", schedule: str = "grid",
                   local_preprocessing: bool = True,
                   family: Optional[str] = None) -> RoundPlan:
    """An unmeasured geometric-ladder plan for AOT costing (dry-run).

    Encodes the paper's contraction assumption directly — Borůvka at
    least halves the active components per round, so round ``r`` gets
    rung ``r`` of the shared halving ladder for every exchange — with
    ``log2(n) + 1`` rounds (the engines' round bound).  Meant for
    *costing* a planned program's compiled memory/collectives on meshes
    where no measurement graph exists (``launch/dryrun.py``); replaying
    it on a real graph is legal but may report overflow / residual
    rounds and replan, exactly like any other ill-fitting plan.

    ``family`` (ISSUE 6) calibrates the MINEDGES trajectory to a
    traffic family's measured decay instead of the generic full-cap
    halving: ``"gnm"`` plateaus ``cap_edge`` at the vertices-per-shard
    rung, ``"rgg2d"`` halves from the cap/p rung
    (``_FAMILY_EDGE_DECAY``; both within one ladder rung of the
    measured plan at n=4096/p=8 — pinned by tests/test_serve_msf.py).
    ``None`` keeps the conservative generic ladder.

    Conservative lever choices (no ghost cache, no settled skip): the
    synthesized capacities have no host mirror to make them exact, so
    the plan sticks to the paths whose floors degrade to reported
    overflow rather than extra structure.
    """
    from repro.core.distributed import quantize_capacity, shrink_schedule
    cap = max(1, cap_total // num_shards)
    vps = max(1, -(-n // num_shards))
    rounds_n = max(1, math.ceil(math.log2(max(n, 2))) + 1)
    edge_l = shrink_schedule(cap)
    lab_l = shrink_schedule(vps)

    if family is None:
        start_idx, step = 0, 1
    else:
        if family not in _FAMILY_EDGE_DECAY:
            raise ValueError(
                f"no calibrated decay model for family {family!r} "
                f"(known: {sorted(_FAMILY_EDGE_DECAY)}); pass "
                "family=None for the generic halving ladder")
        anchor, step = _FAMILY_EDGE_DECAY[family]
        first = min(vps, cap) if anchor == "vps" \
            else max(1, -(-cap // num_shards))
        start_idx = edge_l.index(quantize_capacity(first, cap))

    def rung(ladder, r):
        return ladder[min(r, len(ladder) - 1)]

    def edge_rung(r):
        return edge_l[min(start_idx + step * r, len(edge_l) - 1)]

    rounds = tuple(
        RoundSpec(level=0, cap_edge=edge_rung(r),
                  cap_lookup=edge_rung(r),
                  cap_contract=rung(lab_l, r), cap_relabel=vps,
                  cap_push=1, ghost=False,
                  sentinel=(r == rounds_n - 1))
        for r in range(rounds_n))
    return RoundPlan(
        n=n, num_shards=num_shards, cap_per_shard=cap,
        algorithm=algorithm, schedule=schedule,
        local_preprocessing=local_preprocessing,
        coalesce=True, src_only=True, adaptive_doubling=True,
        relabel_skip=False, vsorted_index=True, cap_prep=vps,
        edge_capacity_full=cap, label_capacity_full=vps,
        lookup_capacity_full=cap, ghost=None,
        level_bounds=((-math.inf, math.inf),), rounds=rounds).validate()
