"""Round-level checkpoints for the sharded MSF engine (ISSUE 9).

At 65 536 cores (the paper's headline scale) a component failure mid-run
is the expected case, and PR 7's detection stack (fault injection,
on-device verifier, gateway retry ladder) still recovers from every
detected fault by re-executing from round 0.  This module makes the
cheaper recovery possible: Borůvka's per-round state is exactly the
O(n/p) vertex-keyed tables (the memory-efficient observation of
arxiv 2305.05121), so snapshotting it between rounds is one label
vector, three masks and the chosen-edge ids — not the edge arrays,
which the host already holds.

An ``MSFCheckpoint`` is a plain host-side value (numpy only — importing
this module must not initialize a JAX backend, same discipline as
``core/plan.py``):

  * vertex-keyed state: the contracted label table ``lab`` and the
    per-level ``settled`` mask, both laid out ``[p * vps]`` and indexed
    by vertex id (shard-major layout makes the flat index *be* the
    vid), which is what makes **elastic restore** a re-owner-mapping:
    a p′-shard mesh re-slices the same first ``n`` entries;
  * edge-keyed state: the slot-aligned MSF ``mask`` and dead-edge mask
    for bit-exact same-mesh resume, plus the mesh-independent ``eids``
    of the chosen undirected edges — the representation that survives
    re-partitioning the edges from the host store onto p′ shards
    (``remap``: mask slots are re-derived as the canonical ``u < v``
    copy per chosen eid, dead as label-internal edges);
  * position: executed-round count, the (level, in-level round) the
    host driver re-enters at, the plan-round index ``plan_pos`` the
    unrolled executor skips ahead to, and the frozen level weight
    windows (recomputing pivots on a p′ mesh could move them);
  * integrity: a per-shard CRC32 over that shard's slices of every
    array, re-checked on restore (``verify_checksums``) so a checkpoint
    corrupted at rest is a typed ``CheckpointError``, never a wrong
    resume.

Certification is the *taker's* job, not this module's: both drivers run
the ``core/verify.py`` invariant barrier (label fixpoint, range,
``count == n - components``, edge sanity) **before** constructing the
checkpoint, so every checkpoint in a ``ckpt_out`` list is
certified-good — resuming from one can never replay a corrupted state.
Ghost tables are deliberately *not* snapshotted: they are a cache of
the label table and are rebuilt on restore through the existing setup
path (``_ghost_setup``), which keeps the checkpoint O(n/p) and makes
elastic restore trivially coherent.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional, Tuple

import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint failed integrity or shape validation on restore."""


def _shard_crc(arrays, shard: int, spans) -> np.uint32:
    """CRC32 over ``shard``'s slice of every array (``spans[i]`` is the
    per-shard span of ``arrays[i]``)."""
    crc = 0
    for a, span in zip(arrays, spans):
        lo = shard * span
        sl = np.ascontiguousarray(a[lo:lo + span])
        crc = zlib.crc32(sl.tobytes(), crc)
    return np.uint32(crc)


@dataclasses.dataclass(frozen=True)
class MSFCheckpoint:
    """One certified snapshot of the sharded engine's per-round state.

    ``round_index`` counts rounds *executed* before the snapshot;
    ``level`` / ``round_in_level`` are the position the shrinking driver
    re-enters at; ``plan_pos`` is the index into ``RoundPlan.rounds``
    the unrolled executor skips ahead to (``None`` for driver-taken
    checkpoints, which have no plan).  ``stats_acc`` carries the
    driver's 8-field comm accumulator so a resumed run's ``CommStats``
    continues the interrupted run's totals.
    """
    n: int
    num_shards: int
    cap_per_shard: int
    algorithm: str
    round_index: int
    level: int
    round_in_level: int
    plan_pos: Optional[int]
    level_bounds: Tuple[Tuple[float, float], ...]
    lab: np.ndarray          # int32 [p * vps] — label table, vid-indexed
    settled: np.ndarray      # bool  [p * vps] — current level's mask
    mask: np.ndarray         # bool  [p * cap] — MSF slots chosen so far
    dead: np.ndarray         # bool  [p * cap] — retired edge slots
    eids: np.ndarray         # int32 sorted — chosen undirected edge ids
    ghost_on: bool           # ghost cache still active at the snapshot
    stats_acc: np.ndarray    # float64 [8] — driver comm accumulator
    checksums: np.ndarray    # uint32 [p] — per-shard content CRC32

    # -- construction ------------------------------------------------------

    @staticmethod
    def create(n: int, num_shards: int, cap_per_shard: int,
               algorithm: str, round_index: int, level: int,
               round_in_level: int, plan_pos: Optional[int],
               level_bounds, lab, settled, mask, dead, eid,
               ghost_on: bool, stats_acc) -> "MSFCheckpoint":
        """Snapshot (copies taken; ``eid`` is the graph's slot-aligned
        edge-id column from which the chosen undirected ids are read)."""
        p = num_shards
        lab = np.array(lab, np.int32, copy=True)
        settled = np.array(settled, bool, copy=True)
        mask = np.array(mask, bool, copy=True)
        dead = np.array(dead, bool, copy=True)
        eids = np.unique(np.asarray(eid, np.int32)[mask])
        vps = lab.shape[0] // p
        cap = mask.shape[0] // p
        sums = np.array(
            [_shard_crc((lab, settled, mask, dead), s,
                        (vps, vps, cap, cap)) for s in range(p)],
            np.uint32)
        return MSFCheckpoint(
            n=n, num_shards=p, cap_per_shard=cap_per_shard,
            algorithm=algorithm, round_index=int(round_index),
            level=int(level), round_in_level=int(round_in_level),
            plan_pos=plan_pos,
            level_bounds=tuple((float(lo), float(hi))
                               for lo, hi in level_bounds),
            lab=lab, settled=settled, mask=mask, dead=dead, eids=eids,
            ghost_on=bool(ghost_on),
            stats_acc=np.array(stats_acc, np.float64, copy=True),
            checksums=sums)

    # -- integrity ---------------------------------------------------------

    def verify_checksums(self) -> "MSFCheckpoint":
        """Recompute every per-shard CRC and compare; raises the typed
        ``CheckpointError`` naming the corrupted shards on mismatch."""
        p = self.num_shards
        vps = self.lab.shape[0] // p
        cap = self.mask.shape[0] // p
        now = np.array(
            [_shard_crc((self.lab, self.settled, self.mask, self.dead),
                        s, (vps, vps, cap, cap)) for s in range(p)],
            np.uint32)
        bad = np.nonzero(now != self.checksums)[0]
        if bad.size:
            raise CheckpointError(
                f"checkpoint content checksum mismatch on shard(s) "
                f"{bad.tolist()} (round {self.round_index}): the "
                "snapshot was corrupted at rest — refusing to resume")
        return self

    def validate_for(self, n: int, num_shards: int,
                     cap_per_shard: int) -> "MSFCheckpoint":
        """Shape gate for same-mesh resume (checksums included)."""
        self.verify_checksums()
        if (self.n, self.num_shards, self.cap_per_shard) != \
                (n, num_shards, cap_per_shard):
            raise CheckpointError(
                f"checkpoint was taken at n={self.n}, "
                f"p={self.num_shards}, cap/shard={self.cap_per_shard} "
                f"but this solve has n={n}, p={num_shards}, "
                f"cap/shard={cap_per_shard}; use remap() + the host "
                "edge store for an elastic restore")
        return self

    # -- elastic restore ---------------------------------------------------

    def remap(self, num_shards: int, cap_per_shard: int,
              u: np.ndarray, v: np.ndarray,
              eid: np.ndarray) -> "MSFCheckpoint":
        """Re-key this checkpoint onto a p′-shard mesh (elastic restore).

        ``u`` / ``v`` / ``eid`` are the slot columns of the graph
        *re-partitioned from the host store* at the new shard count
        (``build_dist_graph(..., num_shards=p′)``).  Vertex-keyed state
        re-owner-maps (the flat layout is vid-indexed, so the first
        ``n`` entries transfer verbatim; the tail is identity labels /
        unsettled).  Edge-keyed state is re-derived: the MSF mask marks
        the canonical ``u < v`` copy of every chosen ``eid`` and the
        dead mask is exactly the label-internal edges — a superset of
        the original dead mask that retires the same information, since
        ``alive`` is recomputed as ``ru != rv`` every round anyway.
        The resumed position (level / round / plan_pos / stats) and the
        frozen level windows carry over unchanged.
        """
        self.verify_checksums()
        p2 = int(num_shards)
        vps2 = max(1, -(-self.n // p2))
        u = np.asarray(u)
        v = np.asarray(v)
        eid = np.asarray(eid, np.int32)
        if u.shape[0] != p2 * cap_per_shard:
            raise CheckpointError(
                f"re-partitioned edge arrays have {u.shape[0]} slots, "
                f"expected p'*cap = {p2 * cap_per_shard}")
        lab2 = np.arange(p2 * vps2, dtype=np.int32)
        lab2[:self.n] = self.lab[:self.n]
        settled2 = np.zeros(p2 * vps2, bool)
        settled2[:self.n] = self.settled[:self.n]
        chosen = np.zeros(int(eid.max(initial=0)) + 1, bool)
        chosen[self.eids] = True
        mask2 = chosen[eid] & (u < v)
        dead2 = lab2[np.minimum(u, p2 * vps2 - 1)] == \
            lab2[np.minimum(v, p2 * vps2 - 1)]
        return MSFCheckpoint.create(
            n=self.n, num_shards=p2, cap_per_shard=int(cap_per_shard),
            algorithm=self.algorithm, round_index=self.round_index,
            level=self.level, round_in_level=self.round_in_level,
            plan_pos=self.plan_pos, level_bounds=self.level_bounds,
            lab=lab2, settled=settled2, mask=mask2, dead=dead2,
            eid=eid, ghost_on=self.ghost_on, stats_acc=self.stats_acc)

    # -- introspection -----------------------------------------------------

    @property
    def mst_count(self) -> int:
        return int(self.eids.size)

    def __repr__(self) -> str:  # dataclass default would dump the arrays
        return (f"MSFCheckpoint(n={self.n}, p={self.num_shards}, "
                f"round={self.round_index}, level={self.level}.r"
                f"{self.round_in_level}, plan_pos={self.plan_pos}, "
                f"edges={self.mst_count}, ghost_on={self.ghost_on})")


def latest_certified(ckpts: List[MSFCheckpoint]
                     ) -> Optional[MSFCheckpoint]:
    """The most advanced checkpoint of a ``ckpt_out`` list (the drivers
    only append certified snapshots, so "last" is also "best")."""
    return ckpts[-1] if ckpts else None
