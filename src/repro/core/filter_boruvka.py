"""Filter-Borůvka (Section V of the paper), two engines.

Static engine (jittable, what a TPU executes / what the dry-run lowers):
    Sort edges once by (w, idx).  Quantile pivots make the recursion a
    *static* schedule of equal-size ascending weight buckets; processing
    bucket b with the component labels accumulated from buckets < b is
    exactly Filter-Kruskal's light-then-filtered-heavy order (a batch
    contraction Kruskal), with a Borůvka run as the per-bucket base case.
    Filtering is the relabel gather: an edge inside an already-built
    component becomes a self-loop and is dead for the min-reduction.

Dynamic engine (host-orchestrated, paper-faithful):
    Real recursion with randomly sampled median pivots, true edge
    compaction after filtering (the linear-work claim of Theorem 1), and
    a jitted Borůvka base case on padded-to-power-of-two slices.  Used by
    the CPU benchmarks that mirror the paper's figures.

Both produce the unique MSF under the (w, edge-id) total order and are
property-tested against the Kruskal oracle and each other.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boruvka import boruvka_round
from repro.core import oracle


# --------------------------------------------------------------------------
# Static engine
# --------------------------------------------------------------------------

def _bucket_rounds(bucket: int, n: int) -> int:
    return max(1, math.ceil(math.log2(max(min(2 * bucket, n), 2))) + 1)


@partial(jax.jit, static_argnames=("n", "num_buckets"))
def filter_boruvka_msf(u: jax.Array, v: jax.Array, w: jax.Array, n: int,
                       num_buckets: int = 8
                       ) -> Tuple[jax.Array, jax.Array]:
    """Jittable Filter-Borůvka. Returns (mst_mask[m], labels[n])."""
    m = u.shape[0]
    num_buckets = max(1, min(num_buckets, m))
    bucket = -(-m // num_buckets)
    pad = bucket * num_buckets - m
    order = jnp.argsort(w, stable=True)  # ties broken by index: (w, idx)
    us = jnp.concatenate([u[order], jnp.zeros((pad,), u.dtype)])
    vs = jnp.concatenate([v[order], jnp.zeros((pad,), v.dtype)])
    ws = jnp.concatenate([w[order], jnp.full((pad,), jnp.inf, w.dtype)])

    labels = jnp.arange(n, dtype=jnp.int32)
    mask_sorted = jnp.zeros((num_buckets * bucket,), bool)

    for b in range(num_buckets):  # static schedule of quantile buckets
        sl = slice(b * bucket, (b + 1) * bucket)
        ub, vb, wb = us[sl], vs[sl], ws[sl]
        mb = jnp.zeros((bucket,), bool)

        def cond(s):
            labels_, mb_, changed, r = s
            return changed & (r < _bucket_rounds(bucket, n))

        def body(s):
            labels_, mb_, changed, r = s
            labels_, mb_, changed = boruvka_round(ub, vb, wb, labels_, mb_, n)
            return labels_, mb_, changed, r + 1

        labels, mb, _, _ = jax.lax.while_loop(
            cond, body, (labels, mb, jnp.array(True), jnp.int32(0)))
        mask_sorted = mask_sorted.at[sl].set(mb)

    mask = jnp.zeros((m,), bool).at[order].set(mask_sorted[:m])
    return mask, labels


# --------------------------------------------------------------------------
# Dynamic engine (paper-faithful recursion with compaction)
# --------------------------------------------------------------------------

def _pad_pow2(x: np.ndarray, fill) -> np.ndarray:
    m = len(x)
    cap = 1 << max(4, math.ceil(math.log2(max(m, 1))))
    out = np.full(cap, fill, x.dtype)
    out[:m] = x
    return out


@partial(jax.jit, static_argnames=("n",))
def _base_case(u, v, w, labels, n):
    """Borůvka to completion starting from the running global labels."""
    m = u.shape[0]
    max_rounds = max(1, math.ceil(math.log2(max(min(2 * m, n), 2))) + 1)
    mst = jnp.zeros((m,), bool)

    def cond(s):
        labels_, mst_, changed, r = s
        return changed & (r < max_rounds)

    def body(s):
        labels_, mst_, changed, r = s
        labels_, mst_, changed = boruvka_round(u, v, w, labels_, mst_, n)
        return labels_, mst_, changed, r + 1

    labels, mst, _, _ = jax.lax.while_loop(
        cond, body, (labels, mst, jnp.array(True), jnp.int32(0)))
    return mst, labels


def filter_boruvka_dynamic(u: np.ndarray, v: np.ndarray, w: np.ndarray,
                           n: int, *, sparse_avg_degree: float = 4.0,
                           min_edges: int = 1024,
                           sample_size: int = 512,
                           seed: int = 0,
                           ) -> Tuple[np.ndarray, float]:
    """Host-driven Filter-Borůvka. Returns (mask over input edges, weight).

    Mirrors Algorithm 2: recursive median-of-sample pivoting, filtering of
    heavy edges against the partial MSF's component labels (the global
    distributed array ``P`` is the dense ``labels`` vector here), and a
    Borůvka base case once the graph is sparse (avg degree <= 4) or small.
    """
    rng = np.random.default_rng(seed)
    m = len(u)
    labels = np.arange(n, dtype=np.int32)
    mask = np.zeros(m, bool)
    mst_count = 0

    def base(eu, ev, ew, eidx):
        nonlocal labels, mst_count
        if len(eu) == 0:
            return
        pu = _pad_pow2(eu.astype(np.int32), 0)
        pv = _pad_pow2(ev.astype(np.int32), 0)
        pw = _pad_pow2(ew.astype(np.float32), np.inf)
        sub, labels_j = _base_case(jnp.asarray(pu), jnp.asarray(pv),
                                   jnp.asarray(pw), jnp.asarray(labels), n)
        sub = np.asarray(sub)[:len(eu)]
        labels = np.asarray(labels_j)
        mask[eidx[sub]] = True
        mst_count += int(sub.sum())

    def rec(eu, ev, ew, eidx):
        nonlocal labels
        n_comp = n - mst_count
        if len(eu) <= max(min_edges, sparse_avg_degree * n_comp / 2):
            base(eu, ev, ew, eidx)
            return
        # PivotSelection: median of a random sample (Section V).
        samp = rng.choice(ew, size=min(sample_size, len(ew)), replace=False)
        pivot = float(np.median(samp))
        light = ew <= pivot
        if light.all() or not light.any():  # degenerate pivot: fall back
            base(eu, ev, ew, eidx)
            return
        rec(eu[light], ev[light], ew[light], eidx[light])
        # Filter: drop heavy edges inside components of the partial MSF.
        hu, hv, hw, hidx = eu[~light], ev[~light], ew[~light], eidx[~light]
        ru, rv = labels[hu], labels[hv]
        keep = ru != rv
        # Paper Section VI-C: if filtering removed almost nothing, don't
        # recurse again immediately — just run the base case.
        survivors = (hu[keep], hv[keep], hw[keep], hidx[keep])
        rec(*survivors)

    finite = np.isfinite(w)
    rec(u[finite].astype(np.int32), v[finite].astype(np.int32),
        w[finite].astype(np.float32), np.arange(m)[finite])
    return mask, float(w[mask].sum())


def boruvka_dynamic(u: np.ndarray, v: np.ndarray, w: np.ndarray, n: int
                    ) -> Tuple[np.ndarray, float]:
    """Plain Borůvka through the dynamic-engine plumbing (for benchmarks)."""
    m = len(u)
    finite = np.isfinite(w)
    labels = np.arange(n, dtype=np.int32)
    pu = _pad_pow2(u[finite].astype(np.int32), 0)
    pv = _pad_pow2(v[finite].astype(np.int32), 0)
    pw = _pad_pow2(w[finite].astype(np.float32), np.inf)
    sub, _ = _base_case(jnp.asarray(pu), jnp.asarray(pv), jnp.asarray(pw),
                        jnp.asarray(labels), n)
    sub = np.asarray(sub)[:finite.sum()]
    mask = np.zeros(m, bool)
    mask[np.arange(m)[finite][sub]] = True
    return mask, float(w[mask].sum())


def validate_against_oracle(u, v, w, n, mask) -> bool:
    """Check a computed MSF mask against the Kruskal oracle by weight."""
    _, ow = oracle.kruskal(np.asarray(u), np.asarray(v), np.asarray(w), n)
    got = float(np.asarray(w)[np.asarray(mask)].sum())
    return abs(got - ow) < 1e-4 * max(1.0, abs(ow))
