"""Distributed Borůvka / Filter-Borůvka over a device mesh (Sections IV+V).

Graph representation (paper Section II-B): both directions of every
undirected edge, lexicographically sorted, 1D-partitioned into equal
padded shards.  Every directed copy carries the undirected edge id
``eid`` so that tie-breaking uses the *direction-independent* total order
``(w, eid)`` — without it, equal-weight edges could be ordered differently
by the two endpoints' components and chosen-edge cycles become possible.

Vertex labels are replicated dense vectors (the representation of the
paper's base case, Adler et al., Section IV-D): the per-round segmented
min-edge reduction then becomes per-shard scatter-min + one
``allReduce(min)`` of an n-vector, and pointer doubling is a local
computation.  This is the *baseline* distribution; the sharded-label
variant with the sparse routed exchange (the paper's scalable path for
n >> memory/PE) lives in ``distributed_sharded.py`` and is documented in
EXPERIMENTS.md §Sharded-label engine (version-portability policy for
both engines: EXPERIMENTS.md §Compat).

Pipeline per the paper's Algorithm 1:
  LOCALPREPROCESSING   -> comm-free contraction of provably-local MST
                          edges (shared boundary vertices stay roots)
  rounds:  MINEDGES    -> scatter-min + pmin      (dense allreduce)
           CONTRACT    -> pointer doubling         (replicated, local)
           EXCHANGE    -> one psum label combine after preprocessing
  filter levels        -> weight-interval buckets from sampled pivots
                          (PIVOTSELECTION), light-to-heavy, Section V
  REDISTRIBUTEMST      -> output mask stays aligned with input slots
"""
from __future__ import annotations

import functools
import math
from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.graph import INVALID_W, CapacityError

# "no chosen edge" sentinel in eid space, shared by every engine (and
# distributed_sharded.py) so the (w, eid) total orders can never diverge.
# Host-side np constant: a jnp scalar would initialize the backend at
# import time and lock the device count.
ESENT = np.int32(2 ** 30)


class CommStats(NamedTuple):
    """Per-solve collective-traffic accounting, shared by both mesh
    engines (ISSUE 2: comm counters are the honest metric on one host).

    ``calls``/``items``/``bytes`` cover the per-round collectives
    (MINEDGES / CONTRACT / EXCHANGELABELS and the preprocessing label
    combine); the two one-off result reductions (weight, count) are
    excluded.  The replicated engine counts its dense allreduces, the
    sharded engine counts its routed all-to-alls — same fields, so
    benchmarks can compare the engines like-for-like.  All are
    device-invariant scalars (out_spec P()).

    ``hits``/``misses``/``pushed`` mirror the sharded engine's
    ghost-label-cache counters (``comm/exchange.py: ExchangeStats`` has
    the field-by-field units; ``misses`` doubles as the routed
    endpoint-lookup item count when the cache is off), and ``injected``
    its fault-injection counter (``comm/faults.py``, ISSUE 7; always 0
    outside an active ``FaultPlan``).  They default to 0 so the
    replicated engine — which has no routed exchanges — keeps
    constructing the 4-field view unchanged.
    """
    calls: jax.Array   # [] int32 — collective invocations
    items: jax.Array   # [] f32 — payload items moved (n-vector: n items)
    bytes: jax.Array   # [] f32 — payload bytes moved
    rounds: jax.Array  # [] int32 — Borůvka rounds executed
    hits: jax.Array = np.float32(0.0)    # [] f32 — ghost-cache hits
    misses: jax.Array = np.float32(0.0)  # [] f32 — routed lookup items
    pushed: jax.Array = np.float32(0.0)  # [] f32 — dirty labels pushed
    injected: jax.Array = np.float32(0.0)  # [] f32 — fault-injected items


class DistGraph(NamedTuple):
    """Shard-major padded directed edge arrays ([p * cap])."""
    u: jax.Array
    v: jax.Array
    w: jax.Array
    eid: jax.Array  # undirected edge id shared by both copies

    @property
    def cap_total(self) -> int:
        return int(self.u.shape[0])


def build_dist_graph(u: np.ndarray, v: np.ndarray, w: np.ndarray, n: int,
                     num_shards: int,
                     cap: Optional[int] = None) -> Tuple[DistGraph, int]:
    """Host-side: canonical undirected edges -> doubled, sorted, padded.

    Returns (graph, cap).  ``eid`` is the index into the *undirected*
    input arrays, so a result mask over slots can be reduced back to the
    input edges via eid.

    ``cap`` pins the per-shard slot count instead of the exact
    ``ceil(2m/p)`` (must be >= it): the serving gateway (ISSUE 6) pads
    every request's capacity up to a shared ladder rung so that
    same-family graphs of slightly different edge counts land on one
    array shape — one ``RoundPlan``, one compiled program.  Padding
    slots carry ``INVALID_W`` like any other tail padding.
    """
    m = len(u)
    eid = np.arange(m, dtype=np.int32)
    du = np.concatenate([u, v]).astype(np.int64)
    dv = np.concatenate([v, u]).astype(np.int64)
    dw = np.concatenate([w, w]).astype(np.float32)
    de = np.concatenate([eid, eid])
    order = np.lexsort((dw, dv, du))
    du, dv, dw, de = du[order], dv[order], dw[order], de[order]
    dm = len(du)
    need = max(1, -(-dm // num_shards))
    if cap is None:
        cap = need
    elif cap < need:
        # CapacityError subclasses ValueError, so pre-existing callers
        # catching ValueError (and tests matching "cap") are unaffected
        raise CapacityError(
            f"cap={cap} cannot hold ceil(2m/p)={need} edge slots per "
            f"shard (m={m}, p={num_shards}; "
            f"{dm - cap * num_shards} directed copies would be silently "
            "dropped)", dropped=dm - cap * num_shards)
    uu = np.zeros(num_shards * cap, np.int32)
    vv = np.zeros(num_shards * cap, np.int32)
    ww = np.full(num_shards * cap, INVALID_W, np.float32)
    ee = np.zeros(num_shards * cap, np.int32)
    for s in range(num_shards):
        lo, hi = s * cap, min((s + 1) * cap, dm)
        if hi > lo:
            k = hi - lo
            uu[s * cap: s * cap + k] = du[lo:hi]
            vv[s * cap: s * cap + k] = dv[lo:hi]
            ww[s * cap: s * cap + k] = dw[lo:hi]
            ee[s * cap: s * cap + k] = de[lo:hi]
    return DistGraph(jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ww),
                     jnp.asarray(ee)), cap


# --------------------------------------------------------------------------
# shard-local building blocks (all run inside shard_map)
# --------------------------------------------------------------------------

def _doubling_iters(n: int) -> int:
    return max(1, math.ceil(math.log2(max(n, 2))))


def shrink_schedule(full: int, floor: int = 1) -> Tuple[int, ...]:
    """Geometric halving ladder ``(full, ceil(full/2), ..., floor)``.

    The shared shrink discipline of the repo: Borůvka at least halves the
    number of active components per round, so any per-round quantity that
    is bounded by the active set can be sized from this ladder.  Used by
    ``_distributed_rounds_shrink`` (the dense engine's per-round vector
    sizes) and by the sharded engine's per-round exchange-capacity
    schedule (``distributed_sharded.py``: the static unroll of decreasing
    MINEDGES / lookup / contract capacities).  For ``full >= 2`` the
    ladder has ``ceil(log2(full)) + 1`` rungs — the same count as the
    engines' round bound ``_doubling_iters(full) + 1``.
    """
    out = [max(int(full), floor)]
    while out[-1] > floor:
        out.append(max(-(-out[-1] // 2), floor))
    return tuple(out)


def quantize_capacity(bound: int, full: int, floor: int = 1) -> int:
    """Smallest ``shrink_schedule(full, floor)`` rung ``>= bound``.

    Snapping measured per-round bounds to the ladder keeps the number of
    distinct (and therefore separately compiled) capacity configurations
    logarithmic while never under-sizing a buffer: the rung is an upper
    bound on ``bound``, and a ``bound`` above every rung returns ``full``
    (callers never pass one, but an explicit undersized user capacity
    must stay undersized so its overflow is *reported*, not papered
    over).
    """
    best = max(int(full), floor)
    for rung in shrink_schedule(full, floor):
        if rung >= bound:
            best = rung
        else:
            break
    return best


def _vary(x, axes):
    """pvary only the axes the value is not already varying over."""
    return compat.vary(x, axes)


def _shared_vertex_root_mask(u: jax.Array, valid: jax.Array, n: int,
                             axes: Tuple[str, ...]) -> jax.Array:
    """Dense [n] mask of shared vertices (edge runs straddling shards).

    A vertex whose edges live on two shards is declared a component root
    (Section IV-B) so that no shard contracts "through" it without
    communication.
    """
    cnt = jnp.sum(valid.astype(jnp.int32))
    has = cnt > 0
    first = jnp.where(has, u[0], -1)
    last = jnp.where(has, u[jnp.clip(cnt - 1, 0, u.shape[0] - 1)], -2)
    firsts = lax.all_gather(first, axes, tiled=False).reshape(-1)
    lasts = lax.all_gather(last, axes, tiled=False).reshape(-1)
    p = firsts.shape[0]
    # boundary j|j+1 is shared when shard j's last src == shard j+1's first
    shared = (lasts[:-1] == firsts[1:]) & (lasts[:-1] >= 0)
    shared_ids = jnp.where(shared, lasts[:-1], n)  # n -> dropped
    mask = jnp.zeros((n,), bool).at[shared_ids].set(True, mode="drop")
    return mask, firsts, lasts


def _local_vertex_mask_for_edges(x: jax.Array, firsts, lasts, shard: int,
                                 root_mask_at: jax.Array) -> jax.Array:
    """Is vertex array ``x`` home on this shard and non-shared?"""
    lo = firsts[shard]
    hi = lasts[shard]
    inside = (x >= lo) & (x <= hi) & (lo >= 0)
    return inside & ~root_mask_at


def _local_preprocessing_core(u, v, w, eid, valid, n: int,
                              axes: Tuple[str, ...]):
    """Section IV-A: contract local MST edges without communication.

    Returns this shard's *contribution* (labels[n] deviating from the
    identity only for vertices contracted on this shard — each vertex is
    contracted on at most one shard — and mst_slots[cap] bool).  Callers
    combine contributions their own way: the replicated engine with one
    dense psum(n) (``_local_preprocessing``), the sharded engine with a
    routed label scatter to the owners (distributed_sharded.py), which
    avoids reintroducing the O(n) collective the sharded representation
    exists to avoid.
    """
    cap = u.shape[0]
    shard = lax.axis_index(axes)
    root_mask, firsts, lasts = _shared_vertex_root_mask(u, valid, n, axes)
    local_u = _local_vertex_mask_for_edges(u, firsts, lasts, shard,
                                           root_mask[u])
    local_v = _local_vertex_mask_for_edges(v, firsts, lasts, shard,
                                           root_mask[v])
    local_edge = local_u & local_v & valid

    iota = jnp.arange(n, dtype=jnp.int32)
    sent = jnp.int32(cap)

    def round_(state):
        labels, mst, _, r = state
        ru = labels[u]
        rv = labels[v]
        alive = (ru != rv) & valid
        wk = jnp.where(alive, w, jnp.inf)
        wmin = jnp.full((n,), jnp.inf, w.dtype).at[ru].min(wk).at[rv].min(wk)
        # tie-break by the *global undirected* eid (not the local slot) so
        # the contracted edges are a subset of the unique (w, eid) MSF —
        # the same total order every engine and the oracle use
        esent = ESENT
        at_min_u = jnp.isfinite(wk) & (wk == wmin[ru])
        at_min_v = jnp.isfinite(wk) & (wk == wmin[rv])
        eminid = jnp.full((n,), esent, jnp.int32)
        eminid = eminid.at[ru].min(jnp.where(at_min_u, eid, esent))
        eminid = eminid.at[rv].min(jnp.where(at_min_v, eid, esent))
        slot = jnp.arange(cap, dtype=jnp.int32)
        cu = jnp.where(at_min_u & (eid == eminid[ru]), slot, sent)
        cv = jnp.where(at_min_v & (eid == eminid[rv]), slot, sent)
        emin = jnp.full((n,), sent, jnp.int32).at[ru].min(cu).at[rv].min(cv)
        has = emin < sent
        ce = jnp.clip(emin, 0, cap - 1)
        # contract only if the component's global-min edge is local
        eligible = has & local_edge[ce] & ~root_mask
        emin_m = jnp.where(eligible, emin, sent)
        ce = jnp.clip(emin_m, 0, cap - 1)
        cru = labels[u[ce]]
        crv = labels[v[ce]]
        other = cru + crv - iota
        parent = jnp.where(eligible, other, iota)
        gp = parent[parent]
        parent = jnp.where((gp == iota) & (iota < parent), iota, parent)
        roots = lax.fori_loop(0, _doubling_iters(n), lambda _, p_: p_[p_],
                              parent)
        mst = mst.at[ce].max(eligible.astype(jnp.int32))
        labels = roots[labels]
        return labels, mst, jnp.any(eligible), r + 1

    max_rounds = _doubling_iters(n) + 1

    def cond(state):
        return state[2] & (state[3] < max_rounds)

    labels0 = _vary(iota, axes)
    mst0 = _vary(jnp.zeros((cap,), jnp.int32), axes)
    labels, mst, _, _ = lax.while_loop(
        cond, lambda s: round_(s),
        (labels0, mst0, _vary(jnp.array(True), axes), jnp.int32(0)))
    return labels, mst.astype(bool)


def _local_preprocessing(u, v, w, eid, valid, n: int,
                         axes: Tuple[str, ...]):
    """Replicated combine of the comm-free contraction contributions.

    Returns (labels[n] replicated-consistent, mst_slots[cap] bool).
    One psum(n) label combine at the end (the ghost-label exchange).
    """
    labels, mst = _local_preprocessing_core(u, v, w, eid, valid, n, axes)
    iota = jnp.arange(n, dtype=jnp.int32)
    # EXCHANGELABELS (dense): each vertex is contracted on at most one
    # shard, so summing the deviations from identity merges all shards'
    # label updates in one allreduce.
    labels = lax.psum(labels - iota, axes) + iota
    return labels, mst


def _distributed_rounds(u, v, w, eid, valid, labels, mst, n: int,
                        axes: Tuple[str, ...], active: Optional[jax.Array],
                        max_rounds: int):
    """Borůvka rounds with replicated labels (Sections IV-B..IV-D).

    ``active`` optionally restricts the edge set (the filter levels).
    Chosen-edge marking uses the canonical (u < v) directed copy so each
    undirected MSF edge is marked exactly once across all shards.
    """
    cap = u.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    esent = ESENT

    live = valid if active is None else (valid & active)

    def round_(state):
        labels, mst, _, r = state
        ru = labels[u]
        rv = labels[v]
        alive = (ru != rv) & live
        wk = jnp.where(alive, w, jnp.inf)
        # MINEDGES: per-shard scatter-min + allreduce-min over n-vectors
        wmin_l = jnp.full((n,), jnp.inf, w.dtype).at[ru].min(wk).at[rv].min(wk)
        wmin = lax.pmin(wmin_l, axes)
        cu = jnp.where(jnp.isfinite(wk) & (wk == wmin[ru]), eid, esent)
        cv = jnp.where(jnp.isfinite(wk) & (wk == wmin[rv]), eid, esent)
        emin_l = jnp.full((n,), esent, jnp.int32).at[ru].min(cu).at[rv].min(cv)
        emin = lax.pmin(emin_l, axes)
        has = emin < esent
        # the winning (w, eid) slot(s) on this shard
        win_u = alive & (wk == wmin[ru]) & (eid == emin[ru])
        win_v = alive & (wk == wmin[rv]) & (eid == emin[rv])
        win = win_u | win_v
        # other-endpoint component of each component's chosen edge
        oth_l = jnp.full((n,), -1, jnp.int32)
        oth_l = oth_l.at[ru].max(jnp.where(win_u, rv, -1))
        oth_l = oth_l.at[rv].max(jnp.where(win_v, ru, -1))
        other = lax.pmax(oth_l, axes)
        # CONTRACTCOMPONENTS: replicated pointer doubling
        parent = jnp.where(has & (other >= 0), other, iota)
        gp = parent[parent]
        parent = jnp.where((gp == iota) & (iota < parent), iota, parent)
        roots = lax.fori_loop(0, _doubling_iters(n), lambda _, p_: p_[p_],
                              parent)
        # mark the canonical directed copy exactly once
        mst = mst | (win & (u < v))
        labels = roots[labels]
        return labels, mst, jnp.any(has), r + 1

    def cond(state):
        return state[2] & (state[3] < max_rounds)

    labels, mst, _, r = lax.while_loop(
        cond, round_, (labels, _vary(mst, axes), jnp.array(True),
                       jnp.int32(0)))
    return labels, mst, r


def _weight_pivots(w, valid, num_levels: int, axes: Tuple[str, ...]):
    """PIVOTSELECTION (Section V): global weight quantiles from a sample."""
    cap = w.shape[0]
    s = min(64, cap)
    idx = (jnp.arange(s) * cap) // s
    samp = jnp.where(valid[idx], w[idx], jnp.inf)
    all_samp = jnp.sort(lax.all_gather(samp, axes, tiled=False).reshape(-1))
    nfin = jnp.maximum(jnp.sum(jnp.isfinite(all_samp).astype(jnp.int32)), 1)
    pos = (jnp.arange(1, num_levels) * nfin) // num_levels
    return all_samp[pos]  # [num_levels - 1] ascending pivots


def _distributed_rounds_shrink(u, v, w, eid, valid, labels, mst, n: int,
                               axes: Tuple[str, ...],
                               src_only: bool = False):
    """Beyond-paper §Perf variant: geometrically shrinking dense rounds.

    The replicated-label formulation allReduces O(n)-vectors every round
    => O(n log n) collective volume.  But Borůvka guarantees the number
    of *active* components at round r is <= n / 2^r: a component either
    has no alive edge (done forever — all incident edges internal) or it
    merges.  This variant renumbers the active components into a dense
    prefix after every round (purely local prefix-sum) and allReduces
    arrays of size n/2^r — total volume sum_r n/2^r = 2n, a log2(n)-fold
    reduction of the dominant collective term on large graphs.

    Rounds are Python-unrolled (log2(n)+1), each with static shapes.
    """
    cap = u.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    esent = ESENT
    # per-round vector sizes come from the shared geometric ladder (the
    # halving structure the sharded engine's capacity schedule reuses);
    # for n >= 2 its length equals the old _doubling_iters(n) + 1 round
    # bound.  max(n, 1) — not 2 — so a single-vertex graph's first rung
    # never exceeds the n-sized rep/cid buffers below.
    sizes = shrink_schedule(max(n, 1))
    rounds = len(sizes)

    # active-slot mapping over vertex-label space; initially every vertex
    # label is its own active slot.
    cid = iota  # [n] vertex-label -> active slot (or >= s below)
    rep = iota  # [n-sized buffer] slot -> representative vertex label
    acc_items = 0  # static: allreduced items (3 (s+1)-vectors per round)

    for r, s in enumerate(sizes):
        acc_items += 3 * (s + 1)
        s_next = sizes[r + 1] if r + 1 < rounds else 1
        pad = jnp.int32(s)  # inactive sentinel slot
        ru = jnp.where(valid, cid[labels[u]], pad)
        rv = jnp.where(valid, cid[labels[v]], pad)
        alive = (ru != rv) & valid & (ru < s) & (rv < s)
        wk = jnp.where(alive, w, jnp.inf)
        wmin_l = jnp.full((s + 1,), jnp.inf, w.dtype)
        if src_only:
            # directed both-copy representation: every component sees all
            # of its incident edges as ru somewhere globally, so the
            # rv-side scatters are redundant (§Perf: halves scatter work)
            wmin_l = wmin_l.at[ru].min(wk)
        else:
            wmin_l = wmin_l.at[ru].min(wk).at[rv].min(wk)
        wmin = lax.pmin(wmin_l, axes)
        cu = jnp.where(jnp.isfinite(wk) & (wk == wmin[ru]), eid, esent)
        emin_l = jnp.full((s + 1,), esent, jnp.int32)
        if src_only:
            emin_l = emin_l.at[ru].min(cu)
        else:
            cv = jnp.where(jnp.isfinite(wk) & (wk == wmin[rv]), eid, esent)
            emin_l = emin_l.at[ru].min(cu).at[rv].min(cv)
        emin = lax.pmin(emin_l, axes)
        has = emin[:s] < esent
        win_u = alive & (wk == wmin[ru]) & (eid == emin[ru])
        win_v = alive & (wk == wmin[rv]) & (eid == emin[rv])
        oth_l = jnp.full((s + 1,), -1, jnp.int32)
        if src_only:
            oth_l = oth_l.at[ru].max(jnp.where(win_u, rv, -1))
        else:
            oth_l = oth_l.at[ru].max(jnp.where(win_u, rv, -1))
            oth_l = oth_l.at[rv].max(jnp.where(win_v, ru, -1))
        other = lax.pmax(oth_l, axes)[:s]
        # contraction in slot space (replicated, local)
        sid = jnp.arange(s, dtype=jnp.int32)
        parent = jnp.where(has & (other >= 0), other, sid)
        gp = parent[parent]
        parent = jnp.where((gp == sid) & (sid < parent), sid, parent)
        roots = lax.fori_loop(0, _doubling_iters(s),
                              lambda _, p_: p_[p_], parent)
        mst = mst | ((win_u | win_v) & (u < v))
        # labels: active vertices point at the root slot's representative
        lab_slot = cid[labels]                     # [n]
        act = lab_slot < s
        root_slot = roots[jnp.clip(lab_slot, 0, s - 1)]
        labels = jnp.where(act, rep[root_slot], labels)
        # renumber merged components into [0, s_next)
        merged_root = has[jnp.arange(s)] & (roots == sid)
        # a root slot that merged this round stays active next round
        newid = jnp.cumsum(merged_root.astype(jnp.int32)) - 1
        newid = jnp.where(merged_root, newid, s_next)
        newid = jnp.minimum(newid, s_next)         # overflow-safe clamp
        # map: vertex-label -> next-round slot
        cid_next = jnp.full((n,), jnp.int32(s_next))
        cid_next = cid_next.at[rep[:s]].min(
            jnp.where(merged_root, newid, s_next), mode="drop")
        rep_next = jnp.zeros((n,), jnp.int32)
        rep_next = rep_next.at[jnp.clip(newid, 0, s_next - 1)].max(
            jnp.where(merged_root, rep[:s], 0), mode="drop")
        cid = cid_next
        rep = rep_next
    return labels, mst, rounds, acc_items


# --------------------------------------------------------------------------
# the full per-shard program + host wrapper
# --------------------------------------------------------------------------

def _msf_shard_fn(u, v, w, eid, n: int, axes: Tuple[str, ...],
                  algorithm: str, local_preprocessing: bool,
                  num_levels: int, max_rounds: Optional[int]):
    valid = jnp.isfinite(w)
    iota = jnp.arange(n, dtype=jnp.int32)
    mr = max_rounds or (math.ceil(math.log2(max(n, 2))) + 1)
    p = 1
    for a in axes:
        p *= compat.axis_size(a)
    # analytic-but-threaded collective accounting (CommStats): the dense
    # engine's traffic is fully determined by (n, rounds) — 3 allreduced
    # n-vectors per round (wmin f32, emin i32, other i32)
    calls = jnp.int32(0)
    items = jnp.float32(0.0)
    nbytes = jnp.float32(0.0)
    rounds = jnp.int32(0)

    if local_preprocessing:
        labels, pre_mst = _local_preprocessing(u, v, w, eid, valid, n, axes)
        # psum(n) label combine + the 2 tiny firsts/lasts all_gathers
        calls += 3
        items += jnp.float32(n + 2 * p)
        nbytes += jnp.float32(4 * (n + 2 * p))
    else:
        labels, pre_mst = iota, jnp.zeros(u.shape, bool)

    mst = jnp.zeros(u.shape, bool)
    if algorithm == "boruvka":
        labels, mst, r = _distributed_rounds(u, v, w, eid, valid, labels,
                                             mst, n, axes, None, mr)
        rounds += r
        calls += 3 * r
        items += 3.0 * n * r.astype(jnp.float32)
        nbytes += 12.0 * n * r.astype(jnp.float32)
    elif algorithm in ("boruvka_shrink", "boruvka_shrink_srconly"):
        mst = _vary(mst, axes)
        labels, mst, r, acc = _distributed_rounds_shrink(
            u, v, w, eid, valid, labels, mst, n, axes,
            src_only=algorithm.endswith("srconly"))
        rounds += r
        calls += 3 * r
        items += jnp.float32(acc)
        nbytes += jnp.float32(4 * acc)
    elif algorithm == "filter_boruvka":
        pivots = _weight_pivots(w, valid, num_levels, axes)
        calls += 1
        items += jnp.float32(64 * p)
        nbytes += jnp.float32(4 * 64 * p)
        lo = jnp.float32(-jnp.inf)
        for lvl in range(num_levels):
            hi = pivots[lvl] if lvl < num_levels - 1 else jnp.float32(jnp.inf)
            active = (w > lo) & (w <= hi)
            labels, mst, r = _distributed_rounds(u, v, w, eid, valid, labels,
                                                 mst, n, axes, active, mr)
            rounds += r
            calls += 3 * r
            items += 3.0 * n * r.astype(jnp.float32)
            nbytes += 12.0 * n * r.astype(jnp.float32)
            lo = hi
    else:
        raise ValueError(algorithm)

    # local-preprocessing MST edges were marked per chosen slot; distributed
    # rounds mark canonical copies.  Both mark each undirected edge once.
    full_mask = mst | pre_mst
    weight = lax.psum(jnp.sum(jnp.where(full_mask, w, 0.0)), axes)
    count = lax.psum(jnp.sum(full_mask.astype(jnp.int32)), axes)
    stats = CommStats(calls, items, nbytes, rounds)
    return full_mask, weight, count, labels, stats


@functools.lru_cache(maxsize=64)
def _build_msf_fn(n: int, mesh: jax.sharding.Mesh, axes: Tuple[str, ...],
                  algorithm: str, local_preprocessing: bool,
                  num_levels: int, max_rounds: Optional[int]):
    fn = partial(_msf_shard_fn, n=n, axes=axes, algorithm=algorithm,
                 local_preprocessing=local_preprocessing,
                 num_levels=num_levels, max_rounds=max_rounds)
    spec = P(axes)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, P(), P(), P(), P())))


def distributed_msf(graph: DistGraph, n: int, mesh: jax.sharding.Mesh,
                    *, algorithm: str = "boruvka",
                    axis_names: Optional[Sequence[str]] = None,
                    local_preprocessing: bool = True,
                    num_levels: int = 4,
                    max_rounds: Optional[int] = None):
    """Run the distributed MSF on a mesh.

    Returns (mask, weight, count, labels, stats): ``mask`` is aligned
    with ``graph`` slots (one canonical directed copy per MSF edge
    marked); ``stats`` is a ``CommStats`` of the per-round collective
    traffic.  The jitted program is cached per (n, mesh, options) so
    repeated solves only pay tracing once.
    """
    axes = tuple(axis_names or mesh.axis_names)
    shard_fn = _build_msf_fn(n, mesh, axes, algorithm, local_preprocessing,
                             num_levels, max_rounds)
    return shard_fn(graph.u, graph.v, graph.w, graph.eid)


def make_mst_step(n: int, cap_total: int, mesh: jax.sharding.Mesh,
                  algorithm: str = "boruvka", **kw):
    """AOT-lowerable distributed MSF step for the dry-run/roofline harness."""
    def step(u, v, w, eid):
        g = DistGraph(u, v, w, eid)
        return distributed_msf(g, n, mesh, algorithm=algorithm, **kw)

    specs = (
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
        jax.ShapeDtypeStruct((cap_total,), jnp.float32),
        jax.ShapeDtypeStruct((cap_total,), jnp.int32),
    )
    return step, specs
