"""Parameter / activation partition rules for the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model")
multi-pod.  Megatron-style tensor parallelism over "model"; DP over
("pod", "data"); MoE experts sharded over "model" with the hidden dim of
expert weights additionally sharded over "data" (weight-gathered /
FSDP-style storage — the all-gather is re-materialised per layer, which
is what makes the 236B/400B MoE param + optimizer state fit per chip).

Rules are by parameter path leaf name — the whole tree is mapped in one
pass, with the layer-stack leading dim always unsharded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# leaf-name -> spec builder; `st` is True when the leaf has a leading
# layer-stack dim (prepend None)
_RULES: Dict[str, Tuple] = {
    # attention (column-parallel QKV, row-parallel out)
    "wq": (None, "model", None),
    "wk": (None, "model", None),
    "wv": (None, "model", None),
    "wo": ("model", None, None),
    "bq": ("model", None),
    "bk": ("model", None),
    "bv": ("model", None),
    # MLA
    "wq_a": (None, "model"),
    "wq_b": (None, "model", None),
    "wkv_a": (None, None),
    "wkv_b": (None, "model", None),
    "q_norm": (None,),
    "kv_norm": (None,),
    # dense mlp
    "wg": (None, "model"),
    "wu": (None, "model"),
    "wd": ("model", None),
    "wi": (None, "model"),
    "bi": ("model",),
    # mamba
    "in_proj": (None, "model"),
    "out_proj": ("model", None),
    "conv_w": (None, "model"),
    "A_log": ("model",),
    "D": ("model",),
    "dt_bias": ("model",),
    "norm": ("model",),
    # embeddings
    "embed": ("model", None),
    "unembed": (None, "model"),
    "enc_pos": (None, None),
    "dec_pos": (None, None),
}

# expert-weight overrides (leaf names inside a "moe" subtree): E over
# "model", hidden dim over "data" (gathered at use — ZeRO-3 for experts)
_MOE_RULES: Dict[str, Tuple] = {
    "router": (None, None),
    "wg": ("model", None, "data"),
    "wu": ("model", None, "data"),
    "wd": ("model", "data", None),
    "shared_wg": (None, "model"),
    "shared_wu": (None, "model"),
    "shared_wd": ("model", None),
}


def _spec_for(path, leaf) -> P:
    names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    leaf_name = names[-1]
    in_moe = any(n == "moe" for n in names[:-1])
    rules = _MOE_RULES if (in_moe and leaf_name in _MOE_RULES) else _RULES
    rule = rules.get(leaf_name)
    ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    if rule is None:
        return P()  # norms, scalars: replicated
    rule = tuple(rule)
    if len(rule) < ndim:  # leading layer-stack dim(s): unsharded
        rule = (None,) * (ndim - len(rule)) + rule
    elif len(rule) > ndim:
        rule = rule[-ndim:] if ndim else ()
    # drop axes that would not divide evenly — checked at placement time
    return P(*rule)


def param_specs(params: Any) -> Any:
    """PartitionSpec tree parallel to the parameter tree."""
    return jax.tree_util.tree_map_with_path(_spec_for, params)


def _valid(spec: P, shape, mesh: Mesh) -> P:
    """Clear axes that do not divide the corresponding dim."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            out.append(None)
            continue
        size = mesh.shape[ax] if isinstance(ax, str) else 1
        out.append(ax if dim % size == 0 and dim >= size else None)
    return P(*out)


def valid_param_specs(params: Any, mesh: Mesh) -> Any:
    """Partition specs with non-dividing axes cleared for this mesh."""
    specs = param_specs(params)
    return jax.tree.map(
        lambda leaf, spec: _valid(spec, leaf.shape, mesh), params, specs)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        valid_param_specs(params, mesh))


def batch_spec(mesh: Mesh) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(dp)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def cache_spec(mesh: Mesh) -> P:
    """KV caches: batch over DP axes, heads over model."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(None, dp, None, "model", None)


def activation_spec(mesh: Mesh) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(dp, None, None)
