"""Model assembly: init / train-forward / prefill / decode per family.

Layer stacks are scanned (``lax.scan`` over parameter stacks with
``jax.checkpoint`` remat) so the lowered HLO stays one-layer-sized — this
is what keeps 80-layer × 512-device AOT compiles tractable and is also the
production choice (less HLO, better XLA scheduling).

Families:
  dense / vlm      — [ln, GQA, ln, SwiGLU] x L  (vlm: patch-prefix stub)
  moe              — GQA + (routed experts | dense) per the layer pattern
  ssm              — Mamba2 mixer x L
  hybrid (zamba2)  — Mamba2 backbone + one *shared-weight* attention block
                     applied every ``shared_attn_every`` layers
  audio (whisper)  — encoder (bidirectional, learned pos, GELU) + decoder
                     (causal self-attn + cross-attn); conv frontend is a
                     stub: encoder consumes precomputed frame embeddings
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models.layers import (KVCache, MLACache, QuantKVCache,
                                 causal_mask, gelu_mlp, gqa_attention,
                                 layernorm, mla_attention, rmsnorm, swiglu)
from repro.models.ssm import SSMState, mamba2_block, ssm_dims


@dataclasses.dataclass(frozen=True)
class MeshContext:
    mesh: Any
    dp_axes: Tuple[str, ...]
    ep_axes: Tuple[str, ...]

    @property
    def ep_size(self) -> int:
        s = 1
        for a in self.ep_axes:
            s *= self.mesh.shape[a]
        return s


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _dense(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def _attn_params(cfg: ModelConfig, key, L: Optional[int], dt) -> Dict:
    H, KV, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.d_model
    pre = (L,) if L is not None else ()
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense(ks[0], pre + (D, H, hd), dt),
        "wk": _dense(ks[1], pre + (D, KV, hd), dt),
        "wv": _dense(ks[2], pre + (D, KV, hd), dt),
        "wo": _dense(ks[3], pre + (H, hd, D), dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros(pre + (H, hd), dt)
        p["bk"] = jnp.zeros(pre + (KV, hd), dt)
        p["bv"] = jnp.zeros(pre + (KV, hd), dt)
    return p


def _mla_params(cfg: ModelConfig, key, L: Optional[int], dt) -> Dict:
    D, H, hd, r = cfg.d_model, cfg.num_heads, cfg.hd, cfg.rope_head_dim
    lo, qlo = cfg.kv_lora_rank, cfg.q_lora_rank
    pre = (L,) if L is not None else ()
    ks = jax.random.split(key, 6)
    return {
        "wq_a": _dense(ks[0], pre + (D, qlo), dt),
        "q_norm": jnp.ones(pre + (qlo,), dt),
        "wq_b": _dense(ks[1], pre + (qlo, H, hd + r), dt),
        "wkv_a": _dense(ks[2], pre + (D, lo + r), dt),
        "kv_norm": jnp.ones(pre + (lo,), dt),
        "wkv_b": _dense(ks[3], pre + (lo, H, 2 * hd), dt),
        "wo": _dense(ks[4], pre + (H, hd, D), dt),
    }


def _mlp_params(cfg: ModelConfig, key, L: Optional[int], dt,
                d_ff: Optional[int] = None) -> Dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    pre = (L,) if L is not None else ()
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense(ks[0], pre + (D, F), dt),
        "wu": _dense(ks[1], pre + (D, F), dt),
        "wd": _dense(ks[2], pre + (F, D), dt),
    }


def _moe_params(cfg: ModelConfig, key, L: Optional[int], dt) -> Dict:
    D, E, Fe = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    pre = (L,) if L is not None else ()
    ks = jax.random.split(key, 7)
    p = {
        "router": _dense(ks[0], pre + (D, E), jnp.float32),
        "wg": _dense(ks[1], pre + (E, D, Fe), dt),
        "wu": _dense(ks[2], pre + (E, D, Fe), dt),
        "wd": _dense(ks[3], pre + (E, Fe, D), dt),
    }
    if cfg.num_shared_experts:
        Fs = cfg.num_shared_experts * Fe
        p["shared_wg"] = _dense(ks[4], pre + (D, Fs), dt)
        p["shared_wu"] = _dense(ks[5], pre + (D, Fs), dt)
        p["shared_wd"] = _dense(ks[6], pre + (Fs, D), dt)
    return p


def _mamba_params(cfg: ModelConfig, key, L: Optional[int], dt) -> Dict:
    H, Pd, N = ssm_dims(cfg)
    D = cfg.d_model
    inner = H * Pd
    proj_out = 2 * inner + 2 * N + H
    conv_ch = inner + 2 * N
    pre = (L,) if L is not None else ()
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense(ks[0], pre + (D, proj_out), dt),
        "conv_w": _dense(ks[1], pre + (cfg.conv_width, conv_ch), dt, 0.2),
        "dt_bias": jnp.zeros(pre + (H,), jnp.float32),
        "A_log": jnp.zeros(pre + (H,), jnp.float32),
        "D": jnp.ones(pre + (H,), dt),
        "norm": jnp.ones(pre + (inner,), dt),
        "out_proj": _dense(ks[2], pre + (inner, D), dt),
    }


def _norm(pre, D, dt):
    return jnp.ones(pre + (D,), dt)


def layer_pattern(cfg: ModelConfig) -> Sequence[str]:
    """Per-layer kind for MoE stacks: 'dense' | 'moe'."""
    if not cfg.is_moe:
        return ["dense"] * cfg.num_layers
    pat = []
    moe_every = cfg.moe_every
    for i in range(cfg.num_layers):
        if i < cfg.first_dense_layers:
            pat.append("dense")
        elif (i - cfg.first_dense_layers) % moe_every == moe_every - 1 \
                or moe_every == 1:
            pat.append("moe")
        else:
            pat.append("dense")
    return pat


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    dt = cfg.jdtype
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    ks = jax.random.split(key, 16)
    params: Dict[str, Any] = {
        "embed": _dense(ks[0], (V, D), dt, 1.0),
        "unembed": _dense(ks[1], (D, V), dt),
        "final_norm": jnp.ones((D,), dt),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = {
            "ln1": _norm((L,), D, dt),
            "ln2": _norm((L,), D, dt),
            "attn": _attn_params(cfg, ks[2], L, dt),
            "mlp": _mlp_params(cfg, ks[3], L, dt),
        }
    elif fam == "moe":
        pat = layer_pattern(cfg)
        nd = sum(1 for k in pat if k == "dense")
        nm = L - nd
        attn_fn = _mla_params if cfg.kv_lora_rank else _attn_params
        if nd:
            params["dense_blocks"] = {
                "ln1": _norm((nd,), D, dt),
                "ln2": _norm((nd,), D, dt),
                "attn": attn_fn(cfg, ks[2], nd, dt),
                "mlp": _mlp_params(cfg, ks[3], nd, dt),
            }
        params["moe_blocks"] = {
            "ln1": _norm((nm,), D, dt),
            "ln2": _norm((nm,), D, dt),
            "attn": attn_fn(cfg, ks[4], nm, dt),
            "moe": _moe_params(cfg, ks[5], nm, dt),
        }
    elif fam == "ssm":
        params["blocks"] = {
            "ln": _norm((L,), D, dt),
            "mixer": _mamba_params(cfg, ks[2], L, dt),
        }
    elif fam == "hybrid":
        params["blocks"] = {
            "ln": _norm((L,), D, dt),
            "mixer": _mamba_params(cfg, ks[2], L, dt),
        }
        params["shared_attn"] = {
            "ln1": _norm((), D, dt),
            "ln2": _norm((), D, dt),
            "attn": _attn_params(cfg, ks[3], None, dt),
            "mlp": _mlp_params(cfg, ks[4], None, dt),
        }
    elif fam == "audio":
        Le = cfg.encoder_layers
        params["enc_pos"] = _dense(ks[6], (cfg.frontend_len, D), dt)
        # whisper publishes 448 learned positions; the assigned decode
        # cells need 32k — the table is enlarged structurally (DESIGN.md)
        params["dec_pos"] = _dense(ks[7], (32768, D), dt)
        params["enc_blocks"] = {
            "ln1": _norm((Le,), D, dt),
            "ln2": _norm((Le,), D, dt),
            "attn": _attn_params(cfg, ks[2], Le, dt),
            "mlp": {
                "wi": _dense(ks[8], (Le, D, cfg.d_ff), dt),
                "bi": jnp.zeros((Le, cfg.d_ff), dt),
                "wo": _dense(ks[9], (Le, cfg.d_ff, D), dt),
                "bo": jnp.zeros((Le, D), dt),
            },
        }
        params["enc_final_norm"] = jnp.ones((D,), dt)
        params["dec_blocks"] = {
            "ln1": _norm((L,), D, dt),
            "ln_x": _norm((L,), D, dt),
            "ln2": _norm((L,), D, dt),
            "attn": _attn_params(cfg, ks[3], L, dt),
            "xattn": _attn_params(cfg, ks[4], L, dt),
            "mlp": {
                "wi": _dense(ks[10], (L, D, cfg.d_ff), dt),
                "bi": jnp.zeros((L, cfg.d_ff), dt),
                "wo": _dense(ks[11], (L, cfg.d_ff, D), dt),
                "bo": jnp.zeros((L, D), dt),
            },
        }
    else:
        raise ValueError(fam)
    return params


# --------------------------------------------------------------------------
# block applications
# --------------------------------------------------------------------------

def _dense_block(cfg, lp, x, positions, cache=None, cache_pos=None):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    attn = mla_attention if cfg.kv_lora_rank else gqa_attention
    attn_out, new_cache = attn(cfg, lp["attn"], h, positions,
                               cache=cache, cache_pos=cache_pos)
    if cfg.parallel_block:
        mlp_out = swiglu(h, **{k: lp["mlp"][k] for k in ("wg", "wu", "wd")})
        return x + attn_out + mlp_out, new_cache
    x = x + attn_out
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    x = x + swiglu(h2, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    return x, new_cache


def _moe_block(cfg, lp, x, positions, mesh_ctx, cache=None, cache_pos=None):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.kv_lora_rank:
        attn_out, new_cache = mla_attention(cfg, lp["attn"], h, positions,
                                            cache=cache, cache_pos=cache_pos)
    else:
        attn_out, new_cache = gqa_attention(cfg, lp["attn"], h, positions,
                                            cache=cache, cache_pos=cache_pos)
    x = x + attn_out
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    x = x + moe_lib.moe_apply(cfg, lp["moe"], h2, mesh_ctx)
    return x, new_cache


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def _embed(cfg, params, tokens, extras):
    x = params["embed"][tokens].astype(cfg.jdtype)
    if cfg.frontend == "patch" and extras is not None \
            and "patch_embeds" in extras and tokens.shape[1] > 1:
        fl = cfg.frontend_len
        x = x.at[:, :fl].set(extras["patch_embeds"].astype(x.dtype))
    return x


def forward_train(cfg: ModelConfig, params: Dict, batch: Dict,
                  mesh_ctx: Optional[MeshContext] = None) -> jax.Array:
    """Next-token cross-entropy loss (fp32 accumulation)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    if cfg.family == "audio":
        logits = _whisper_logits(cfg, params, batch)
    else:
        x = _embed(cfg, params, tokens, batch)
        x = _backbone(cfg, params, x, positions, mesh_ctx)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["unembed"].astype(x.dtype))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _backbone(cfg, params, x, positions, mesh_ctx, caches=None,
              cache_pos=None):
    """Returns hidden states (and new caches when decoding)."""
    fam = cfg.family
    new_caches = None
    if fam in ("dense", "vlm"):
        fn = lambda lp, h, c: _dense_block(cfg, lp, h, positions, c,
                                           cache_pos)
        x, new_caches = _scan_with_caches(fn, params["blocks"], x, caches,
                                          unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
    elif fam == "moe":
        pat = layer_pattern(cfg)
        x, new_caches = _moe_backbone(cfg, params, x, positions, mesh_ctx,
                                      pat, caches, cache_pos)
    elif fam == "ssm":
        fn = lambda lp, h, c: _mamba_layer(cfg, lp, h, c)
        x, new_caches = _scan_with_caches(fn, params["blocks"], x, caches,
                                          unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
    elif fam == "hybrid":
        x, new_caches = _zamba_backbone(cfg, params, x, positions, caches,
                                        cache_pos)
    else:
        raise ValueError(fam)
    if caches is None:
        return x
    return x, new_caches


def _remat_policy(name: str):
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def _scan_with_caches(fn, stack, x, caches, unroll: bool = False,
                      policy=None):
    def body(carry, inp):
        lp, cache = inp
        y, nc = fn(lp, carry, cache)
        return y, nc

    body = jax.checkpoint(body, policy=policy)
    if caches is None:
        def body_nc(carry, lp):
            y, _ = fn(lp, carry, None)
            return y, None
        x, _ = lax.scan(jax.checkpoint(body_nc, policy=policy), x, stack,
                        unroll=unroll)
        return x, None
    x, ncaches = lax.scan(body, x, (stack, caches), unroll=unroll)
    return x, ncaches


def _mamba_layer(cfg, lp, x, state):
    h = rmsnorm(x, lp["ln"], cfg.norm_eps)
    out, new_state = mamba2_block(cfg, lp["mixer"], h, state)
    return x + out, new_state


def _zamba_backbone(cfg, params, x, positions, caches, cache_pos):
    """Mamba2 stack with a shared attention block every k layers.

    Python-level loop (38 layers): the shared block's weights are reused
    at every site but each site has its own KV cache.
    """
    every = cfg.shared_attn_every
    L = cfg.num_layers
    stack = params["blocks"]
    sp = params["shared_attn"]
    site = 0
    new_states = []
    new_kv = []
    for i in range(L):
        lp = jax.tree.map(lambda a: a[i], stack)
        st = None if caches is None else \
            jax.tree.map(lambda a: a[i], caches["ssm"])
        x, ns = _mamba_layer(cfg, lp, x, st)
        if ns is not None:
            new_states.append(ns)
        if every and (i % every == every - 1):
            kv = None if caches is None else \
                jax.tree.map(lambda a: a[site], caches["attn"])
            h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
            att, nkv = gqa_attention(cfg, sp["attn"], h, positions,
                                     cache=kv, cache_pos=cache_pos)
            x = x + att
            h2 = rmsnorm(x, sp["ln2"], cfg.norm_eps)
            x = x + swiglu(h2, sp["mlp"]["wg"], sp["mlp"]["wu"],
                           sp["mlp"]["wd"])
            if nkv is not None:
                new_kv.append(nkv)
            site += 1
    if caches is None:
        return x, None
    stacked = {
        "ssm": jax.tree.map(lambda *a: jnp.stack(a), *new_states),
        "attn": jax.tree.map(lambda *a: jnp.stack(a), *new_kv),
    }
    return x, stacked


def _moe_backbone(cfg, params, x, positions, mesh_ctx, pat, caches,
                  cache_pos):
    """Dense/MoE interleave: scan homogeneous runs, unroll transitions."""
    runs = []  # (kind, start, length)
    i = 0
    while i < len(pat):
        j = i
        while j < len(pat) and pat[j] == pat[i]:
            j += 1
        runs.append((pat[i], i, j - i))
        i = j
    # alternating patterns (llama4) produce L runs of length 1; pair them
    if len(runs) > 4 and all(r[2] == 1 for r in runs):
        return _moe_paired(cfg, params, x, positions, mesh_ctx, pat,
                           caches, cache_pos)
    di = mi = 0
    new_dense_c, new_moe_c = [], []
    out_caches = {} if caches is not None else None
    for kind, start, length in runs:
        if kind == "dense":
            stack = jax.tree.map(lambda a: a[di:di + length],
                                 params["dense_blocks"])
            sub = None if caches is None else \
                jax.tree.map(lambda a: a[di:di + length], caches["dense"])
            fn = lambda lp, h, c: _dense_block(cfg, lp, h, positions, c,
                                               cache_pos)
            x, nc = _scan_with_caches(fn, stack, x, sub,
                                       unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
            if nc is not None:
                new_dense_c.append(nc)
            di += length
        else:
            stack = jax.tree.map(lambda a: a[mi:mi + length],
                                 params["moe_blocks"])
            sub = None if caches is None else \
                jax.tree.map(lambda a: a[mi:mi + length], caches["moe"])
            fn = lambda lp, h, c: _moe_block(cfg, lp, h, positions,
                                             mesh_ctx, c, cache_pos)
            x, nc = _scan_with_caches(fn, stack, x, sub,
                                       unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
            if nc is not None:
                new_moe_c.append(nc)
            mi += length
    if caches is None:
        return x, None
    cat = lambda parts: jax.tree.map(
        lambda *a: jnp.concatenate(a, axis=0), *parts) if parts else None
    out_caches = {"dense": cat(new_dense_c), "moe": cat(new_moe_c)}
    out_caches = {k: v for k, v in out_caches.items() if v is not None}
    return x, out_caches


def _moe_paired(cfg, params, x, positions, mesh_ctx, pat, caches,
                cache_pos):
    """(dense, moe) repeating unit scanned as pairs (llama4 interleave)."""
    nd = sum(1 for k in pat if k == "dense")
    pairs = nd

    def pair_fn(lp, h, c):
        dc = None if c is None else c["dense"]
        mc = None if c is None else c["moe"]
        h, ndc = _dense_block(cfg, lp["dense"], h, positions, dc, cache_pos)
        h, nmc = _moe_block(cfg, lp["moe"], h, positions, mesh_ctx, mc,
                            cache_pos)
        if c is None:
            return h, None
        return h, {"dense": ndc, "moe": nmc}

    stack = {"dense": params["dense_blocks"], "moe": params["moe_blocks"]}
    sub = None if caches is None else caches
    x, nc = _scan_with_caches(pair_fn, stack, x, sub,
                              unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
    return x, nc


def _whisper_logits(cfg, params, batch):
    frames = batch["frames"].astype(cfg.jdtype)   # [B, Tf, D] stub
    tokens = batch["tokens"]
    B, S = tokens.shape
    Tf = frames.shape[1]
    enc = frames + params["enc_pos"][None, :Tf].astype(frames.dtype)
    enc_pos = jnp.arange(Tf, dtype=jnp.int32)[None]

    def enc_fn(lp, h, _):
        hn = layernorm(h, lp["ln1"], jnp.zeros_like(lp["ln1"]), cfg.norm_eps)
        att, _ = gqa_attention(cfg, lp["attn"], hn, enc_pos, causal=False,
                               use_rope=False)
        h = h + att
        hn = layernorm(h, lp["ln2"], jnp.zeros_like(lp["ln2"]), cfg.norm_eps)
        h = h + gelu_mlp(hn, lp["mlp"]["wi"], lp["mlp"]["bi"],
                         lp["mlp"]["wo"], lp["mlp"]["bo"])
        return h, None

    enc, _ = _scan_with_caches(enc_fn, params["enc_blocks"], enc, None,
                               unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
    enc = layernorm(enc, params["enc_final_norm"],
                    jnp.zeros_like(params["enc_final_norm"]), cfg.norm_eps)

    x = params["embed"][tokens].astype(cfg.jdtype)
    x = x + params["dec_pos"][None, :S].astype(x.dtype)
    dpos = jnp.arange(S, dtype=jnp.int32)[None]

    def dec_fn(lp, h, _):
        hn = layernorm(h, lp["ln1"], jnp.zeros_like(lp["ln1"]), cfg.norm_eps)
        att, _ = gqa_attention(cfg, lp["attn"], hn, dpos, causal=True,
                               use_rope=False)
        h = h + att
        hn = layernorm(h, lp["ln_x"], jnp.zeros_like(lp["ln_x"]),
                       cfg.norm_eps)
        xatt, _ = gqa_attention(cfg, lp["xattn"], hn, dpos, kv_source=enc,
                                use_rope=False)
        h = h + xatt
        hn = layernorm(h, lp["ln2"], jnp.zeros_like(lp["ln2"]), cfg.norm_eps)
        h = h + gelu_mlp(hn, lp["mlp"]["wi"], lp["mlp"]["bi"],
                         lp["mlp"]["wo"], lp["mlp"]["bo"])
        return h, None

    x, _ = _scan_with_caches(dec_fn, params["dec_blocks"], x, None,
                             unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
    x = layernorm(x, params["final_norm"],
                  jnp.zeros_like(params["final_norm"]), cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))


# --------------------------------------------------------------------------
# serving: cache init, prefill, decode
# --------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, B: int, T: int) -> Any:
    dt = cfg.jdtype
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    L = cfg.num_layers

    def kv(n):
        if cfg.kv_cache_dtype == "int8":
            return QuantKVCache(
                jnp.zeros((n, B, T, KV, hd), jnp.int8),
                jnp.zeros((n, B, T, KV), jnp.float32),
                jnp.zeros((n, B, T, KV, hd), jnp.int8),
                jnp.zeros((n, B, T, KV), jnp.float32))
        return KVCache(jnp.zeros((n, B, T, KV, hd), dt),
                       jnp.zeros((n, B, T, KV, hd), dt))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        return kv(L)
    if fam == "moe":
        pat = layer_pattern(cfg)
        nd = sum(1 for k in pat if k == "dense")
        nm = L - nd
        if cfg.kv_lora_rank:
            mk = lambda n: MLACache(jnp.zeros(
                (n, B, T, cfg.kv_lora_rank + cfg.rope_head_dim), dt))
        else:
            mk = kv
        out = {"moe": mk(nm)}
        if nd:
            out["dense"] = mk(nd)
        return out
    if fam == "ssm":
        Hh, Pd, N = ssm_dims(cfg)
        conv_ch = Hh * Pd + 2 * N
        return SSMState(jnp.zeros((L, B, Hh, Pd, N), dt),
                        jnp.zeros((L, B, cfg.conv_width - 1, conv_ch), dt))
    if fam == "hybrid":
        Hh, Pd, N = ssm_dims(cfg)
        conv_ch = Hh * Pd + 2 * N
        sites = sum(1 for i in range(L)
                    if cfg.shared_attn_every
                    and i % cfg.shared_attn_every == cfg.shared_attn_every - 1)
        return {
            "ssm": SSMState(jnp.zeros((L, B, Hh, Pd, N), dt),
                            jnp.zeros((L, B, cfg.conv_width - 1, conv_ch),
                                      dt)),
            "attn": kv(max(sites, 1)),
        }
    if fam == "audio":
        return {"self": kv(L),
                "enc": jnp.zeros((B, cfg.frontend_len, cfg.d_model), dt)}
    raise ValueError(fam)


def forward_decode(cfg: ModelConfig, params: Dict, caches: Any,
                   tokens: jax.Array, pos: jax.Array,
                   mesh_ctx: Optional[MeshContext] = None
                   ) -> Tuple[jax.Array, Any]:
    """One decode step. tokens [B] int32, pos [B] int32 (write position)."""
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None].astype(cfg.jdtype)  # [B,1,D]
    positions = pos[:, None]

    if cfg.family == "audio":
        enc = caches["enc"]
        dpos = positions

        def dec_fn(lp, h, c):
            hn = layernorm(h, lp["ln1"], jnp.zeros_like(lp["ln1"]),
                           cfg.norm_eps)
            att, nc = gqa_attention(cfg, lp["attn"], hn, dpos, cache=c,
                                    cache_pos=pos, use_rope=False)
            h = h + att
            hn = layernorm(h, lp["ln_x"], jnp.zeros_like(lp["ln_x"]),
                           cfg.norm_eps)
            xatt, _ = gqa_attention(cfg, lp["xattn"], hn, dpos,
                                    kv_source=enc, use_rope=False)
            h = h + xatt
            hn = layernorm(h, lp["ln2"], jnp.zeros_like(lp["ln2"]),
                           cfg.norm_eps)
            h = h + gelu_mlp(hn, lp["mlp"]["wi"], lp["mlp"]["bi"],
                             lp["mlp"]["wo"], lp["mlp"]["bo"])
            return h, nc

        x = x + params["dec_pos"][pos][:, None].astype(x.dtype)
        x, nkv = _scan_with_caches(dec_fn, params["dec_blocks"], x,
                                   caches["self"],
                                   unroll=cfg.scan_unroll,
                                     policy=_remat_policy(cfg.remat_policy))
        x = layernorm(x, params["final_norm"],
                      jnp.zeros_like(params["final_norm"]), cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["unembed"].astype(x.dtype))[:, 0]
        return logits, {"self": nkv, "enc": enc}

    x, new_caches = _backbone(cfg, params, x, positions, mesh_ctx,
                              caches=caches, cache_pos=pos)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["unembed"].astype(x.dtype))[:, 0]
    return logits, new_caches


def forward_prefill(cfg: ModelConfig, params: Dict, batch: Dict,
                    mesh_ctx: Optional[MeshContext] = None) -> jax.Array:
    """Prefill: full forward, last-position logits (cache-build regime)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    if cfg.family == "audio":
        logits = _whisper_logits(cfg, params, batch)
        return logits[:, -1]
    x = _embed(cfg, params, tokens, batch)
    x = _backbone(cfg, params, x, positions, mesh_ctx)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x,
                      params["unembed"].astype(x.dtype))[:, 0]
