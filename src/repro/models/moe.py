"""Mixture-of-Experts with the paper's sparse-exchange machinery.

Token->expert dispatch *is* a capacity-bounded sparse all-to-all — the
same communication problem the paper engineers for MST label exchange
(Section VI-A).  This module therefore reuses the comm layer:

  * ``moe_local``    — single-program reference: per-expert capacity
    buckets built with the exact positioning logic of
    ``comm.exchange._group_positions``; no collectives.  Used for smoke
    tests and as the oracle for the distributed path.
  * ``moe_dispatch`` — expert-parallel shard_map path: tokens are routed
    to the expert's home device with per-expert capacity buckets through
    one all-to-all each way.  ``dispatch="grid"`` routes both hops with
    the paper's two-level grid schedule when the expert axis spans >= 2
    mesh axes (the O(alpha*sqrt(p)) startup trick).

Over-capacity tokens are dropped from the expert and pass through the
residual (standard MoE semantics; drop counts are observable).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm.exchange import _group_positions
from repro.comm.grid_alltoall import all_to_all_nd
from repro.configs.base import ModelConfig


def router_topk(x2d: jax.Array, w_router: jax.Array, k: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Returns (gates [T, k] fp32 normalised, experts [T, k] int32)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    gates, experts = lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts.astype(jnp.int32)


def _expert_ffn(xe: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array
                ) -> jax.Array:
    """xe [E_local, C, D]; weights [E_local, D, F] / [E_local, F, D]."""
    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      wd.astype(xe.dtype))


def _bucketize(x2d, gates, experts, E: int, capacity: int):
    """Pack token copies into per-expert capacity buckets.

    Returns (xbuf [E, C, D], gbuf [E, C], src [E, C] source-token index or
    -1, ok [T, k]).
    """
    T, k = experts.shape
    D = x2d.shape[-1]
    flat_e = experts.reshape(-1)
    valid = jnp.ones((T * k,), bool)
    pos = _group_positions(flat_e, valid, E)
    ok = pos < capacity
    e_idx = jnp.where(ok, flat_e, E)
    c_idx = jnp.where(ok, pos, 0)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    xbuf = jnp.zeros((E, capacity, D), x2d.dtype
                     ).at[e_idx, c_idx].set(x2d[tok], mode="drop")
    gbuf = jnp.zeros((E, capacity), jnp.float32
                     ).at[e_idx, c_idx].set(gates.reshape(-1), mode="drop")
    src = jnp.full((E, capacity), -1, jnp.int32
                   ).at[e_idx, c_idx].set(tok, mode="drop")
    return xbuf, gbuf, src, ok.reshape(T, k)


def moe_local(cfg: ModelConfig, p: dict, x: jax.Array,
              capacity: Optional[int] = None) -> jax.Array:
    """Single-program MoE (capacity semantics identical to the dispatch
    path with an undivided expert axis)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    x2d = x.reshape(B * S, D)
    T = x2d.shape[0]
    C = capacity or max(1, int(T * k * cfg.capacity_factor / E) + 1)
    gates, experts = router_topk(x2d, p["router"], k)
    xbuf, gbuf, src, _ = _bucketize(x2d, gates, experts, E, C)
    ybuf = _expert_ffn(xbuf, p["wg"], p["wu"], p["wd"])
    ybuf = ybuf * gbuf[..., None].astype(ybuf.dtype)
    y = jnp.zeros_like(x2d).at[jnp.where(src >= 0, src, T).reshape(-1)
                               ].add(ybuf.reshape(E * C, D), mode="drop")
    return y.reshape(B, S, D)


def moe_dispatch(cfg: ModelConfig, p: dict, x: jax.Array,
                 mesh: jax.sharding.Mesh, dp_axes: Sequence[str],
                 ep_axes: Sequence[str],
                 capacity: Optional[int] = None) -> jax.Array:
    """Expert-parallel MoE: routed exchange over ``ep_axes``.

    Experts are sharded over ep_axes; tokens enter *sequence-sharded over
    the expert axes* (the sequence-parallel MoE boundary), so every device
    owns a distinct token slice and the two all-to-alls (out and back)
    carry real traffic with no redundant expert compute.  The Section
    VI-A grid schedule applies when the expert axes span >= 2 mesh axes.
    Requires S % ep_size == 0 (callers fall back to ``moe_local`` — e.g.
    single-token decode).
    """
    dp = tuple(dp_axes)
    ep = tuple(ep_axes)
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    schedule = cfg.moe_dispatch if len(ep) > 1 else "direct"

    def body(x_l, router, wg, wu, wd):
        # ZeRO-3 expert storage: the hidden dim arrives sharded over the
        # DP axes and is re-gathered just-in-time (per layer, per step).
        wg = lax.all_gather(wg, dp, axis=2, tiled=True)
        wu = lax.all_gather(wu, dp, axis=2, tiled=True)
        wd = lax.all_gather(wd, dp, axis=1, tiled=True)
        pe = 1
        for a in ep:
            pe *= compat.axis_size(a)
        B, S, D = x_l.shape
        x2d = x_l.reshape(B * S, D)
        T = x2d.shape[0]
        e_local = E // pe
        C = capacity or max(1, int(T * k * cfg.capacity_factor / E) + 1)
        gates, experts = router_topk(x2d, router, k)
        xbuf, gbuf, src, _ = _bucketize(x2d, gates, experts, E, C)
        # [E, C, D] -> [pe, e_local * C, D]: experts are contiguous per
        # device, so one reshape makes the buffer all-to-all ready.
        send_x = xbuf.reshape(pe, e_local * C, D)
        recv_x = all_to_all_nd(send_x, ep, schedule)       # [pe, elC, D]
        xe = recv_x.reshape(pe, e_local, C, D).transpose(1, 0, 2, 3)
        xe = xe.reshape(e_local, pe * C, D)
        ye = _expert_ffn(xe, wg, wu, wd)                   # [e_local, peC, D]
        back = ye.reshape(e_local, pe, C, D).transpose(1, 0, 2, 3)
        back = back.reshape(pe, e_local * C, D)
        recv_y = all_to_all_nd(back, ep, schedule)         # [pe, elC, D]
        ybuf = recv_y.reshape(E, C, D) * gbuf[..., None].astype(x_l.dtype)
        y = jnp.zeros_like(x2d).at[
            jnp.where(src >= 0, src, T).reshape(-1)
        ].add(ybuf.reshape(E * C, D), mode="drop")
        return y.reshape(B, S, D)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, ep, None), P(), P(ep, None, dp),
                  P(ep, None, dp), P(ep, dp, None)),
        out_specs=P(dp, ep, None),
    )(x, p["router"], p["wg"], p["wu"], p["wd"])


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array,
              mesh_ctx=None) -> jax.Array:
    """MoE layer: routed experts (+ optional shared experts) + residual."""
    if cfg.moe_impl == "dispatch" and mesh_ctx is not None \
            and mesh_ctx.ep_size > 1 \
            and x.shape[1] % mesh_ctx.ep_size == 0:
        from jax.sharding import NamedSharding
        y = moe_dispatch(cfg, p, x, mesh_ctx.mesh, mesh_ctx.dp_axes,
                         mesh_ctx.ep_axes)
        # pin the sequence-parallel boundary here: re-shard the cheap
        # bf16 activation back to DP-only so the seq-sharding does not
        # propagate into the attention's fp32 internals (§Perf: this
        # boundary costs one 670MB all-gather instead of 2x15GB)
        y = lax.with_sharding_constraint(
            y, NamedSharding(mesh_ctx.mesh,
                             P(tuple(mesh_ctx.dp_axes), None, None)))
    else:
        y = moe_local(cfg, p, x)
    if cfg.num_shared_experts:
        from repro.models.layers import swiglu
        y = y + swiglu(x, p["shared_wg"], p["shared_wu"], p["shared_wd"])
    return y
