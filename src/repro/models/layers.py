"""Common transformer building blocks (pure JAX, einsum-based).

Conventions:
  * activations [B, S, D]; weights carry explicit head dims so sharding
    rules can target them by path (see models/sharding.py)
  * fp32 for norms/softmax accumulation, bf16 (cfg.dtype) elsewhere
  * decode paths take a KVCache and a position index; shapes are static
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


# -- RoPE -------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """positions [.. S] -> (cos, sin) [.., S, dim//2], fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B, S, H, hd] (split-half convention), cos/sin [B or 1, S, hd//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1)
    return out.astype(x.dtype)


# -- FFN --------------------------------------------------------------------

def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array
           ) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, wu.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, wd.astype(x.dtype))


def gelu_mlp(x: jax.Array, wi: jax.Array, bi: jax.Array, wo: jax.Array,
             bo: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype)) + bi)
    return jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype)) + bo


# -- attention core ---------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # [B, T, KV, hd]
    v: jax.Array  # [B, T, KV, hd]


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(token, head) symmetric scales (§Perf:
    halves the decode memory term vs bf16; KIVI/KVQuant-style)."""
    k_q: jax.Array      # int8 [B, T, KV, hd]
    k_scale: jax.Array  # f32  [B, T, KV]
    v_q: jax.Array      # int8 [B, T, KV, hd]
    v_scale: jax.Array  # f32  [B, T, KV]


def _quant_kv(x: jax.Array):
    """x [B, KV, hd] -> (int8, scale[B, KV])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array], scale: float) -> jax.Array:
    """q [B,S,H,hd]; k,v [B,T,KV,hd]; GQA via head grouping. fp32 softmax."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, hd)


def causal_mask(S: int, dtype=bool) -> jax.Array:
    return jnp.tril(jnp.ones((S, S), dtype))


def _sdpa_blockwise(q: jax.Array, k: jax.Array, v: jax.Array,
                    scale: float, causal: bool, block: int) -> jax.Array:
    """Flash-style attention: online softmax over KV chunks.

    Never materialises [B, H, S, T]; peak intermediate is
    [B, KV, G, S, block].  This is the §Perf memory-term optimization —
    on TPU the same tiling becomes a Pallas kernel; expressed here with
    lax.scan so XLA fuses each chunk's score/softmax/weighted-sum.
    q [B,S,H,hd]; k,v [B,T,KV,hd].
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    blk = min(block, T)
    pad = (-T) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nb = Tp // blk
    qg = (q.reshape(B, S, KV, G, hd) * scale).astype(q.dtype)
    kb = k.reshape(B, nb, blk, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, blk, KV, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(S)

    def chunk(carry, inp):
        m, l, acc = carry                      # running max / sum / out
        kc, vc, start = inp                    # [B, blk, KV, hd]
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kc).astype(jnp.float32)
        kpos = start + jnp.arange(blk)
        dead = kpos[None, :] >= T + jnp.zeros((1,), jnp.int32)
        if causal:
            dead = dead | (kpos[None, :] > qpos[:, None])
        s = jnp.where(dead[None, None, None], -1e30, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, hd), jnp.float32)
    starts = jnp.arange(nb, dtype=jnp.int32) * blk
    (m, l, acc), _ = jax.lax.scan(chunk, (m0, l0, a0), (kb, vb, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(q.dtype)


def gqa_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                  positions: jax.Array,
                  cache: Optional[KVCache] = None,
                  cache_pos: Optional[jax.Array] = None,
                  kv_source: Optional[jax.Array] = None,
                  causal: bool = True,
                  use_rope: bool = True
                  ) -> Tuple[jax.Array, Optional[KVCache]]:
    """Standard GQA attention with optional KV cache / cross-attention.

    cache + cache_pos: decode mode — insert the new K/V at ``cache_pos``
    and attend to positions <= cache_pos (static cache length).
    kv_source: encoder states for cross-attention (no cache, no mask).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope and kv_source is None:
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    scale = 1.0 / (hd ** 0.5)

    new_cache = None
    if isinstance(cache, QuantKVCache):
        # int8 cache: quantise the new entry, attend over the dequantised
        # buffer (int8 reads halve the decode memory term vs bf16)
        T = cache.k_q.shape[1]
        idx = cache_pos
        bidx = jnp.arange(B)
        kq, ks = _quant_kv(k[:, 0])
        vq, vs = _quant_kv(v[:, 0])
        new_cache = QuantKVCache(
            cache.k_q.at[bidx, idx].set(kq),
            cache.k_scale.at[bidx, idx].set(ks),
            cache.v_q.at[bidx, idx].set(vq),
            cache.v_scale.at[bidx, idx].set(vs))
        ck = (new_cache.k_q.astype(x.dtype)
              * new_cache.k_scale[..., None].astype(x.dtype))
        cv = (new_cache.v_q.astype(x.dtype)
              * new_cache.v_scale[..., None].astype(x.dtype))
        tpos = jnp.arange(T)[None, :]
        mask = (tpos <= idx[:, None])[:, None, :]
        out = _sdpa(q, ck, cv, mask, scale)
    elif cache is not None:
        # decode: write the new entries, attend over the whole buffer
        T = cache.k.shape[1]
        idx = cache_pos  # [B] int32 — current write position
        bidx = jnp.arange(B)
        ck = cache.k.at[bidx, idx].set(k[:, 0])
        cv = cache.v.at[bidx, idx].set(v[:, 0])
        new_cache = KVCache(ck, cv)
        tpos = jnp.arange(T)[None, :]
        mask = (tpos <= idx[:, None])[:, None, :]  # [B, 1, T]
        out = _sdpa(q, ck, cv, mask, scale)
    elif kv_source is not None:
        if cfg.attn_impl == "blockwise":
            out = _sdpa_blockwise(q, k, v, scale, False, cfg.attn_block)
        else:
            out = _sdpa(q, k, v, None, scale)
    elif cfg.attn_impl == "blockwise":
        out = _sdpa_blockwise(q, k, v, scale, causal, cfg.attn_block)
    else:
        mask = causal_mask(S)[None] if causal else None
        out = _sdpa(q, k, v, mask, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


# -- MLA (multi-head latent attention, DeepSeek-V2) --------------------------

class MLACache(NamedTuple):
    latent: jax.Array  # [B, T, kv_lora + rope_head_dim]


def mla_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                  positions: jax.Array,
                  cache: Optional[MLACache] = None,
                  cache_pos: Optional[jax.Array] = None,
                  causal: bool = True
                  ) -> Tuple[jax.Array, Optional[MLACache]]:
    """MLA: low-rank KV latent cache (kv_lora) + decoupled RoPE key.

    The cache stores the compressed latent (kv_lora + rope_head_dim per
    token) — the memory-side point of MLA — and K/V are re-expanded from
    it through ``wkv_b`` at attention time.
    """
    B, S, D = x.shape
    H, hd, r = cfg.num_heads, cfg.hd, cfg.rope_head_dim
    lo = cfg.kv_lora_rank

    # queries through the q-LoRA bottleneck
    q_lat = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype))
    q_lat = rmsnorm(q_lat, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :hd], q[..., hd:]

    # KV latent (+ decoupled rope key channel, shared across heads)
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    latent, k_rope_in = kv[..., :lo], kv[..., lo:]
    latent = rmsnorm(latent, p["kv_norm"], cfg.norm_eps)

    cos, sin = rope_cos_sin(positions, r, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope_in[:, :, None, :], cos, sin)[:, :, 0, :]

    packed = jnp.concatenate([latent, k_rope], axis=-1)  # [B, S, lo+r]

    new_cache = None
    if cache is not None:
        T = cache.latent.shape[1]
        bidx = jnp.arange(B)
        buf = cache.latent.at[bidx, cache_pos].set(packed[:, 0])
        new_cache = MLACache(buf)
        packed_all = buf
        tpos = jnp.arange(T)[None, :]
        mask = (tpos <= cache_pos[:, None])[:, None, :]
    else:
        packed_all = packed
        mask = causal_mask(S)[None] if causal else None

    scale = 1.0 / ((hd + r) ** 0.5)
    if cache is not None and cfg.mla_absorb:
        # absorbed-weight decode: fold wkv_b into the query and output so
        # attention runs in the latent space — the cached latents are
        # never re-expanded (the classic MLA serving optimization; cuts
        # per-step attention flops by ~2*hd/lo per position)
        lat_all = packed_all[..., :lo]
        k_rope_all = packed_all[..., lo:]
        wk_abs = p["wkv_b"][..., :hd].astype(x.dtype)   # [lo, H, hd]
        wv_abs = p["wkv_b"][..., hd:].astype(x.dtype)   # [lo, H, hd]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wk_abs)
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat, lat_all)
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope_all)
        scores = (s_nope + s_rope).astype(jnp.float32) * scale
        if mask is not None:
            scores = jnp.where(mask[:, None] if mask.ndim == 3 else mask,
                               scores, -1e30)
        wgt = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhst,btr->bshr", wgt, lat_all)
        out = jnp.einsum("bshr,rhk->bshk", ctx, wv_abs)
    elif cfg.attn_impl == "blockwise" and cache is None:
        out = _mla_blockwise(q_nope, q_rope, packed_all, p["wkv_b"], lo, hd,
                             scale, causal, cfg.attn_block)
    else:
        lat_all = packed_all[..., :lo]
        k_rope_all = packed_all[..., lo:]
        # expand K (nope part) and V from the latent
        kvex = jnp.einsum("btr,rhk->bthk", lat_all,
                          p["wkv_b"].astype(x.dtype))
        k_nope, v = kvex[..., :hd], kvex[..., hd:]
        s_nope = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope_all)
        scores = (s_nope + s_rope).astype(jnp.float32) * scale
        if mask is not None:
            scores = jnp.where(mask[:, None] if mask.ndim == 3 else mask,
                               scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthk->bshk", w, v)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


def _mla_blockwise(q_nope: jax.Array, q_rope: jax.Array,
                   packed: jax.Array, wkv_b: jax.Array, lo: int, hd: int,
                   scale: float, causal: bool, block: int) -> jax.Array:
    """Blockwise MLA: chunk the *latent* cache, expand K/V per chunk.

    Avoids both the [B,H,S,T] score tensor and the full [B,T,H,2hd]
    latent expansion — the expansion itself is re-done per chunk (compute
    for memory, the same trade remat makes).
    """
    B, S, H, _ = q_nope.shape
    T = packed.shape[1]
    blk = min(block, T)
    pad = (-T) % blk
    if pad:
        packed = jnp.pad(packed, ((0, 0), (0, pad), (0, 0)))
    nb = (T + pad) // blk
    pc = packed.reshape(B, nb, blk, packed.shape[-1]).transpose(1, 0, 2, 3)
    qpos = jnp.arange(S)
    wkv = wkv_b.astype(q_nope.dtype)

    def pin(t, spec):
        """Keep the chunked online-softmax internals head-sharded: GSPMD
        otherwise re-shards the fp32 carries through the bwd scan with
        full-rematerialisation gathers (§Perf, deepseek-v2 iteration 5)."""
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is not None and "model" in mesh.axis_names \
                    and t.shape[1] % mesh.shape["model"] == 0:
                return jax.lax.with_sharding_constraint(t, spec)
        except Exception:
            pass
        return t

    from jax.sharding import PartitionSpec as _P

    def chunk(carry, inp):
        m, l, acc = carry
        lat_c, start = inp                       # [B, blk, lo + r]
        kvex = jnp.einsum("btr,rhk->bthk", lat_c[..., :lo], wkv)
        k_nope_c, v_c = kvex[..., :hd], kvex[..., hd:]
        s = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope_c)
             + jnp.einsum("bshk,btk->bhst", q_rope, lat_c[..., lo:])
             ).astype(jnp.float32) * scale
        s = pin(s, _P(None, "model", None, None))
        kpos = start + jnp.arange(blk)
        dead = kpos[None, :] >= T
        if causal:
            dead = dead | (kpos[None, :] > qpos[:, None])
        s = jnp.where(dead[None, None], -1e30, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p_.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthk->bhsk", p_.astype(q_nope.dtype), v_c
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = pin(jnp.full((B, H, S), -jnp.inf, jnp.float32),
             _P(None, "model", None))
    l0 = pin(jnp.zeros((B, H, S), jnp.float32), _P(None, "model", None))
    a0 = pin(jnp.zeros((B, H, S, hd), jnp.float32),
             _P(None, "model", None, None))
    starts = jnp.arange(nb, dtype=jnp.int32) * blk
    (m, l, acc), _ = jax.lax.scan(chunk, (m0, l0, a0), (pc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q_nope.dtype)
