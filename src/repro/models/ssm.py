"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked SSD algorithm: the sequence is split into chunks of length Q;
within a chunk the recurrence is materialised as a decay-masked
attention-like quadratic form (MXU-friendly), and chunk-level states are
propagated with a short ``lax.scan`` — O(S*Q) work, O(S/Q) sequential
steps.  This is the TPU-native adaptation: no per-token scan, all heavy
ops are batched einsums.

Decode keeps O(1) state per layer: the SSM state [H, P, N] plus the
causal-conv tail — which is what makes the ``long_500k`` cell feasible
for the SSM/hybrid architectures (DESIGN.md shape-cell table).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class SSMState(NamedTuple):
    h: jax.Array        # [B, H, P, N] ssm state
    conv: jax.Array     # [B, W-1, conv_channels] causal-conv tail


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    H = cfg.num_heads
    Pd = cfg.ssm_head_dim
    N = cfg.ssm_state
    return H, Pd, N


def _causal_conv(x: jax.Array, w: jax.Array, tail: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x [B,S,C], w [W,C]. Returns (y, new_tail)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(y), xp[:, -(W - 1):]


def ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh [B,S,H,P], dt [B,S,H] (softplus'd), A [H] (negative), Bm/Cm [B,S,N].
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    def r(t):  # reshape into chunks
        return t.reshape((B, nc, Q) + t.shape[2:])

    xc, dtc, Bc, Cc = r(xh), r(dt.astype(jnp.float32)), r(Bm), r(Cm)
    # per-step log decay: l = A * dt  (A < 0)
    lc = A.astype(jnp.float32)[None, None, None, :] * dtc  # [B,nc,Q,H]
    cum = jnp.cumsum(lc, axis=2)                            # [B,nc,Q,H]
    # intra-chunk decay matrix M[t,s] = exp(cum_t - cum_s), s <= t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,nc,t,s,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    # intra-chunk (attention-like) term
    scores = jnp.einsum("bctn,bcsn->bcts", Cc, Bc).astype(jnp.float32)
    dx = xc.astype(jnp.float32) * dtc[..., None]            # [B,nc,Q,H,P]
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", scores, M, dx)

    # chunk summary states and cross-chunk scan
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)            # [B,nc,Q,H]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_end, dx)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # [B,nc,H]

    h_init = (jnp.zeros((B, H, Pd, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(h, inp):
        st, dec = inp  # [B,H,P,N], [B,H]
        h_out = h  # state entering this chunk
        h_next = h * dec[..., None, None] + st
        return h_next, h_out

    (h_final, h_enter) = jax.lax.scan(
        step, h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)              # [B,nc,H,P,N]
    # contribution of the entering state to each position
    y_init = jnp.einsum("bctn,bcth,bchpn->bcthp", Cc, jnp.exp(cum), h_enter)
    y = (y_intra + y_init).reshape(B, Sp, H, Pd)[:, :S]
    return y.astype(xh.dtype), h_final.astype(xh.dtype)


def mamba2_block(cfg: ModelConfig, p: dict, x: jax.Array,
                 state: Optional[SSMState] = None
                 ) -> Tuple[jax.Array, Optional[SSMState]]:
    """Full Mamba2 mixer. x [B,S,D]. state!=None -> streaming/decode mode."""
    B, S, D = x.shape
    H, Pd, N = ssm_dims(cfg)
    inner = H * Pd
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xr, Bm, Cm, dt = jnp.split(
        proj, [inner, 2 * inner, 2 * inner + N, 2 * inner + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    tail = state.conv if state is not None else None
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"], tail)
    xr, Bm, Cm = jnp.split(conv_out, [inner, inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xr.reshape(B, S, H, Pd)
    h0 = state.h if state is not None else None
    y, h = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, h0)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, inner)
    # gated RMSNorm then out projection
    from repro.models.layers import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    new_state = SSMState(h, new_tail) if state is not None else None
    return out, new_state
