"""Capacity-bounded sparse all-to-all (the paper's bulk request/reply).

The paper's algorithms batch arbitrary point-to-point messages into sparse
``MPI_Alltoallv`` exchanges.  XLA programs need static shapes, so the
TPU-native equivalent is the *capacity-bounded routed exchange* — the same
discipline MoE dispatch uses: a [p, capacity, ...] send buffer per device,
one (optionally two-level, Section VI-A) all-to-all, and an explicit
overflow count instead of variable message sizes.  Overflow never corrupts
results: overflowing items are reported back to the caller (``sent_ok``)
and the dynamic engines retry at a higher capacity.

Primitives:
  * ``routed_exchange``  — deliver items to destination shards.
  * ``request_reply``    — full round trip: route requests to their home
    shard, apply a local answer function, route answers back to the
    requesting slots (the paper's EXCHANGELABELS pattern).
  * ``scatter_updates``  — push-style multicast: deliver item ``i`` to
    every shard whose bit is set in ``dest_mask[i]`` (the ghost-vertex
    dirty-label push of the sharded MST engine: an owner ships a changed
    label to every subscriber shard in one exchange, no request leg).
  * ``scatter_updates_grid`` — the two-level multicast (Section VI-A
    applied to the push): subscriptions are a *pair* of per-axis
    bitmasks on a (row, col) mesh, and each item travels two hops —
    along the owner's row to one deputy per subscribing column, then
    down each deputy's column to the subscribing rows — so the copy
    matrix shrinks from [L, p] to [L, sqrt(p)] per hop and the fan-out
    from O(p) to O(sqrt(p)), lifting the flat primitive's 31-shard cap
    to 31 x 31 = 961.

Used by: distributed MST (ghost-label exchange, redistribution) and the
MoE layers (token->expert dispatch) — one primitive, two workloads.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.comm import faults
from repro.comm.grid_alltoall import all_to_all_nd


class ExchangeStats(NamedTuple):
    """Comm accumulator for the routed exchanges (the honest perf metric:
    on one host, wall time over virtual devices is noise — counting the
    all-to-alls and the routed volume is what separates engine variants;
    benchmarks/sharded_scaling.py reports these, and the per-round deltas
    drive the sharded engine's shrinking capacity schedule trace).

    All four are device-invariant scalars, safe to carry through
    shard_map loops and to return with out_spec P().  Field-by-field,
    with the units the benchmarks report:

      * ``calls`` — int32 count of ``lax.all_to_all`` **invocations**.
        One logical exchange of a k-array payload costs k + 1 buffer
        all-to-alls (the +1 is the validity mask); a ``reply`` costs one
        per answer array.  Grid schedules multiply by the hop count (one
        invocation per mesh axis), matching what the interconnect
        actually executes.  Unit: invocations, NOT items or bytes.
      * ``items`` — float32 count of payload **items** accepted into
        send buffers, psum'd over devices (a k-array payload item counts
        once, not k times; ``reply`` counts every occupied receive
        slot).  This is what request coalescing and dead-edge retirement
        shrink.  Unit: routed items, independent of per-item width.
      * ``bytes`` — float32 **capacity-padded buffer bytes** shipped per
        invocation: every [p, capacity, ...] send buffer contributes its
        full static size (validity mask included, grid hop multiplier
        applied) whether or not the slots are occupied.  This is the
        honest memory/wire cost of a static-shape exchange and is what a
        smaller ``capacity`` shrinks even when ``items`` is unchanged.
        Unit: bytes.  float32 because int32 overflows at benchmark size.
      * ``slots`` — float32 count of **buffer slots** allocated across
        calls: one logical exchange (or reply) adds ``p * capacity``
        per hop, with no payload-width multiplier.  Request/reply legs
        ship one pre-packed buffer end to end, so their hop count is
        always 1 logical allocation (``routed_exchange`` books
        ``p * capacity`` once regardless of schedule); the *multicast*
        primitives re-admit items at every hop — ``scatter_updates``
        books ``p * capacity * hops`` and ``scatter_updates_grid``
        books its two legs distinctly (``C * cap_row + R * cap_col``),
        which is exactly the O(sqrt(p))-vs-O(p) fan-out the grid push
        exists to shrink.  This is the capacity-per-call plumbing:
        ``slots`` divided by logical exchanges recovers the average
        capacity a solve actually used, which is how the
        shrinking-capacity schedule is audited without re-deriving
        capacities from the code.  Unit: slots (rows), not bytes.
        Conservation law (asserted in tests/test_comm.py): one
        request/reply lookup contributes exactly ``2 * p * capacity`` —
        never more; the primitives below only ever *carry* these fields
        through (``_replace``), so a caller cannot double-book a call by
        threading the same accumulator into both legs.
      * ``injected`` — float32 count of items affected by an active
        fault-injection plan (``comm/faults.py``, ISSUE 7), psum'd like
        ``items``: suppressed (stall), corrupted, misrouted, clipped or
        dropped items each count once at the exchange that faulted
        them, so a chaos run can assert every injected fault is
        attributable.  Always 0 outside ``faults.inject`` — the fault
        hooks trace no code when no plan is active.
      * ``hits`` / ``misses`` / ``pushed`` — float32 ghost-label-cache
        counters (ISSUE 4), psum'd like ``items``.  ``misses`` counts
        routed endpoint-lookup request items (with the cache disabled
        every endpoint lookup is by definition a miss, so this is also
        the per-round routed-lookup-volume counter the benchmarks
        track); ``hits`` counts endpoint reads served from the local
        ghost table (one per coalesced run that would otherwise have
        sent a request); ``pushed`` counts the cache's *entire*
        maintenance traffic — the root-delta items multicast through
        ``scatter_updates`` plus the subscription build/forward
        exchange items that keep the subscriber bitmasks with the
        surviving roots — so ``misses + pushed`` covers everything the
        cache ships.  The exchange primitives never touch these
        fields — only the sharded engine's lookup/push sites do.

    ``CommStats`` (core/distributed.py) is the engine-level view of the
    same counters (calls/items/bytes plus the Borůvka round count and
    the ghost hit/miss/push triple); the replicated engine derives those
    analytically, the sharded engine sums these accumulators, so
    benchmarks compare engines like-for-like.
    """
    calls: jax.Array   # [] int32   — all_to_all invocations
    items: jax.Array   # [] float32 — routed payload items (psum'd)
    bytes: jax.Array   # [] float32 — capacity-padded buffer bytes
    slots: jax.Array   # [] float32 — p * capacity rows per logical exchange
    hits: jax.Array    # [] float32 — ghost-cache label reads served locally
    misses: jax.Array  # [] float32 — routed endpoint-lookup request items
    pushed: jax.Array  # [] float32 — dirty labels multicast to subscribers
    injected: jax.Array  # [] float32 — fault-injected items (ISSUE 7)

    @staticmethod
    def zeros() -> "ExchangeStats":
        return ExchangeStats(jnp.int32(0), jnp.float32(0.0),
                             jnp.float32(0.0), jnp.float32(0.0),
                             jnp.float32(0.0), jnp.float32(0.0),
                             jnp.float32(0.0), jnp.float32(0.0))


def _hops(axis_names: Sequence[str], schedule: str) -> int:
    """all_to_all invocations one logical exchange costs (grid: one/axis)."""
    names = tuple(axis_names)
    return 1 if (schedule == "direct" or len(names) == 1) else len(names)


def _buffer_bytes(buffers) -> int:
    """Bytes one exchange of the (already [p, C, ...]-shaped) buffers ships."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(buffers))


class ExchangeResult(NamedTuple):
    """One routed exchange's receive-side view plus the bookkeeping a
    later ``reply`` needs to route answers back.  ``capacity`` (``C``
    below) is a per-call argument: two exchanges in the same program may
    use different capacities — the sharded engine's shrinking schedule
    relies on exactly that — and each call's capacity is recorded in
    ``stats.slots``."""
    recv: jax.Array        # [p, C, ...] received payloads (source-major)
    recv_ok: jax.Array     # [p, C] bool — slot holds a delivered item
    sent_ok: jax.Array     # [L] bool — item was within capacity
    dest: jax.Array        # [L] int32 (echoed)
    slot: jax.Array        # [L] int32 position used in the send buffer
    overflow: jax.Array    # [] int32 dropped-item count, psum'd (0 =>
    #                        results exact; > 0 => caller must retry
    #                        with a larger capacity — never silent)
    stats: Optional[ExchangeStats] = None  # set iff the caller threads one


def _group_positions(dest: jax.Array, valid: jax.Array, p: int) -> jax.Array:
    """Rank of each item within its destination group (stable)."""
    L = dest.shape[0]
    key = jnp.where(valid, dest, p)  # invalid items sort to the end
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    idx = jnp.arange(L, dtype=jnp.int32)
    first = jnp.searchsorted(sorted_key, sorted_key, side="left"
                             ).astype(jnp.int32)
    pos_sorted = idx - first
    return jnp.zeros((L,), jnp.int32).at[order].set(pos_sorted)


def routed_exchange(payload, dest: jax.Array, valid: jax.Array,
                    capacity: int, axis_names: Sequence[str],
                    schedule: str = "grid",
                    stats: Optional[ExchangeStats] = None,
                    site: str = "") -> ExchangeResult:
    """Deliver ``payload[i]`` to shard ``dest[i]``; static [p, C] buffers.

    ``payload`` is a pytree of [L, ...] arrays.  Must run inside shard_map
    with all ``axis_names`` present.  When ``stats`` is given, the result's
    ``stats`` field carries it plus this exchange's contribution.

    ``site`` labels this call for fault injection (``comm/faults.py``,
    ISSUE 7): while a ``FaultPlan`` is active, specs matching the label
    are applied at trace time and the affected-item count rides
    ``stats.injected``.  With no active plan (the default, and always
    outside ``faults.inject``) the fault hooks trace nothing — the
    fault-free program is bit-identical to one built before this
    parameter existed.
    """
    names = tuple(axis_names)
    p = 1
    for n in names:
        p *= compat.axis_size(n)
    L = dest.shape[0]
    cap_ok = capacity
    fspecs = faults.specs_for(site)
    inj = None
    if fspecs:
        payload, dest, valid, cap_ok, inj = faults.apply_send(
            fspecs, faults.active().seed, site, payload, dest, valid,
            capacity, p, names)
    pos = _group_positions(dest, valid, p)
    ok = valid & (pos < cap_ok) & (dest >= 0) & (dest < p)
    if fspecs and cap_ok < capacity:
        # clip: the admission rows a genuine capacity would have taken
        # are forced overflow — charge them to the injected counter too
        inj = inj + jnp.sum((valid & (pos >= cap_ok)
                             & (pos < capacity)).astype(jnp.float32))
    # predicated scatter: out-of-range rows are dropped
    d_idx = jnp.where(ok, dest, p)
    s_idx = jnp.where(ok, pos, 0)

    def scatter(x):
        # freshly created buffers are unvarying; promote them before the
        # scatter of per-shard data so the module passes check_vma on
        # JAX >= 0.6 (no-op on 0.4.x — see repro.compat)
        buf = compat.vary(jnp.zeros((p, capacity) + x.shape[1:], x.dtype),
                          names)
        return buf.at[d_idx, s_idx].set(x, mode="drop")

    send = jax.tree.map(scatter, payload)
    send_mask = compat.vary(jnp.zeros((p, capacity), bool), names).at[
        d_idx, s_idx].set(ok, mode="drop")
    recv = jax.tree.map(lambda b: all_to_all_nd(b, names, schedule), send)
    recv_ok = all_to_all_nd(send_mask, names, schedule)
    if fspecs:
        recv_ok, inj_r = faults.apply_recv(fspecs, faults.active().seed,
                                           site, recv_ok, names)
        inj = inj + inj_r
    overflow = lax.psum(jnp.sum((valid & ~ok).astype(jnp.int32)), names)
    if stats is not None:
        h = _hops(names, schedule)
        nbuf = len(jax.tree.leaves(payload)) + 1  # + validity mask
        by = _buffer_bytes(send) + _buffer_bytes(send_mask)
        items = lax.psum(jnp.sum(ok.astype(jnp.float32)), names)
        stats = stats._replace(calls=stats.calls + jnp.int32(nbuf * h),
                               items=stats.items + items,
                               bytes=stats.bytes + jnp.float32(by * h),
                               slots=stats.slots + jnp.float32(p * capacity))
        if fspecs:
            stats = stats._replace(
                injected=stats.injected + lax.psum(inj, names))
    return ExchangeResult(recv, recv_ok, ok, dest, pos, overflow, stats)


def reply(ex: ExchangeResult, answers, axis_names: Sequence[str],
          schedule: str = "grid", stats: Optional[ExchangeStats] = None):
    """Route per-slot ``answers`` ([p, C, ...], aligned with ``ex.recv``)
    back to the requesting items.  Returns [L, ...] with ``ex.sent_ok``
    telling which entries are meaningful; with ``stats``, returns
    ([L, ...], updated stats) instead."""
    names = tuple(axis_names)
    back = jax.tree.map(lambda a: all_to_all_nd(a, names, schedule), answers)
    # item i used buffer position (dest[i], slot[i]); after the return
    # exchange, that slot holds the answer from shard dest[i].
    d = jnp.clip(ex.dest, 0, None)

    def gather(b):
        return b[d, ex.slot]

    out = jax.tree.map(gather, back)
    if stats is None:
        return out
    h = _hops(names, schedule)
    by = _buffer_bytes(answers)
    items = lax.psum(jnp.sum(ex.recv_ok.astype(jnp.float32)), names)
    leaves = jax.tree.leaves(answers)
    nbuf = len(leaves)
    slots = leaves[0].shape[0] * leaves[0].shape[1] if leaves else 0
    stats = stats._replace(calls=stats.calls + jnp.int32(nbuf * h),
                           items=stats.items + items,
                           bytes=stats.bytes + jnp.float32(by * h),
                           slots=stats.slots + jnp.float32(slots))
    return out, stats


def _mask_to_copies(dest_mask: jax.Array, valid: jax.Array,
                    p: int) -> jax.Array:
    """Expand per-item int32 destination bitmasks to the [L, p] copy
    matrix ``scatter_updates`` routes from: copy (i, s) exists iff item
    ``i`` is valid and bit ``s`` of ``dest_mask[i]`` is set.

    Pure bit arithmetic, factored out so the width contract is testable
    without a mesh (tests/test_comm.py): bits 0..30 are usable
    destinations, bit 31 is the int32 sign bit — which is why callers
    (the ghost cache) must fall back beyond 31 shards, and why this
    helper is only ever called with ``p <= 31``.
    """
    lanes = jnp.arange(p, dtype=jnp.int32)
    return valid[:, None] & (((dest_mask[:, None] >> lanes) & 1) > 0)


def _axis_masks_to_copies(row_mask: jax.Array, col_mask: jax.Array,
                          valid: jax.Array, r: int, c: int
                          ) -> Tuple[jax.Array, jax.Array]:
    """Per-axis sibling of ``_mask_to_copies`` for the two-level grid
    multicast: expand a *pair* of per-axis int32 subscription bitmasks
    into the two per-hop copy matrices.

    Returns ``(row_copies [L, r], col_copies [L, c])``: copy (i, rr)
    exists iff item ``i`` is valid and bit ``rr`` of ``row_mask[i]`` is
    set (the deputy's second hop down its column), copy (i, cc) likewise
    from ``col_mask`` (the owner's first hop along its row).  The
    delivered set is the outer product ``row_copies & col_copies`` —
    every device (rr, cc) with both bits set — which covers up to
    31 x 31 = 961 shards from two sign-bit-safe int32 masks, while each
    hop's transient stays [L, <=31] instead of the flat [L, p].
    Pure bit arithmetic (meshless-testable, tests/test_comm.py); both
    axes share the flat helper's bit-30 width contract.
    """
    return (_mask_to_copies(row_mask, valid, r),
            _mask_to_copies(col_mask, valid, c))


class ScatterResult(NamedTuple):
    """Receive-side view of one ``scatter_updates`` multicast.  There is
    no reply leg, so no routing bookkeeping is carried — consumers apply
    the received updates in place (e.g. scatter new labels into a ghost
    table) and only need the source-major buffers plus the overflow
    contract shared with ``routed_exchange``."""
    recv: jax.Array      # [p, C, ...] received payloads (source-major)
    recv_ok: jax.Array   # [p, C] bool — slot holds a delivered item
    sent_ok: jax.Array   # [L, p] bool — (item, dest) copy was in capacity
    overflow: jax.Array  # [] int32 dropped (item, dest) copies, psum'd
    stats: Optional[ExchangeStats] = None


def scatter_updates(payload, dest_mask: jax.Array, valid: jax.Array,
                    capacity: int, axis_names: Sequence[str],
                    schedule: str = "grid",
                    stats: Optional[ExchangeStats] = None,
                    site: str = "") -> ScatterResult:
    """Multicast ``payload[i]`` to every shard set in bitmask ``dest_mask[i]``.

    The push-style dual of ``routed_exchange``: no request leg, no reply
    routing — item ``i`` is copied into the send row of every
    destination shard ``s`` with ``dest_mask[i] >> s & 1`` set (so one
    changed ghost label reaches all its subscribers in a single
    exchange).  ``dest_mask`` is an int32 bitmask, which caps the mesh
    at 31 shards for this primitive (bit 31 would be the int32 sign
    bit); callers gate on that and fall back to per-destination
    request/reply beyond it.  Per-destination positions come from one
    column-wise cumsum over the [L, p] copy mask — an O(L·p) transient,
    the price of static shapes for a multicast (documented honestly in
    docs/ARCHITECTURE.md).

    Overflow accounting matches ``routed_exchange``: copies beyond
    ``capacity`` are dropped *per destination* and counted, never
    silent.  ``stats`` accrues one logical exchange (payload leaves + 1
    mask buffer) with the grid hop multiplier on slots as well as bytes
    — a multicast's copies are *re-admitted* at every hop, so a
    d-axis grid schedule allocates ``p * capacity * d`` rows, unlike
    the request/reply legs whose pre-packed buffer ships end to end
    (see the ``ExchangeStats.slots`` contract); the ghost-specific
    ``pushed`` counter is the caller's to bump — this primitive is
    generic.
    """
    names = tuple(axis_names)
    p = 1
    for n in names:
        p *= compat.axis_size(n)
    L = dest_mask.shape[0]
    cap_ok = capacity
    fspecs = faults.specs_for(site)
    inj = None
    if fspecs:
        payload, dest_mask, valid, cap_ok, inj = faults.apply_send_scatter(
            fspecs, faults.active().seed, site, payload, dest_mask,
            valid, capacity, p, names)
    want = _mask_to_copies(dest_mask, valid, p)
    pos = jnp.cumsum(want.astype(jnp.int32), axis=0) - 1     # [L, p]
    ok = want & (pos < cap_ok)
    if fspecs and cap_ok < capacity:
        inj = inj + jnp.sum((want & (pos >= cap_ok)
                             & (pos < capacity)).astype(jnp.float32))
    d_idx = jnp.where(ok, jnp.arange(p, dtype=jnp.int32)[None, :], p)
    s_idx = jnp.where(ok, pos, 0)

    def scatter(x):
        buf = compat.vary(jnp.zeros((p, capacity) + x.shape[1:], x.dtype),
                          names)
        rep = jnp.broadcast_to(x[:, None], (L, p) + x.shape[1:])
        return buf.at[d_idx, s_idx].set(rep, mode="drop")

    send = jax.tree.map(scatter, payload)
    send_mask = compat.vary(jnp.zeros((p, capacity), bool), names).at[
        d_idx, s_idx].set(ok, mode="drop")
    recv = jax.tree.map(lambda b: all_to_all_nd(b, names, schedule), send)
    recv_ok = all_to_all_nd(send_mask, names, schedule)
    if fspecs:
        recv_ok, inj_r = faults.apply_recv(fspecs, faults.active().seed,
                                           site, recv_ok, names)
        inj = inj + inj_r
    overflow = lax.psum(jnp.sum((want & ~ok).astype(jnp.int32)), names)
    if stats is not None:
        h = _hops(names, schedule)
        nbuf = len(jax.tree.leaves(payload)) + 1  # + validity mask
        by = _buffer_bytes(send) + _buffer_bytes(send_mask)
        items = lax.psum(jnp.sum(ok.astype(jnp.float32)), names)
        stats = stats._replace(calls=stats.calls + jnp.int32(nbuf * h),
                               items=stats.items + items,
                               bytes=stats.bytes + jnp.float32(by * h),
                               slots=stats.slots
                               + jnp.float32(p * capacity * h))
        if fspecs:
            stats = stats._replace(
                injected=stats.injected + lax.psum(inj, names))
    return ScatterResult(recv, recv_ok, ok, overflow, stats)


def scatter_updates_grid(payload, row_mask: jax.Array,
                         col_mask: jax.Array, valid: jax.Array,
                         cap_row: int, cap_col: int,
                         axis_names: Sequence[str],
                         stats: Optional[ExchangeStats] = None,
                         site_row: str = "", site_col: str = ""
                         ) -> ScatterResult:
    """Two-level grid multicast (Section VI-A applied to the push).

    Delivers ``payload[i]`` to every device ``(rr, cc)`` with bit ``rr``
    of ``row_mask[i]`` *and* bit ``cc`` of ``col_mask[i]`` set, in two
    hops on a 2-axis ``(row, col)`` mesh:

      1. the owner at ``(r0, c0)`` ships one copy per subscribing
         column along its own row — an ``all_to_all`` over the *col*
         axis only, ``[C, cap_row]`` buffers — to the grid deputies
         ``(r0, cc)``, each copy carrying its ``row_mask``;
      2. each deputy re-multicasts its received items down its column
         to the subscribing rows — an ``all_to_all`` over the *row*
         axis, ``[R, cap_col]`` buffers.

    Per hop the copy matrix is ``[*, <=31]`` instead of the flat
    ``[L, p]``, the per-item fan-out is O(sqrt(p)) instead of O(p), and
    the pair of int32 masks addresses up to 961 shards — the flat
    primitive's 31-shard sign-bit cap, lifted.  The delivered set is
    the *outer product* of the two masks, a superset of any true
    subscriber set whose projections they are; callers must apply
    updates value-keyed (the ghost push rewrites table entries matching
    the shipped old root, so an unsubscribed ``(rr, cc)`` in the cross
    product simply matches nothing).

    Overflow follows the shared exchange contract on **both** hops:
    copies beyond ``cap_row`` per (owner, column) or beyond ``cap_col``
    per (deputy, row) are dropped and counted, never silent.  ``stats``
    books the two legs distinctly — hop 1 adds ``C * cap_row`` slots
    (payload leaves + the forwarded row mask + validity), hop 2
    ``R * cap_col`` — so the roofline cross-check sees the deputy leg's
    real cost.  ``site_row`` / ``site_col`` label the hops separately
    for fault injection (``ghost_push_row`` / ``ghost_push_col`` in the
    engine).  The result's ``sent_ok`` is the hop-1 admission matrix
    ``[L, C]`` (the owner's view; hop-2 drops are visible in
    ``overflow`` only, like any relayed exchange).
    """
    names = tuple(axis_names)
    if len(names) != 2:
        raise ValueError(
            f"scatter_updates_grid needs a (row, col) axis pair, got "
            f"{names!r}")
    row_ax, col_ax = names
    R = compat.axis_size(row_ax)
    C = compat.axis_size(col_ax)
    L = valid.shape[0]
    leaves = jax.tree.leaves(payload)

    # -- hop 1: owner -> deputies along the row (exchange over col) ------
    cap1_ok = cap_row
    fspecs1 = faults.specs_for(site_row)
    inj = jnp.float32(0.0)
    pl1 = (payload, row_mask)
    if fspecs1:
        pl1, col_mask, valid, cap1_ok, inj = faults.apply_send_scatter(
            fspecs1, faults.active().seed, site_row, pl1, col_mask,
            valid, cap_row, C, names)
    want1 = _mask_to_copies(col_mask, valid, C)          # [L, C]
    pos1 = jnp.cumsum(want1.astype(jnp.int32), axis=0) - 1
    ok1 = want1 & (pos1 < cap1_ok)
    if fspecs1 and cap1_ok < cap_row:
        inj = inj + jnp.sum((want1 & (pos1 >= cap1_ok)
                             & (pos1 < cap_row)).astype(jnp.float32))
    d1 = jnp.where(ok1, jnp.arange(C, dtype=jnp.int32)[None, :], C)
    s1 = jnp.where(ok1, pos1, 0)

    def scatter1(x):
        buf = compat.vary(jnp.zeros((C, cap_row) + x.shape[1:], x.dtype),
                          names)
        rep = jnp.broadcast_to(x[:, None], (L, C) + x.shape[1:])
        return buf.at[d1, s1].set(rep, mode="drop")

    send1 = jax.tree.map(scatter1, pl1)
    mask1 = compat.vary(jnp.zeros((C, cap_row), bool), names).at[
        d1, s1].set(ok1, mode="drop")
    hop1 = jax.tree.map(
        lambda b: lax.all_to_all(b, col_ax, split_axis=0, concat_axis=0),
        send1)
    ok_r = lax.all_to_all(mask1, col_ax, split_axis=0, concat_axis=0)
    if fspecs1:
        ok_r, inj_r = faults.apply_recv(fspecs1, faults.active().seed,
                                        site_row, ok_r, names)
        inj = inj + inj_r
    ovf1 = lax.psum(jnp.sum((want1 & ~ok1).astype(jnp.int32)), names)
    recv_payload, rmask_r = hop1                         # [C, cap_row, ...]

    # -- hop 2: deputy -> subscribers down the column (exchange over row)
    M = C * cap_row
    dep_valid = ok_r.reshape(-1)
    dep_rmask = rmask_r.reshape(-1)
    dep_payload = jax.tree.map(
        lambda x: x.reshape((M,) + x.shape[2:]), recv_payload)
    cap2_ok = cap_col
    fspecs2 = faults.specs_for(site_col)
    if fspecs2:
        (dep_payload, dep_rmask, dep_valid, cap2_ok,
         inj2) = faults.apply_send_scatter(
            fspecs2, faults.active().seed, site_col, dep_payload,
            dep_rmask, dep_valid, cap_col, R, names)
        inj = inj + inj2
    want2 = _mask_to_copies(dep_rmask, dep_valid, R)     # [M, R]
    pos2 = jnp.cumsum(want2.astype(jnp.int32), axis=0) - 1
    ok2 = want2 & (pos2 < cap2_ok)
    if fspecs2 and cap2_ok < cap_col:
        inj = inj + jnp.sum((want2 & (pos2 >= cap2_ok)
                             & (pos2 < cap_col)).astype(jnp.float32))
    d2 = jnp.where(ok2, jnp.arange(R, dtype=jnp.int32)[None, :], R)
    s2 = jnp.where(ok2, pos2, 0)

    def scatter2(x):
        buf = compat.vary(jnp.zeros((R, cap_col) + x.shape[1:], x.dtype),
                          names)
        rep = jnp.broadcast_to(x[:, None], (M, R) + x.shape[1:])
        return buf.at[d2, s2].set(rep, mode="drop")

    send2 = jax.tree.map(scatter2, dep_payload)
    mask2 = compat.vary(jnp.zeros((R, cap_col), bool), names).at[
        d2, s2].set(ok2, mode="drop")
    recv = jax.tree.map(
        lambda b: lax.all_to_all(b, row_ax, split_axis=0, concat_axis=0),
        send2)
    recv_ok = lax.all_to_all(mask2, row_ax, split_axis=0, concat_axis=0)
    if fspecs2:
        recv_ok, inj_r2 = faults.apply_recv(fspecs2, faults.active().seed,
                                            site_col, recv_ok, names)
        inj = inj + inj_r2
    ovf2 = lax.psum(jnp.sum((want2 & ~ok2).astype(jnp.int32)), names)

    if stats is not None:
        nbuf1 = len(leaves) + 2          # + row mask + validity mask
        nbuf2 = len(leaves) + 1          # + validity mask
        by = (_buffer_bytes(send1) + _buffer_bytes(mask1)
              + _buffer_bytes(send2) + _buffer_bytes(mask2))
        items = lax.psum(jnp.sum(ok1.astype(jnp.float32))
                         + jnp.sum(ok2.astype(jnp.float32)), names)
        stats = stats._replace(
            calls=stats.calls + jnp.int32(nbuf1 + nbuf2),
            items=stats.items + items,
            bytes=stats.bytes + jnp.float32(by),
            slots=stats.slots + jnp.float32(C * cap_row + R * cap_col))
        if fspecs1 or fspecs2:
            stats = stats._replace(
                injected=stats.injected + lax.psum(inj, names))
    return ScatterResult(recv, recv_ok, ok1, ovf1 + ovf2, stats)


def request_reply(request, dest: jax.Array, valid: jax.Array,
                  answer_fn: Callable, capacity: int,
                  axis_names: Sequence[str], schedule: str = "grid",
                  site: str = ""
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """EXCHANGELABELS pattern: ship requests home, answer, ship answers back.

    ``answer_fn(recv, recv_ok) -> answers`` runs on the home shard with
    [p, C, ...] inputs.  Returns (answers[L, ...], answered[L] bool,
    overflow count)."""
    ex = routed_exchange(request, dest, valid, capacity, axis_names, schedule,
                         site=site)
    answers = answer_fn(ex.recv, ex.recv_ok)
    out = reply(ex, answers, axis_names, schedule)
    return out, ex.sent_ok, ex.overflow
