"""Two-level (grid) all-to-all — the paper's Section VI-A, TPU-native.

The paper arranges p MPI ranks on a virtual sqrt(p) x sqrt(p) grid and
routes every message through the intermediate rank sharing the sender's
column and the receiver's row, replacing one p-way sparse exchange by two
sqrt(p)-way exchanges: startup cost drops from O(alpha * p) to
O(alpha * sqrt(p)) at 2x volume.

On a TPU mesh this maps *structurally*: factor the mesh axis into
("row", "col") and run two ``lax.all_to_all`` hops, one along each
sub-axis.  Each hop only talks to sqrt(p) peers, which on a 2D/3D torus
keeps traffic on single-axis rings (the XLA all-to-all for a product axis
otherwise builds a p-way exchange).  This module is used by

  * the distributed MST label exchange / redistribution,
  * the MoE dispatch of the deepseek-v2 / llama4 configs
    (``moe.dispatch = "grid"``),

making the paper's communication idea a first-class framework feature.

Semantics: ``grid_all_to_all(x, ("row", "col"))`` inside shard_map is
element-wise identical to ``lax.all_to_all(x, ("row", "col"), 0, 0)``
with chunk dim 0 of size p = |row| * |col| (destination-major in, source-
major out), verified by tests for all shapes/dtypes.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def axis_sizes(names: Sequence[str]) -> Tuple[int, ...]:
    return tuple(compat.axis_size(n) for n in names)


def grid_all_to_all(x: jax.Array, axis_names: Tuple[str, str]) -> jax.Array:
    """Two-hop all-to-all over the product axis ``axis_names = (row, col)``.

    ``x``: [p, ...] — chunk d goes to device d (row-major over (row, col)).
    Returns [p, ...] — chunk s came from device s.
    Must be called inside shard_map with both axes present.
    """
    row, col = axis_names
    r, c = compat.axis_size(row), compat.axis_size(col)
    p = r * c
    assert x.shape[0] == p, (x.shape, p)
    xr = x.reshape((r, c) + x.shape[1:])
    # Hop 1 (paper: send to the intermediate PE in the destination's row,
    # the sender's column): exchange along the row axis, splitting the
    # destination-row dim.  After this, device (t, ci) holds the chunks of
    # every source in column ci destined for row t.
    y = lax.all_to_all(xr, row, split_axis=0, concat_axis=0)
    # y[s_row, d_col] = chunk of source (s_row, self_col) for dest (self_row, d_col)
    # Hop 2: exchange along the column axis, splitting the destination-col
    # dim and concatenating received chunks as a new source-col dim.
    z = lax.all_to_all(y[:, :, None], col, split_axis=1, concat_axis=2)
    # z[s_row, s_col, ...] = chunk of source (s_row, s_col) for this device
    return z.reshape((p,) + x.shape[1:])


def direct_all_to_all(x: jax.Array, axis_names: Tuple[str, str]) -> jax.Array:
    """Single-phase all-to-all over the product axis (the baseline)."""
    return lax.all_to_all(x, axis_names, split_axis=0, concat_axis=0)


def all_to_all_nd(x: jax.Array, axis_names: Sequence[str],
                  schedule: str = "grid") -> jax.Array:
    """Dispatch between the direct and the two-level schedule.

    ``schedule="grid"`` generalises to d mesh axes: one hop per axis, the
    paper's d-dimensional grid generalisation (Section VI-A); with
    d = log p it degenerates to the hypercube algorithm of Johnsson & Ho.
    """
    names = tuple(axis_names)
    if schedule == "direct" or len(names) == 1:
        return lax.all_to_all(x, names if len(names) > 1 else names[0],
                              split_axis=0, concat_axis=0)
    if schedule == "grid":
        if len(names) == 2:
            return grid_all_to_all(x, names)  # type: ignore[arg-type]
        # d-dimensional: peel one axis per hop.
        sizes = axis_sizes(names)
        p = 1
        for s in sizes:
            p *= s
        assert x.shape[0] == p
        xr = x.reshape(sizes + x.shape[1:])
        for d, name in enumerate(names):
            xr = lax.all_to_all(xr, name, split_axis=d, concat_axis=d)
        return xr.reshape((p,) + x.shape[1:])
    raise ValueError(schedule)
