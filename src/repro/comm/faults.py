"""Deterministic fault injection for the capacity-bounded exchanges
(ISSUE 7).

The engine's robustness contract is "overflow never silent" — but until
this module nothing between ``comm/exchange.py`` and the serving
gateway had ever been *tested* against an injected fault.  A
``FaultPlan`` describes a seeded, reproducible set of faults; while one
is active (``inject``), ``routed_exchange`` / ``scatter_updates`` apply
the matching specs at trace time and book every affected item into
``ExchangeStats.injected``, so a chaos run can assert the global
invariant end to end: every injected fault is either **detected**
(nonzero overflow, a raised replay error, or a ``VerifyFailure``) or
**tolerated** (bit-identical final MSF) — never silent
(``launch/chaos.py``).

Fault classes (``FaultSpec.kind``):

  * ``clip``         — capacity starvation: the send-side admission test
    runs at ``max(1, int(capacity * cap_frac))`` while the buffers keep
    their static shape, forcing the overflow counter to fire exactly as
    a genuinely undersized capacity would.  Detected at the transport
    layer by construction.
  * ``corrupt``      — payload corruption: a deterministic ``fraction``
    of valid items get bit ``bit`` of every float32 payload leaf
    XOR-flipped (weight bit-flips in MINEDGES candidates).  Silent at
    the transport layer — detection must come from the algorithm layer
    (verify checksum / oracle), which is the point of the harness.
  * ``shuffle_dest`` — misrouting: selected items' destinations rotate
    to ``(dest + 1) % p`` (``routed_exchange``) or their subscriber
    bitmask rotates one shard left (``scatter_updates``).  The rotated
    destination is still in range, so the transport accepts it; the
    wrong shard answers.
  * ``drop``         — receive-side slot drops: delivered slots are
    cleared from ``recv_ok`` *after* the exchange; the sender still
    sees ``sent_ok`` True and the overflow counter does not move —
    the strictest silent-loss model the transport allows.
  * ``stall``        — per-shard stall: shard ``shard`` contributes no
    items to this exchange (its ``valid`` mask is cleared *before* the
    overflow computation, so the stall is not self-detecting).
  * ``abort``        — shard death (ISSUE 9): the exchange raises the
    typed ``ShardAbort`` at a matched site on the selected ``rounds``
    (empty = any round), simulating a mid-run component failure without
    a process kill.  The engine's host drivers publish their round
    counter here (``set_round``); under an active abort spec every
    round bump clears the registered compiled-program caches so the
    target round's exchange actually retraces and the trace-time raise
    fires deterministically.  A death returns no transport stats by
    nature, so attribution is the exception itself: ``ShardAbort``
    carries the matched site, round and shard, and ``FaultSpec.matches``
    gates the site exactly like every other kind.

Determinism: item selection is a pure function of
``(plan.seed, spec site, item index, shard index)`` — an integer hash
evaluated at trace time, no RNG state — so a chaos cell reproduces
bit-identically across runs and JIT retraces.

jit/lru-cache staleness: the engine memoizes its compiled programs
(``functools.lru_cache`` around every shard_map builder), so flipping a
module global would be invisible to already-compiled code.  Builders
therefore register their ``cache_clear`` here
(``register_cache_clear``) and ``inject`` clears them on entry **and**
exit: entering forces a retrace with the faulted exchange code, leaving
restores a pristine fault-free compilation — which is how the
fault-free path stays bit-identical to the oracle after any number of
chaos cells.  Only registered builders get this guarantee; other
``comm/exchange.py`` callers (the MoE dispatch layers) are unaffected
unless they opt in.
"""
from __future__ import annotations

import contextlib
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

FAULT_KINDS = ("clip", "corrupt", "shuffle_dest", "drop", "stall",
               "abort")

# the labelled exchange call sites of the engine + the verifier's own
# exchange; FaultPlan.validate rejects anything else loudly — a typo'd
# site would otherwise inject nothing and "pass" chaos vacuously
KNOWN_SITES = ("", "minedges", "lookup", "contract", "relabel", "push",
               "ghost_push_row", "ghost_push_col",
               "prep", "fill", "subscribe", "verify")


class ShardAbort(RuntimeError):
    """A simulated shard death (``kind="abort"``): raised from a
    labelled exchange site on a selected round.  Carries the matched
    ``site``, the host driver's ``round`` at the raise, and the
    spec's ``shard`` — the attribution a dead shard can still give."""

    def __init__(self, site: str, round_: int, shard: int):
        self.site = site
        self.round = round_
        self.shard = shard
        super().__init__(
            f"shard {shard} aborted at site {site!r} in round {round_} "
            "(injected shard death)")


class FaultSpec(NamedTuple):
    """One injectable fault.  ``site`` targets a labelled exchange call
    site of the engine (``"minedges"``, ``"lookup"``, ``"contract"``,
    ``"relabel"``, ``"push"``, ``"prep"``, ``"fill"``, ``"subscribe"``);
    the empty default matches every site except ``"verify"`` — the
    self-check of ``core/verify.py`` must stay trustworthy under
    injection or chaos could never classify an outcome."""
    kind: str
    site: str = ""            # "" = any engine site (never "verify")
    fraction: float = 1.0     # of valid items affected (corrupt/drop/
    #                           shuffle_dest); selection is hash-seeded
    cap_frac: float = 0.5     # clip: effective capacity multiplier
    bit: int = 12             # corrupt: float32 bit to XOR-flip
    shard: int = 0            # stall/abort: which shard dies/goes quiet
    rounds: Tuple[int, ...] = ()  # abort: fire on these driver rounds
    #                               (1-based; empty = any round)

    def matches(self, site: str) -> bool:
        if site == "verify":
            return self.site == "verify"
        return self.site in ("", site)


class FaultPlan(NamedTuple):
    """A seeded, deterministic set of faults to inject."""
    seed: int = 0
    specs: Tuple[FaultSpec, ...] = ()

    def validate(self) -> "FaultPlan":
        for s in self.specs:
            if s.kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {s.kind!r}; one of {FAULT_KINDS}")
            if s.site not in KNOWN_SITES:
                raise ValueError(
                    f"unknown exchange site {s.site!r}; one of "
                    f"{KNOWN_SITES} (a typo'd site would inject nothing "
                    "and pass chaos vacuously)")
            if not (0.0 <= s.fraction <= 1.0):
                raise ValueError(f"fraction={s.fraction} not in [0, 1]")
            if not (0.0 < s.cap_frac <= 1.0):
                raise ValueError(f"cap_frac={s.cap_frac} not in (0, 1]")
            if not (0 <= s.bit < 32):
                raise ValueError(f"bit={s.bit} not a float32 bit")
            if any((not isinstance(r, int)) or r < 1 for r in s.rounds):
                raise ValueError(
                    f"rounds={s.rounds!r} must be 1-based round ints")
        return self


_ACTIVE: Optional[FaultPlan] = None
_CACHE_CLEARS: List[Callable[[], None]] = []
_ROUND: int = 0    # host drivers' published round counter (set_round)


def register_cache_clear(clear: Callable[[], None]) -> None:
    """Register a compiled-program cache invalidator (typically the
    ``cache_clear`` of an ``lru_cache``-wrapped shard_map builder).
    ``inject`` calls every registered invalidator on entry and exit so
    activating/deactivating a plan always forces a retrace."""
    if clear not in _CACHE_CLEARS:
        _CACHE_CLEARS.append(clear)


def _clear_caches() -> None:
    for clear in _CACHE_CLEARS:
        clear()


def active() -> Optional[FaultPlan]:
    return _ACTIVE


def set_round(r: int) -> None:
    """Publish the host driver's current (1-based, about-to-execute)
    round.  Round-selected aborts fire at trace time, and the engine
    memoizes compiled rounds — so while an ``abort`` spec is active,
    every round bump clears the registered caches, forcing the next
    step to retrace through the (possibly raising) exchange hooks.
    With no abort spec active this is a counter update and nothing
    else: zero effect on the fault-free or non-abort paths."""
    global _ROUND
    _ROUND = int(r)
    if _ACTIVE is not None and any(s.kind == "abort"
                                   for s in _ACTIVE.specs):
        _clear_caches()


def current_round() -> int:
    return _ROUND


def _maybe_abort(specs: Tuple[FaultSpec, ...], site: str) -> None:
    """Trace-time shard-death hook shared by every apply_* entry."""
    for s in specs:
        if s.kind == "abort" and (not s.rounds or _ROUND in s.rounds):
            raise ShardAbort(site, _ROUND, s.shard)


def specs_for(site: str) -> Tuple[FaultSpec, ...]:
    """The active plan's specs matching ``site`` (empty when inactive —
    the exchange primitives trace their pristine fault-free code)."""
    if _ACTIVE is None:
        return ()
    return tuple(s for s in _ACTIVE.specs if s.matches(site))


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Activate ``plan`` for the dynamic extent of the block.

    Clears every registered compiled-program cache on entry (so the
    faulted exchange code actually traces) and on exit (so subsequent
    fault-free runs recompile pristine — bit-identity of the fault-free
    path is a chaos acceptance criterion, not an accident).  Not
    reentrant: nested injection would make attribution ambiguous.
    """
    global _ACTIVE, _ROUND
    if _ACTIVE is not None:
        raise RuntimeError("a FaultPlan is already active (not reentrant)")
    plan.validate()
    _clear_caches()
    _ROUND = 0
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None
        _clear_caches()


# --------------------------------------------------------------------------
# trace-time application (called from comm/exchange.py)
# --------------------------------------------------------------------------

def _site_hash(site: str) -> int:
    h = 0
    for c in site:
        h = (h * 131 + ord(c)) & 0x7FFFFFFF
    return h


def _select(seed: int, site: str, salt: int, shape,
            fraction: float, names: Tuple[str, ...]) -> jax.Array:
    """Deterministic per-item selection mask: a pure integer hash of
    (seed, site, salt, flat index, shard index) — reproducible across
    retraces, varying across shards."""
    L = 1
    for d in shape:
        L *= int(d)
    idx = jnp.arange(L, dtype=jnp.uint32).reshape(shape)
    h = idx * jnp.uint32(2654435761)
    h = h ^ jnp.uint32((seed * 1000003 + _site_hash(site)
                        + salt * 9176) & 0xFFFFFFFF)
    h = h ^ (lax.axis_index(names).astype(jnp.uint32)
             * jnp.uint32(0x9E3779B9))
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(10_000)) < jnp.uint32(
        min(10_000, int(round(fraction * 10_000))))


def _flip_bit(x: jax.Array, sel: jax.Array, bit: int) -> jax.Array:
    if x.dtype != jnp.float32:
        return x
    raw = lax.bitcast_convert_type(x, jnp.int32)
    flipped = lax.bitcast_convert_type(raw ^ jnp.int32(1 << bit),
                                       jnp.float32)
    return jnp.where(sel, flipped, x)


def apply_send(specs: Tuple[FaultSpec, ...], seed: int, site: str,
               payload, dest: jax.Array, valid: jax.Array,
               capacity: int, p: int, names: Tuple[str, ...]):
    """Send-side faults for ``routed_exchange``.  Returns
    (payload, dest, valid, cap_ok, injected): ``cap_ok`` is the
    (possibly clipped) capacity the admission test must use — buffers
    keep the static ``capacity`` shape — and ``injected`` the float32
    per-shard count of affected items (psum'd by the caller via
    ``ExchangeStats``)."""
    _maybe_abort(specs, site)
    inj = jnp.float32(0.0)
    cap_ok = capacity
    me = lax.axis_index(names).astype(jnp.int32)
    for k, s in enumerate(specs):
        if s.kind == "stall":
            hit = valid & (me == jnp.int32(s.shard))
            inj = inj + jnp.sum(hit.astype(jnp.float32))
            valid = valid & ~hit
        elif s.kind == "clip":
            # affected items are exactly the forced overflow the caller
            # books (it charges the clipped rows to ``injected`` too)
            cap_ok = min(cap_ok, max(1, int(capacity * s.cap_frac)))
        elif s.kind == "corrupt":
            sel = _select(seed, site, k, dest.shape, s.fraction, names) \
                & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            payload = jax.tree.map(
                lambda x: _flip_bit(x, sel, s.bit)
                if x.ndim == 1 else x, payload)
        elif s.kind == "shuffle_dest":
            sel = _select(seed, site, k, dest.shape, s.fraction, names) \
                & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            dest = jnp.where(sel, (dest + 1) % jnp.int32(max(p, 1)), dest)
    return payload, dest, valid, cap_ok, inj


def apply_send_scatter(specs: Tuple[FaultSpec, ...], seed: int,
                       site: str, payload, dest_mask: jax.Array,
                       valid: jax.Array, capacity: int, p: int,
                       names: Tuple[str, ...]):
    """Send-side faults for ``scatter_updates`` (bitmask multicast)."""
    _maybe_abort(specs, site)
    inj = jnp.float32(0.0)
    cap_ok = capacity
    me = lax.axis_index(names).astype(jnp.int32)
    full = jnp.int32((1 << p) - 1)
    for k, s in enumerate(specs):
        if s.kind == "stall":
            hit = valid & (me == jnp.int32(s.shard))
            inj = inj + jnp.sum(hit.astype(jnp.float32))
            valid = valid & ~hit
        elif s.kind == "clip":
            cap_ok = min(cap_ok, max(1, int(capacity * s.cap_frac)))
        elif s.kind == "corrupt":
            sel = _select(seed, site, k, dest_mask.shape, s.fraction,
                          names) & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            payload = jax.tree.map(
                lambda x: _flip_bit(x, sel, s.bit)
                if x.ndim == 1 else x, payload)
        elif s.kind == "shuffle_dest":
            sel = _select(seed, site, k, dest_mask.shape, s.fraction,
                          names) & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            rot = ((dest_mask << 1) | ((dest_mask >> (p - 1)) & 1)) & full \
                if p > 1 else dest_mask
            dest_mask = jnp.where(sel, rot, dest_mask)
    return payload, dest_mask, valid, cap_ok, inj


def apply_recv(specs: Tuple[FaultSpec, ...], seed: int, site: str,
               recv_ok: jax.Array, names: Tuple[str, ...]):
    """Receive-side faults (``drop``): clear delivered slots from
    ``recv_ok`` after the exchange — the sender's ``sent_ok`` and the
    overflow counter are untouched, so the loss is silent at the
    transport layer by design.  Returns (recv_ok, injected)."""
    _maybe_abort(specs, site)
    inj = jnp.float32(0.0)
    for k, s in enumerate(specs):
        if s.kind != "drop":
            continue
        sel = _select(seed, site, 101 + k, recv_ok.shape, s.fraction,
                      names) & recv_ok
        inj = inj + jnp.sum(sel.astype(jnp.float32))
        recv_ok = recv_ok & ~sel
    return recv_ok, inj
