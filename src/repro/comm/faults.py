"""Deterministic fault injection for the capacity-bounded exchanges
(ISSUE 7).

The engine's robustness contract is "overflow never silent" — but until
this module nothing between ``comm/exchange.py`` and the serving
gateway had ever been *tested* against an injected fault.  A
``FaultPlan`` describes a seeded, reproducible set of faults; while one
is active (``inject``), ``routed_exchange`` / ``scatter_updates`` apply
the matching specs at trace time and book every affected item into
``ExchangeStats.injected``, so a chaos run can assert the global
invariant end to end: every injected fault is either **detected**
(nonzero overflow, a raised replay error, or a ``VerifyFailure``) or
**tolerated** (bit-identical final MSF) — never silent
(``launch/chaos.py``).

Fault classes (``FaultSpec.kind``):

  * ``clip``         — capacity starvation: the send-side admission test
    runs at ``max(1, int(capacity * cap_frac))`` while the buffers keep
    their static shape, forcing the overflow counter to fire exactly as
    a genuinely undersized capacity would.  Detected at the transport
    layer by construction.
  * ``corrupt``      — payload corruption: a deterministic ``fraction``
    of valid items get bit ``bit`` of every float32 payload leaf
    XOR-flipped (weight bit-flips in MINEDGES candidates).  Silent at
    the transport layer — detection must come from the algorithm layer
    (verify checksum / oracle), which is the point of the harness.
  * ``shuffle_dest`` — misrouting: selected items' destinations rotate
    to ``(dest + 1) % p`` (``routed_exchange``) or their subscriber
    bitmask rotates one shard left (``scatter_updates``).  The rotated
    destination is still in range, so the transport accepts it; the
    wrong shard answers.
  * ``drop``         — receive-side slot drops: delivered slots are
    cleared from ``recv_ok`` *after* the exchange; the sender still
    sees ``sent_ok`` True and the overflow counter does not move —
    the strictest silent-loss model the transport allows.
  * ``stall``        — per-shard stall: shard ``shard`` contributes no
    items to this exchange (its ``valid`` mask is cleared *before* the
    overflow computation, so the stall is not self-detecting).

Determinism: item selection is a pure function of
``(plan.seed, spec site, item index, shard index)`` — an integer hash
evaluated at trace time, no RNG state — so a chaos cell reproduces
bit-identically across runs and JIT retraces.

jit/lru-cache staleness: the engine memoizes its compiled programs
(``functools.lru_cache`` around every shard_map builder), so flipping a
module global would be invisible to already-compiled code.  Builders
therefore register their ``cache_clear`` here
(``register_cache_clear``) and ``inject`` clears them on entry **and**
exit: entering forces a retrace with the faulted exchange code, leaving
restores a pristine fault-free compilation — which is how the
fault-free path stays bit-identical to the oracle after any number of
chaos cells.  Only registered builders get this guarantee; other
``comm/exchange.py`` callers (the MoE dispatch layers) are unaffected
unless they opt in.
"""
from __future__ import annotations

import contextlib
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

FAULT_KINDS = ("clip", "corrupt", "shuffle_dest", "drop", "stall")


class FaultSpec(NamedTuple):
    """One injectable fault.  ``site`` targets a labelled exchange call
    site of the engine (``"minedges"``, ``"lookup"``, ``"contract"``,
    ``"relabel"``, ``"push"``, ``"prep"``, ``"fill"``, ``"subscribe"``);
    the empty default matches every site except ``"verify"`` — the
    self-check of ``core/verify.py`` must stay trustworthy under
    injection or chaos could never classify an outcome."""
    kind: str
    site: str = ""            # "" = any engine site (never "verify")
    fraction: float = 1.0     # of valid items affected (corrupt/drop/
    #                           shuffle_dest); selection is hash-seeded
    cap_frac: float = 0.5     # clip: effective capacity multiplier
    bit: int = 12             # corrupt: float32 bit to XOR-flip
    shard: int = 0            # stall: which shard goes quiet

    def matches(self, site: str) -> bool:
        if site == "verify":
            return self.site == "verify"
        return self.site in ("", site)


class FaultPlan(NamedTuple):
    """A seeded, deterministic set of faults to inject."""
    seed: int = 0
    specs: Tuple[FaultSpec, ...] = ()

    def validate(self) -> "FaultPlan":
        for s in self.specs:
            if s.kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {s.kind!r}; one of {FAULT_KINDS}")
            if not (0.0 <= s.fraction <= 1.0):
                raise ValueError(f"fraction={s.fraction} not in [0, 1]")
            if not (0.0 < s.cap_frac <= 1.0):
                raise ValueError(f"cap_frac={s.cap_frac} not in (0, 1]")
            if not (0 <= s.bit < 32):
                raise ValueError(f"bit={s.bit} not a float32 bit")
        return self


_ACTIVE: Optional[FaultPlan] = None
_CACHE_CLEARS: List[Callable[[], None]] = []


def register_cache_clear(clear: Callable[[], None]) -> None:
    """Register a compiled-program cache invalidator (typically the
    ``cache_clear`` of an ``lru_cache``-wrapped shard_map builder).
    ``inject`` calls every registered invalidator on entry and exit so
    activating/deactivating a plan always forces a retrace."""
    if clear not in _CACHE_CLEARS:
        _CACHE_CLEARS.append(clear)


def _clear_caches() -> None:
    for clear in _CACHE_CLEARS:
        clear()


def active() -> Optional[FaultPlan]:
    return _ACTIVE


def specs_for(site: str) -> Tuple[FaultSpec, ...]:
    """The active plan's specs matching ``site`` (empty when inactive —
    the exchange primitives trace their pristine fault-free code)."""
    if _ACTIVE is None:
        return ()
    return tuple(s for s in _ACTIVE.specs if s.matches(site))


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Activate ``plan`` for the dynamic extent of the block.

    Clears every registered compiled-program cache on entry (so the
    faulted exchange code actually traces) and on exit (so subsequent
    fault-free runs recompile pristine — bit-identity of the fault-free
    path is a chaos acceptance criterion, not an accident).  Not
    reentrant: nested injection would make attribution ambiguous.
    """
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a FaultPlan is already active (not reentrant)")
    plan.validate()
    _clear_caches()
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None
        _clear_caches()


# --------------------------------------------------------------------------
# trace-time application (called from comm/exchange.py)
# --------------------------------------------------------------------------

def _site_hash(site: str) -> int:
    h = 0
    for c in site:
        h = (h * 131 + ord(c)) & 0x7FFFFFFF
    return h


def _select(seed: int, site: str, salt: int, shape,
            fraction: float, names: Tuple[str, ...]) -> jax.Array:
    """Deterministic per-item selection mask: a pure integer hash of
    (seed, site, salt, flat index, shard index) — reproducible across
    retraces, varying across shards."""
    L = 1
    for d in shape:
        L *= int(d)
    idx = jnp.arange(L, dtype=jnp.uint32).reshape(shape)
    h = idx * jnp.uint32(2654435761)
    h = h ^ jnp.uint32((seed * 1000003 + _site_hash(site)
                        + salt * 9176) & 0xFFFFFFFF)
    h = h ^ (lax.axis_index(names).astype(jnp.uint32)
             * jnp.uint32(0x9E3779B9))
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(10_000)) < jnp.uint32(
        min(10_000, int(round(fraction * 10_000))))


def _flip_bit(x: jax.Array, sel: jax.Array, bit: int) -> jax.Array:
    if x.dtype != jnp.float32:
        return x
    raw = lax.bitcast_convert_type(x, jnp.int32)
    flipped = lax.bitcast_convert_type(raw ^ jnp.int32(1 << bit),
                                       jnp.float32)
    return jnp.where(sel, flipped, x)


def apply_send(specs: Tuple[FaultSpec, ...], seed: int, site: str,
               payload, dest: jax.Array, valid: jax.Array,
               capacity: int, p: int, names: Tuple[str, ...]):
    """Send-side faults for ``routed_exchange``.  Returns
    (payload, dest, valid, cap_ok, injected): ``cap_ok`` is the
    (possibly clipped) capacity the admission test must use — buffers
    keep the static ``capacity`` shape — and ``injected`` the float32
    per-shard count of affected items (psum'd by the caller via
    ``ExchangeStats``)."""
    inj = jnp.float32(0.0)
    cap_ok = capacity
    me = lax.axis_index(names).astype(jnp.int32)
    for k, s in enumerate(specs):
        if s.kind == "stall":
            hit = valid & (me == jnp.int32(s.shard))
            inj = inj + jnp.sum(hit.astype(jnp.float32))
            valid = valid & ~hit
        elif s.kind == "clip":
            # affected items are exactly the forced overflow the caller
            # books (it charges the clipped rows to ``injected`` too)
            cap_ok = min(cap_ok, max(1, int(capacity * s.cap_frac)))
        elif s.kind == "corrupt":
            sel = _select(seed, site, k, dest.shape, s.fraction, names) \
                & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            payload = jax.tree.map(
                lambda x: _flip_bit(x, sel, s.bit)
                if x.ndim == 1 else x, payload)
        elif s.kind == "shuffle_dest":
            sel = _select(seed, site, k, dest.shape, s.fraction, names) \
                & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            dest = jnp.where(sel, (dest + 1) % jnp.int32(max(p, 1)), dest)
    return payload, dest, valid, cap_ok, inj


def apply_send_scatter(specs: Tuple[FaultSpec, ...], seed: int,
                       site: str, payload, dest_mask: jax.Array,
                       valid: jax.Array, capacity: int, p: int,
                       names: Tuple[str, ...]):
    """Send-side faults for ``scatter_updates`` (bitmask multicast)."""
    inj = jnp.float32(0.0)
    cap_ok = capacity
    me = lax.axis_index(names).astype(jnp.int32)
    full = jnp.int32((1 << p) - 1)
    for k, s in enumerate(specs):
        if s.kind == "stall":
            hit = valid & (me == jnp.int32(s.shard))
            inj = inj + jnp.sum(hit.astype(jnp.float32))
            valid = valid & ~hit
        elif s.kind == "clip":
            cap_ok = min(cap_ok, max(1, int(capacity * s.cap_frac)))
        elif s.kind == "corrupt":
            sel = _select(seed, site, k, dest_mask.shape, s.fraction,
                          names) & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            payload = jax.tree.map(
                lambda x: _flip_bit(x, sel, s.bit)
                if x.ndim == 1 else x, payload)
        elif s.kind == "shuffle_dest":
            sel = _select(seed, site, k, dest_mask.shape, s.fraction,
                          names) & valid
            inj = inj + jnp.sum(sel.astype(jnp.float32))
            rot = ((dest_mask << 1) | ((dest_mask >> (p - 1)) & 1)) & full \
                if p > 1 else dest_mask
            dest_mask = jnp.where(sel, rot, dest_mask)
    return payload, dest_mask, valid, cap_ok, inj


def apply_recv(specs: Tuple[FaultSpec, ...], seed: int, site: str,
               recv_ok: jax.Array, names: Tuple[str, ...]):
    """Receive-side faults (``drop``): clear delivered slots from
    ``recv_ok`` after the exchange — the sender's ``sent_ok`` and the
    overflow counter are untouched, so the loss is silent at the
    transport layer by design.  Returns (recv_ok, injected)."""
    inj = jnp.float32(0.0)
    for k, s in enumerate(specs):
        if s.kind != "drop":
            continue
        sel = _select(seed, site, 101 + k, recv_ok.shape, s.fraction,
                      names) & recv_ok
        inj = inj + jnp.sum(sel.astype(jnp.float32))
        recv_ok = recv_ok & ~sel
    return recv_ok, inj
