"""Distributed sample sort (AMS-sort analog, Section II-A / VI-C).

The paper uses hypercube quicksort for small inputs and two-level sample
sort for large ones — data is moved a constant number of times.  The
shard_map implementation here follows the same structure:

  1. local sort,
  2. regular oversampling -> allgather -> global splitters,
  3. one (optionally grid two-level) all-to-all bucket exchange,
  4. local merge of received runs.

Static shapes: the bucket exchange uses a capacity factor; overflow is
counted and returned (never silently dropped) — the dynamic caller can
retry with a larger factor.  Keys are single int32/float32; multi-key
orders (the lexicographic edge order) are realised by a stable local sort
of secondary keys before/after the distribution pass, since distribution
only needs to agree on *which shard* a key lands on.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.comm.exchange import routed_exchange


class SortResult(NamedTuple):
    key: jax.Array      # [cap] locally sorted received keys (+inf padded)
    payload: tuple      # pytree of [cap, ...]
    ok: jax.Array       # [cap] bool validity
    overflow: jax.Array  # [] int32


def sample_sort(key: jax.Array, payload, valid: jax.Array,
                axis_names: Sequence[str], *, oversample: int = 32,
                capacity_factor: float = 2.0,
                schedule: str = "grid") -> SortResult:
    """Globally sort (key, payload) across shards. Inside shard_map."""
    names = tuple(axis_names)
    p = 1
    for n in names:
        p *= compat.axis_size(n)
    L = key.shape[0]
    kf = jnp.where(valid, key, jnp.inf).astype(jnp.float32)
    order = jnp.argsort(kf, stable=True)
    ks = kf[order]
    ps = jax.tree.map(lambda x: x[order], payload)
    vs = valid[order]

    # regular sampling from the locally sorted *valid* prefix
    s = min(oversample, L)
    nvalid = jnp.maximum(jnp.sum(vs.astype(jnp.int32)), 1)
    samp_idx = (jnp.arange(s) * nvalid) // s
    samples = ks[samp_idx]
    all_samples = lax.all_gather(samples, names, tiled=True)  # [p*s]
    sorted_samples = jnp.sort(all_samples)
    spl_idx = (jnp.arange(1, p) * (p * s)) // p
    splitters = sorted_samples[spl_idx]  # [p-1]

    dest = jnp.searchsorted(splitters, ks, side="right").astype(jnp.int32)
    dest = jnp.where(vs, dest, -1)
    capacity = max(1, int(-(-L * capacity_factor // p)))
    ex = routed_exchange((ks,) + tuple(jax.tree.leaves(ps)), dest, vs,
                         capacity, names, schedule)
    recv = ex.recv
    rk = recv[0].reshape(p * capacity)
    rk = jnp.where(ex.recv_ok.reshape(-1), rk, jnp.inf)
    rorder = jnp.argsort(rk, stable=True)
    rk = rk[rorder]
    treedef = jax.tree.structure(payload)
    rp = jax.tree.unflatten(
        treedef,
        [r.reshape((p * capacity,) + r.shape[2:])[rorder] for r in recv[1:]])
    rok = ex.recv_ok.reshape(-1)[rorder]
    return SortResult(rk, rp, rok, ex.overflow)


def splitters_from_sorted(ks: jax.Array, p: int, s: int,
                          axis_names: Sequence[str]) -> jax.Array:
    """Expose the splitter computation for reuse (redistribution by rank)."""
    L = ks.shape[0]
    samp_idx = (jnp.arange(min(s, L)) * L) // min(s, L)
    samples = ks[samp_idx]
    all_samples = lax.all_gather(samples, tuple(axis_names), tiled=True)
    sorted_samples = jnp.sort(all_samples)
    spl_idx = (jnp.arange(1, p) * sorted_samples.shape[0]) // p
    return sorted_samples[spl_idx]
