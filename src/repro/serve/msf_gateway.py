"""MSF serving gateway (ISSUE 6): plan-LRU + continuous batching.

The "compile once, serve heavy traffic" loop the RoundPlan machinery
(ISSUE 5) was built for.  A stream of graph requests is admitted into a
queue; the gateway groups same-key requests into batches and serves
each batch through **one** compiled planned program — ``jax.vmap`` of
the per-shard plan executor over a leading batch axis
(``core/distributed_sharded.py: execute_plan_batched``) — so B graphs
cost one dispatch.

Request lifecycle::

    submit(req)
      └─ cache key = plan_cache_key(family, n, p, cap rung, algorithm,
         levers)   — the per-shard edge capacity is padded UP to the
         next power-of-two rung, so same-family graphs of slightly
         different edge counts land on one array shape → one plan →
         one compiled program
    step()
      ├─ admit up to ``batch_slots`` queued requests sharing the
      │  queue head's key (continuous batching; other keys keep their
      │  queue order)
      ├─ plan-LRU lookup
      │    hit  → reuse the cached padded plan
      │    miss → measure once on the first request's graph
      │           (``plan_sharded_msf``), ``pad(pad_margin)``, insert;
      │           evict the least-recently-used entry beyond
      │           ``cache_size``
      ├─ batched planned execution; per-request overflow / residual is
      │  surfaced independently, so an ill-fitting request replans
      │  alone (one fresh measured pass) without poisoning batchmates
      └─ drift: each entry tracks its replan rate; past
         ``replan_threshold`` (with ``min_samples`` observations) the
         entry is re-measured from a drifted graph and refreshed with
         ``pad(pad_margin)`` headroom

Every result carries the engine's exactness contract: overflow 0
(batched fit or replanned), reducible to the undirected input edge set
via ``eid``.  The slot-pool substrate this models itself on is
``serve/engine.py``; the accounting mirrors its queue/slot structure
with plans in place of KV caches.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (execute_plan_batched,
                                            plan_sharded_msf)
from repro.core.plan import RoundPlan, plan_cache_key


@dataclasses.dataclass
class MSFRequest:
    """One graph to solve: undirected edge arrays + vertex count.

    ``family`` is the traffic label used for plan-cache keying (a wrong
    label can only cost replans, never correctness).  Results are
    filled by the gateway: ``edges`` are indices into the request's
    undirected input arrays, ``weight``/``count`` the forest weight and
    edge count, ``served_via`` is ``"batched"`` or ``"replanned"``.
    """
    rid: int
    family: str
    u: np.ndarray
    v: np.ndarray
    w: np.ndarray
    n: int
    edges: Optional[np.ndarray] = None
    weight: float = 0.0
    count: int = 0
    done: bool = False
    served_via: str = ""
    latency: float = 0.0
    _t_submit: float = 0.0


@dataclasses.dataclass
class GatewayStats:
    submitted: int = 0
    served: int = 0
    batches: int = 0
    hits: int = 0          # plan-cache lookups that found an entry
    misses: int = 0        # lookups that measured a fresh plan
    evictions: int = 0     # LRU entries dropped at capacity
    replans: int = 0       # requests that fell back to a measured pass
    refreshes: int = 0     # drift-triggered entry re-measurements

    @property
    def hit_rate(self) -> float:
        lookups = self.hits + self.misses
        return self.hits / lookups if lookups else 0.0

    @property
    def replan_rate(self) -> float:
        return self.replans / self.served if self.served else 0.0


@dataclasses.dataclass
class _CacheEntry:
    plan: RoundPlan
    cap: int               # the padded per-shard capacity (ladder rung)
    served: int = 0        # requests executed under this entry
    replans: int = 0       # ... of which fell back to a measured pass


class MSFGateway:
    """Continuous-batching MSF server over one device mesh."""

    def __init__(self, mesh: jax.sharding.Mesh, *,
                 axis_names: Optional[Sequence[str]] = None,
                 algorithm: str = "boruvka",
                 cache_size: int = 8, batch_slots: int = 4,
                 pad_margin: float = 0.25,
                 replan_threshold: float = 0.34, min_samples: int = 6):
        self.mesh = mesh
        self.axes = tuple(axis_names or mesh.axis_names)
        self.p = 1
        for a in self.axes:
            self.p *= mesh.shape[a]
        self.algorithm = algorithm
        self.cache_size = int(cache_size)
        self.batch_slots = int(batch_slots)
        self.pad_margin = float(pad_margin)
        self.replan_threshold = float(replan_threshold)
        self.min_samples = int(min_samples)
        self.queue: Deque[MSFRequest] = collections.deque()
        # key -> entry; OrderedDict insertion/move order IS the LRU order
        self.cache: "collections.OrderedDict[str, _CacheEntry]" = \
            collections.OrderedDict()
        self.stats = GatewayStats()

    # -- keying ------------------------------------------------------------

    def _cap_rung(self, req: MSFRequest) -> int:
        """Per-shard edge capacity padded up to the power-of-two ladder."""
        need = max(1, -(-2 * len(req.u) // self.p))
        return 1 << (need - 1).bit_length()

    def _key(self, req: MSFRequest) -> str:
        return plan_cache_key(req.family, req.n, self.p,
                              self._cap_rung(req), self.algorithm)

    # -- admission ---------------------------------------------------------

    def submit(self, req: MSFRequest) -> None:
        if req.n < 1:
            raise ValueError(f"request {req.rid}: n must be >= 1")
        if not (len(req.u) == len(req.v) == len(req.w)):
            raise ValueError(
                f"request {req.rid}: edge arrays disagree in length "
                f"({len(req.u)}/{len(req.v)}/{len(req.w)})")
        req._t_submit = time.monotonic()
        self.queue.append(req)
        self.stats.submitted += 1

    # -- serving -----------------------------------------------------------

    def step(self) -> List[MSFRequest]:
        """Serve one batch: admit same-key requests, execute, fill results.

        Returns the list of requests completed by this step (empty if
        the queue was empty).
        """
        if not self.queue:
            return []
        key = self._key(self.queue[0])
        batch: List[MSFRequest] = []
        rest: Deque[MSFRequest] = collections.deque()
        while self.queue:
            r = self.queue.popleft()
            if len(batch) < self.batch_slots and self._key(r) == key:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest

        cap = self._cap_rung(batch[0])
        n = batch[0].n
        graphs = [build_dist_graph(r.u, r.v, r.w, n, self.p, cap=cap)[0]
                  for r in batch]

        entry = self.cache.get(key)
        if entry is not None:
            self.cache.move_to_end(key)
            self.stats.hits += 1
        else:
            self.stats.misses += 1
            entry = self._measure(key, graphs[0], n, cap)

        results, replanned = execute_plan_batched(
            graphs, n, self.mesh, entry.plan, axis_names=self.axes,
            replan=True)
        entry.served += len(batch)
        entry.replans += len(replanned)
        self.stats.replans += len(replanned)

        # drift: a key whose traffic keeps outgrowing its plan gets one
        # fresh measurement (off a graph that actually overflowed) and
        # new pad() headroom, instead of replanning forever
        if (replanned and entry.served >= self.min_samples
                and entry.replans / entry.served > self.replan_threshold):
            self._measure(key, graphs[replanned[-1]], n, cap)
            self.stats.refreshes += 1

        now = time.monotonic()
        for i, (req, res) in enumerate(zip(batch, results)):
            mask = np.asarray(res[0])
            eid = np.asarray(graphs[i].eid)
            req.edges = np.unique(eid[mask])
            req.weight = float(res[1])
            req.count = int(res[2])
            req.served_via = "replanned" if i in replanned else "batched"
            req.latency = now - req._t_submit
            req.done = True
        self.stats.served += len(batch)
        self.stats.batches += 1
        return batch

    def run(self, max_steps: int = 100_000) -> None:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1

    # -- plan lifecycle ----------------------------------------------------

    def _measure(self, key: str, graph, n: int, cap: int) -> _CacheEntry:
        """Measure a plan off ``graph``, pad it, (re)install the entry."""
        plan = plan_sharded_msf(graph, n, self.mesh,
                                algorithm=self.algorithm,
                                axis_names=self.axes)
        assert plan.cache_key(key.split("|", 1)[0]) == key, \
            (plan.cache_key(key.split("|", 1)[0]), key)
        entry = _CacheEntry(plan=plan.pad(self.pad_margin), cap=cap)
        self.cache[key] = entry
        self.cache.move_to_end(key)
        while len(self.cache) > self.cache_size:
            self.cache.popitem(last=False)
            self.stats.evictions += 1
        return entry
