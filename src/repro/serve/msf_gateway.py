"""MSF serving gateway (ISSUE 6): plan-LRU + continuous batching,
hardened against adversarial traffic and faulty execution (ISSUE 7).

The "compile once, serve heavy traffic" loop the RoundPlan machinery
(ISSUE 5) was built for.  A stream of graph requests is admitted into a
queue; the gateway groups same-key requests into batches and serves
each batch through **one** compiled planned program — ``jax.vmap`` of
the per-shard plan executor over a leading batch axis
(``core/distributed_sharded.py: execute_plan_batched``) — so B graphs
cost one dispatch.

Request lifecycle::

    submit(req)
      ├─ ``validate_graph`` admission control: NaN/±inf weights,
      │  out-of-range vertex ids, mismatched arrays and over-cap edge
      │  lists are rejected with a typed ``AdmissionError`` *here* —
      │  a non-finite weight would silently alias the engine's padding
      │  sentinel, the exact wrong-MSF-with-no-signal failure the
      │  exchange layer's overflow contract exists to prevent
      └─ cache key = plan_cache_key(family, n, p, cap rung, algorithm)
         — the per-shard edge capacity is padded UP to the next
         power-of-two rung, so same-family graphs of slightly
         different edge counts land on one array shape → one plan →
         one compiled program
    step()
      ├─ deadline sweep: a request whose ``deadline`` (seconds from
      │  submit) already passed is rejected, not served late
      ├─ admit up to ``batch_slots`` queued *ready* requests sharing
      │  the queue head's key (continuous batching; backoff-deferred
      │  requests and other keys keep their queue order)
      ├─ plan-LRU lookup (hit → reuse; miss → measure + pad + insert,
      │  LRU-evict past ``cache_size``)
      ├─ batched planned execution with ``replan="defer"`` (and
      │  optionally ``verify=True``): per-request overflow / residual /
      │  verification failure comes back as a per-index flag instead of
      │  an in-library fallback, so the gateway owns the retry ladder:
      │    retry budget left → one strict measured replan, re-verified
      │      — success serves the request (``served_via="replanned"``)
      │    replan itself fails verification → requeue with exponential
      │      backoff (``backoff_base * 2**retries``)
      │    budget exhausted → typed rejection (never an infinite loop:
      │      every flagged request either serves or rejects within
      │      ``max_retries_per_request`` retries)
      ├─ circuit breaker: ``breaker_threshold`` *consecutive* steps
      │  with a still-failing request trip the entry — it is dropped
      │  from the LRU (a fresh measurement will replace it) and the
      │  poisoning requests are rejected immediately, so one hostile
      │  request can never replan-storm ``run()``
      └─ drift: each entry tracks its replan rate; past
         ``replan_threshold`` (with ``min_samples`` observations) the
         entry is re-measured from a drifted graph and refreshed with
         ``pad(pad_margin)`` headroom

Every served result carries the engine's exactness contract: overflow 0
(batched fit or replanned), reducible to the undirected input edge set
via ``eid``; with ``verify=True`` it additionally passed the on-device
self-check of ``core/verify.py``.  Rejections are never silent: the
request is marked ``served_via="rejected"`` with ``error`` set, and
``GatewayStats`` counts rejected / retried / deadline_missed /
breaker_trips / verify_failures.  The slot-pool substrate this models
itself on is ``serve/engine.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Sequence

import numpy as np

import jax

from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (DEFAULT_CKPT_EVERY,
                                            _replan_with_plan,
                                            execute_plan_batched,
                                            plan_sharded_msf)
from repro.core.graph import CapacityError
from repro.core.msf_checkpoint import CheckpointError, MSFCheckpoint
from repro.core.plan import RoundPlan, plan_cache_key
from repro.core.verify import VerifyFailure, verify_forest


class GatewayError(RuntimeError):
    """Base of the gateway's typed serving errors (ISSUE 7)."""


class AdmissionError(GatewayError, ValueError):
    """A request failed admission control (``validate_graph``).  Also a
    ``ValueError`` so pre-hardening callers catching that keep working."""


def validate_graph(u, v, w, n: int, *, max_edges: Optional[int] = None,
                   rid: Optional[int] = None) -> None:
    """Admission control: reject graphs the engine cannot serve honestly.

    Raises ``AdmissionError`` for: ``n < 1``; mismatched edge-array
    lengths; non-integer endpoint arrays; NaN/±inf weights (``+inf`` is
    the engine's padding sentinel — admitting it would silently drop
    the edge, a wrong MSF with no signal); endpoint ids outside
    ``[0, n)``; more than ``max_edges`` edges (when given).  Self-loops
    and duplicate edges are *tolerated* — the engines handle both
    (self-loops die in preprocessing, parallel edges lose the (w, eid)
    tie) — so adversarial inputs of that shape serve normally.
    """
    tag = f"request {rid}: " if rid is not None else ""
    if n < 1:
        raise AdmissionError(tag + "n must be >= 1")
    u = np.asarray(u)
    v = np.asarray(v)
    w = np.asarray(w)
    if not (len(u) == len(v) == len(w)):
        raise AdmissionError(
            tag + f"edge arrays disagree in length "
            f"({len(u)}/{len(v)}/{len(w)})")
    if max_edges is not None and len(u) > max_edges:
        raise AdmissionError(
            tag + f"{len(u)} edges exceed the admission cap "
            f"max_edges={max_edges}")
    if len(u) == 0:
        return
    if not (np.issubdtype(u.dtype, np.integer)
            and np.issubdtype(v.dtype, np.integer)):
        raise AdmissionError(tag + "endpoint arrays must be integer-"
                             f"typed (got {u.dtype}/{v.dtype})")
    nonfinite = int((~np.isfinite(np.asarray(w, np.float32))).sum())
    if nonfinite:
        raise AdmissionError(
            tag + f"{nonfinite} weights are NaN/±inf; finite float32 "
            "required (+inf is the engine's padding sentinel and would "
            "silently drop the edge)")
    oob = int(((u < 0) | (u >= n) | (v < 0) | (v >= n)).sum())
    if oob:
        raise AdmissionError(
            tag + f"{oob} endpoint ids outside [0, {n})")


@dataclasses.dataclass
class MSFRequest:
    """One graph to solve: undirected edge arrays + vertex count.

    ``family`` is the traffic label used for plan-cache keying (a wrong
    label can only cost replans, never correctness).  ``deadline``
    optionally bounds serving latency (seconds from submit): a request
    still queued past it is rejected, never served late.  Results are
    filled by the gateway: ``edges`` are indices into the request's
    undirected input arrays, ``weight``/``count`` the forest weight and
    edge count, ``served_via`` is ``"batched"``, ``"replanned"`` or
    ``"rejected"`` (``error`` says why; ``retries`` counts ladder
    attempts).
    """
    rid: int
    family: str
    u: np.ndarray
    v: np.ndarray
    w: np.ndarray
    n: int
    deadline: Optional[float] = None
    edges: Optional[np.ndarray] = None
    weight: float = 0.0
    count: int = 0
    done: bool = False
    served_via: str = ""
    error: str = ""
    retries: int = 0
    latency: float = 0.0
    _t_submit: float = 0.0
    _not_before: float = 0.0   # backoff gate (monotonic clock)
    # last certified checkpoint from a retry-ladder rung (ISSUE 9): the
    # next rung resumes here instead of re-executing from round 0
    _ckpt: Optional[MSFCheckpoint] = None


@dataclasses.dataclass
class GatewayStats:
    submitted: int = 0
    served: int = 0
    batches: int = 0
    hits: int = 0           # plan-cache lookups that found an entry
    misses: int = 0         # lookups that measured a fresh plan
    evictions: int = 0      # LRU entries dropped at capacity
    replans: int = 0        # requests served via a measured fallback
    refreshes: int = 0      # drift-triggered entry re-measurements
    rejected: int = 0       # admission / budget / breaker rejections
    retried: int = 0        # retry-ladder attempts (flagged requests)
    deadline_missed: int = 0  # ... of the rejections, past-deadline ones
    breaker_trips: int = 0  # cache entries dropped by the breaker
    verify_failures: int = 0  # self-check failures (verify=True only)
    resumed: int = 0        # ladder rungs resumed from a checkpoint
    rounds_saved: int = 0   # rounds *not* re-executed thanks to resume

    @property
    def hit_rate(self) -> float:
        lookups = self.hits + self.misses
        return self.hits / lookups if lookups else 0.0

    @property
    def replan_rate(self) -> float:
        return self.replans / self.served if self.served else 0.0


@dataclasses.dataclass
class _CacheEntry:
    plan: RoundPlan
    cap: int               # the padded per-shard capacity (ladder rung)
    served: int = 0        # requests executed under this entry
    replans: int = 0       # ... of which the plan did not fit
    fails: int = 0         # consecutive steps with a still-failing req


class MSFGateway:
    """Continuous-batching MSF server over one device mesh."""

    def __init__(self, mesh: jax.sharding.Mesh, *,
                 axis_names: Optional[Sequence[str]] = None,
                 algorithm: str = "boruvka",
                 cache_size: int = 8, batch_slots: int = 4,
                 pad_margin: float = 0.25,
                 replan_threshold: float = 0.34, min_samples: int = 6,
                 max_retries_per_request: int = 2,
                 breaker_threshold: int = 3,
                 backoff_base: float = 0.05,
                 verify: bool = False,
                 max_edges: Optional[int] = None,
                 ckpt_every: Optional[int] = DEFAULT_CKPT_EVERY):
        self.mesh = mesh
        self.axes = tuple(axis_names or mesh.axis_names)
        self.p = 1
        for a in self.axes:
            self.p *= mesh.shape[a]
        self.algorithm = algorithm
        self.cache_size = int(cache_size)
        self.batch_slots = int(batch_slots)
        self.pad_margin = float(pad_margin)
        self.replan_threshold = float(replan_threshold)
        self.min_samples = int(min_samples)
        self.max_retries_per_request = int(max_retries_per_request)
        self.breaker_threshold = int(breaker_threshold)
        self.backoff_base = float(backoff_base)
        self.verify = bool(verify)
        self.max_edges = max_edges
        # checkpoint cadence for retry-ladder rungs (ISSUE 9; None
        # disables): a failed rung leaves its last certified checkpoint
        # on the request, and the next rung resumes there
        self.ckpt_every = None if ckpt_every is None else int(ckpt_every)
        self.queue: Deque[MSFRequest] = collections.deque()
        # key -> entry; OrderedDict insertion/move order IS the LRU order
        self.cache: "collections.OrderedDict[str, _CacheEntry]" = \
            collections.OrderedDict()
        self.stats = GatewayStats()

    # -- keying ------------------------------------------------------------

    def _cap_rung(self, req: MSFRequest) -> int:
        """Per-shard edge capacity padded up to the power-of-two ladder."""
        need = max(1, -(-2 * len(req.u) // self.p))
        return 1 << (need - 1).bit_length()

    def _key(self, req: MSFRequest) -> str:
        return plan_cache_key(req.family, req.n, self.p,
                              self._cap_rung(req), self.algorithm)

    # -- admission ---------------------------------------------------------

    def submit(self, req: MSFRequest) -> None:
        """Admit one request, or reject it with a typed error.

        Raises ``AdmissionError`` (a ``ValueError``) on malformed input;
        the request is additionally marked ``served_via="rejected"``
        with ``error`` set so drivers that collect requests rather than
        catch exceptions still see the outcome.
        """
        try:
            validate_graph(req.u, req.v, req.w, req.n,
                           max_edges=self.max_edges, rid=req.rid)
        except AdmissionError as e:
            req.error = str(e)
            req.served_via = "rejected"
            req.done = True
            self.stats.rejected += 1
            raise
        req._t_submit = time.monotonic()
        self.queue.append(req)
        self.stats.submitted += 1

    def _reject(self, req: MSFRequest, reason: str,
                deadline: bool = False) -> None:
        req.error = reason
        req.served_via = "rejected"
        req.done = True
        self.stats.rejected += 1
        if deadline:
            self.stats.deadline_missed += 1

    # -- serving -----------------------------------------------------------

    def step(self) -> List[MSFRequest]:
        """Serve one batch: admit same-key ready requests, execute,
        run the retry ladder, fill results.

        Returns the list of requests *completed* by this step — served
        or rejected; a backoff-requeued request completes in a later
        step (empty list if the queue was empty or nothing was ready).
        """
        now = time.monotonic()
        # deadline sweep: expired requests reject instead of serving late
        expired: List[MSFRequest] = []
        alive: Deque[MSFRequest] = collections.deque()
        while self.queue:
            r = self.queue.popleft()
            if r.deadline is not None and now - r._t_submit > r.deadline:
                self._reject(
                    r, f"deadline {r.deadline}s exceeded "
                    f"({now - r._t_submit:.3f}s queued)", deadline=True)
                expired.append(r)
            else:
                alive.append(r)
        self.queue = alive
        head = next((r for r in self.queue if r._not_before <= now), None)
        if head is None:
            if self.queue:  # everything is backoff-deferred: wait it out
                wait = min(r._not_before for r in self.queue) - now
                if wait > 0:
                    time.sleep(min(wait, 0.1))
            return expired
        key = self._key(head)
        batch: List[MSFRequest] = []
        rest: Deque[MSFRequest] = collections.deque()
        while self.queue:
            r = self.queue.popleft()
            if (len(batch) < self.batch_slots and r._not_before <= now
                    and self._key(r) == key):
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest

        cap = self._cap_rung(batch[0])
        n = batch[0].n
        graphs = []
        kept: List[MSFRequest] = []
        for r in batch:
            try:
                graphs.append(build_dist_graph(r.u, r.v, r.w, n, self.p,
                                               cap=cap)[0])
                kept.append(r)
            except CapacityError as e:
                # build-time capacity shortfalls map to typed rejection
                # (cannot happen off the rung, which covers 2m/p by
                # construction — this guards direct/hostile cap paths)
                self._reject(r, f"capacity: {e}")
                expired.append(r)
        batch = kept
        if not batch:
            return expired

        entry = self.cache.get(key)
        if entry is not None:
            self.cache.move_to_end(key)
            self.stats.hits += 1
        else:
            self.stats.misses += 1
            try:
                entry = self._measure(key, graphs[0], n, cap)
            except (RuntimeError, CapacityError) as e:
                # a measurement pass that cannot complete (e.g. faulted
                # exchanges) rejects the batch instead of crashing run()
                for r in batch:
                    self._reject(r, f"plan measurement failed: {e}")
                    expired.append(r)
                return expired

        results, flagged = execute_plan_batched(
            graphs, n, self.mesh, entry.plan, axis_names=self.axes,
            replan="defer", verify=self.verify)
        entry.served += len(batch)
        entry.replans += len(flagged)

        # retry ladder: every flagged request either serves via one
        # strict measured replan, requeues with backoff (verify-failed
        # replan, budget left), or rejects — bounded per request by
        # ``max_retries_per_request``, so run() can never loop
        replanned: List[int] = []
        requeued: List[MSFRequest] = []
        still_failing = False
        for i in flagged:
            req = batch[i]
            req.retries += 1
            self.stats.retried += 1
            if req.retries > self.max_retries_per_request:
                still_failing = True
                self._reject(
                    req, f"retry budget exhausted ({req.retries - 1} "
                    f"of {self.max_retries_per_request} retries used)")
                continue
            # deadline re-check per rung (ISSUE 9 bugfix): the entry
            # sweep ran before the batched dispatch, so a slow batch or
            # a backoff sleep could land this *dispatch* past the
            # request's deadline — reject here, never serve late
            now_r = time.monotonic()
            if (req.deadline is not None
                    and now_r - req._t_submit > req.deadline):
                self._reject(
                    req, f"deadline {req.deadline}s exceeded before "
                    f"retry dispatch ({now_r - req._t_submit:.3f}s "
                    "since submit)", deadline=True)
                continue
            # deadline-aware cadence skip: past half the budget the
            # barrier overhead hurts more than a potential resume saves
            ck_every = self.ckpt_every
            if (ck_every and req.deadline is not None
                    and now_r - req._t_submit > 0.5 * req.deadline):
                ck_every = None
            cks: List[MSFCheckpoint] = []
            res = None
            try:
                if req._ckpt is not None:
                    self.stats.resumed += 1
                    self.stats.rounds_saved += req._ckpt.round_index
                res = _replan_with_plan(graphs[i], n, self.mesh,
                                        self.axes, entry.plan,
                                        ckpt_every=ck_every,
                                        ckpt_out=cks if ck_every
                                        else None,
                                        resume_from=req._ckpt)
                if int(res[4]) != 0:
                    req.error = f"replan overflowed ({int(res[4])})"
                    res = None
                elif self.verify:
                    verify_forest(graphs[i], n, self.mesh, res[0],
                                  res[3], axis_names=self.axes,
                                  expected_weight=float(res[1]),
                                  expected_count=int(res[2]))
            except VerifyFailure as e:
                self.stats.verify_failures += 1
                req.error = str(e)
                res = None
            except CheckpointError as e:
                # a checkpoint that fails restore validation is dropped
                # — the next rung re-executes from round 0 rather than
                # resuming a corrupted snapshot
                req._ckpt = None
                req.error = f"checkpoint restore failed: {e}"
                res = None
            except (RuntimeError, CapacityError) as e:
                req.error = f"replan failed: {e}"
                res = None
            if cks:
                # keep the furthest certified checkpoint: a later rung
                # (after requeue/backoff) resumes there instead of
                # re-executing the whole solve
                req._ckpt = cks[-1]
            if res is not None:
                results[i] = res
                replanned.append(i)
                continue
            still_failing = True
            if req.retries >= self.max_retries_per_request:
                self._reject(
                    req, f"failed after {req.retries} retries: "
                    + (req.error or "unrecoverable"))
            else:
                req._not_before = time.monotonic() \
                    + self.backoff_base * (2 ** (req.retries - 1))
                self.queue.append(req)
                requeued.append(req)

        # circuit breaker: consecutive failing steps trip the entry —
        # drop it from the LRU (next miss re-measures fresh) and
        # quarantine the poisoning requests so they cannot storm run()
        if still_failing:
            entry.fails += 1
            if entry.fails >= self.breaker_threshold:
                if key in self.cache and self.cache[key] is entry:
                    self.cache.pop(key)
                self.stats.breaker_trips += 1
                for req in requeued:
                    try:
                        self.queue.remove(req)
                    except ValueError:
                        pass
                    self._reject(req, "circuit breaker tripped: entry "
                                 f"{key!r} quarantined after "
                                 f"{entry.fails} consecutive failing "
                                 "steps")
        else:
            entry.fails = 0

        # drift: a key whose traffic keeps outgrowing its plan gets one
        # fresh measurement (off a graph that actually misfit) and new
        # pad() headroom, instead of replanning forever
        if (flagged and self.cache.get(key) is entry
                and entry.served >= self.min_samples
                and entry.replans / entry.served > self.replan_threshold):
            self._measure(key, graphs[flagged[-1]], n, cap)
            self.stats.refreshes += 1

        now = time.monotonic()
        completed: List[MSFRequest] = list(expired)
        for i, (req, res) in enumerate(zip(batch, results)):
            if res is None:
                if req.done:        # rejected by the ladder/breaker
                    completed.append(req)
                continue            # requeued: completes in a later step
            mask = np.asarray(res[0])
            eid = np.asarray(graphs[i].eid)
            req.edges = np.unique(eid[mask])
            req.weight = float(res[1])
            req.count = int(res[2])
            req.served_via = "replanned" if i in replanned else "batched"
            req.latency = now - req._t_submit
            req.done = True
            self.stats.served += 1
            completed.append(req)
        self.stats.replans += len(replanned)
        self.stats.batches += 1
        return completed

    def run(self, max_steps: int = 100_000) -> None:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1

    # -- plan lifecycle ----------------------------------------------------

    def _measure(self, key: str, graph, n: int, cap: int) -> _CacheEntry:
        """Measure a plan off ``graph``, pad it, (re)install the entry."""
        plan = plan_sharded_msf(graph, n, self.mesh,
                                algorithm=self.algorithm,
                                axis_names=self.axes)
        assert plan.cache_key(key.split("|", 1)[0]) == key, \
            (plan.cache_key(key.split("|", 1)[0]), key)
        entry = _CacheEntry(plan=plan.pad(self.pad_margin), cap=cap)
        self.cache[key] = entry
        self.cache.move_to_end(key)
        while len(self.cache) > self.cache_size:
            self.cache.popitem(last=False)
            self.stats.evictions += 1
        return entry
