"""Batched serving engine: continuous-batching decode over a static slot
pool (the serving-side substrate; the paper's kind is a batch algorithm,
so this is an example application layer, exercised by examples/serve_lm).

Slots hold independent requests; finished slots are refilled without
recompiling (static shapes: [B] slots, length-T KV buffers).  Greedy or
temperature sampling.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import forward_decode, init_caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # prompt tokens not yet teacher-forced through the decode path;
    # owned by the engine from admission (_fill_slots) to end of prefill
    _pending: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 128, temperature: float = 0.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.T = max_len
        self.temperature = temperature
        self.caches = init_caches(cfg, self.B, self.T)
        self.pos = np.zeros(self.B, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * self.B
        self.queue: Deque[Request] = collections.deque()
        self.key = jax.random.key(seed)
        self._step = jax.jit(
            lambda p, c, t, q: forward_decode(cfg, p, c, t, q))

    def submit(self, req: Request) -> None:
        if not req.prompt:
            # step() seeds decode from prompt[-1]; an empty prompt has
            # no seed token and would IndexError mid-batch — reject at
            # admission so one bad request cannot stall a full slot pool
            raise ValueError(f"request {req.rid}: empty prompt "
                             "(decode needs >= 1 seed token)")
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for b in range(self.B):
            if self.slot_req[b] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[b] = req
                self.pos[b] = 0
                # prompt is consumed token-by-token (teacher-forced
                # prefill through the decode path keeps one compiled fn)
                req._pending = list(req.prompt)

    def step(self) -> None:
        """One global decode step across all active slots."""
        self._fill_slots()
        tokens = np.zeros(self.B, np.int32)
        for b, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req._pending:
                tokens[b] = req._pending[0]
            elif req.out:
                tokens[b] = req.out[-1]
            else:
                tokens[b] = req.prompt[-1]
        logits, self.caches = self._step(self.params, self.caches,
                                         jnp.asarray(tokens),
                                         jnp.asarray(self.pos))
        logits = np.asarray(logits, np.float32)
        for b, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.pos[b] += 1
            if req._pending:
                req._pending.pop(0)
                if req._pending:
                    continue  # still prefilling
            if self.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits[b]) / self.temperature))
            else:
                nxt = int(logits[b].argmax())
            req.out.append(nxt)
            if len(req.out) >= req.max_new or self.pos[b] >= self.T - 1:
                req.done = True
                self.slot_req[b] = None

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or any(s is not None for s in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
