"""Version bridge for the shard_map / varying-manual-axes (vma) API split.

The repo targets two JAX generations at once (EXPERIMENTS.md §Compat):

* **JAX ≥ 0.6** — ``jax.shard_map`` is public, values inside shard_map
  carry *varying manual axes* (vma) metadata inspectable via
  ``jax.typeof(x).vma``, and ``lax.pvary`` promotes a replicated value to
  a varying one (required before mixing it with varying operands when
  ``check_vma=True``).
* **JAX 0.4.x** — shard_map lives in ``jax.experimental.shard_map``,
  there is no vma system (``lax.pvary`` / ``jax.typeof`` do not exist),
  and the equivalent of disabling vma checking is ``check_rep=False``.

Every module in this repo imports the manual-collective surface from
here instead of from ``jax`` directly:

    from repro.compat import shard_map, pvary, vma_of, vary, psum_scatter

On 0.4.x ``pvary`` is the identity and ``vma_of`` returns an empty
frozenset, so code written for the vma world runs unchanged (the checks
it satisfies simply do not exist).  ``shard_map`` maps the ``check``
knob onto ``check_vma`` (new) or ``check_rep`` (old); by default the
old path disables replication checking, which is the semantic match for
vma-annotated programs.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax import lax

__all__ = ["HAS_VMA", "HAS_NATIVE_SHARD_MAP", "shard_map", "pvary",
           "vma_of", "vary", "psum_scatter", "axis_size"]


def _jax_has(name: str) -> bool:
    # jax >= 0.4.30 raises AttributeError through a deprecation shim for
    # names that only exist in newer versions, so hasattr() is accurate.
    return hasattr(jax, name)


HAS_NATIVE_SHARD_MAP = _jax_has("shard_map")
HAS_VMA = hasattr(lax, "pvary") and _jax_has("typeof")

if HAS_NATIVE_SHARD_MAP:
    _shard_map_impl = jax.shard_map
else:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def shard_map(f, *, mesh=None, in_specs, out_specs,
              check: Optional[bool] = None, **kwargs):
    """Uniform shard_map entry point.

    ``check`` maps to ``check_vma`` (JAX ≥ 0.6) or ``check_rep``
    (JAX 0.4.x).  Default: vma checking stays on where it exists,
    replication checking is off where vma does not exist — the two
    configurations under which the same shard-level program is valid on
    both generations.
    """
    if HAS_NATIVE_SHARD_MAP:
        if check is not None:
            kwargs.setdefault("check_vma", check)
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kwargs)
    kwargs.pop("check_vma", None)
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs,
                           check_rep=False if check is None else check,
                           **kwargs)


if HAS_VMA:
    def pvary(x, axis_names: Sequence[str]):
        """Promote ``x`` to vary over ``axis_names`` (no-op on 0.4.x)."""
        return lax.pvary(x, tuple(axis_names))

    def vma_of(x) -> frozenset:
        """The set of manual axes ``x`` varies over (empty on 0.4.x)."""
        return frozenset(jax.typeof(x).vma)
else:
    def pvary(x, axis_names: Sequence[str]):
        """Promote ``x`` to vary over ``axis_names`` (no-op on 0.4.x)."""
        del axis_names
        return x

    def vma_of(x) -> frozenset:
        """The set of manual axes ``x`` varies over (empty on 0.4.x)."""
        del x
        return frozenset()


def vary(x, axis_names: Sequence[str]):
    """pvary only over the axes ``x`` is not already varying over."""
    missing = tuple(a for a in axis_names if a not in vma_of(x))
    return pvary(x, missing) if missing else x


# lax.psum_scatter exists on both generations; re-exported so callers
# have a single import site for the manual-collective surface.
psum_scatter = lax.psum_scatter

if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:  # 0.4.x: psum of a concrete 1 is folded to the static axis size
    def axis_size(axis_name) -> int:
        return int(lax.psum(1, axis_name))
