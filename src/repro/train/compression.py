"""Gradient compression with error feedback (cross-pod DP all-reduce aid).

The pod axis is the slow link (DCN / inter-pod ICI).  int8 block-quantised
gradients cut the cross-pod all-reduce volume 4x (bf16) / 8x (fp32); the
quantisation error is carried in a residual buffer and re-added next step
(error feedback), which keeps SGD/Adam convergence intact in practice.

Used by the train loop when ``compress_pod_grads=True``: gradients are
reduced in full precision inside the pod (fast ICI) and int8-compressed
only across the pod axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256
                  ) -> Tuple[jax.Array, jax.Array]:
    """Block-wise symmetric int8 quantisation. Returns (q, scales)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype
                    ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(grads: Any, residual: Any
                           ) -> Tuple[Any, Any]:
    """Quantise (grad + residual); return (dequantised grads, new residual).

    The returned grads are what the slow-axis all-reduce ships; the
    residual accumulates this step's quantisation error.
    """
    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(one, grads, residual)
    newg = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    newr = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return newg, newr


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
