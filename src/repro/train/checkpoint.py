"""Fault-tolerant checkpointing: atomic, manifest-verified, reshardable.

Design for 1000+ nodes (DESIGN.md Section 5):
  * step-tagged directories, written to a temp name and atomically
    renamed — a crash mid-write never corrupts the latest checkpoint;
  * a manifest (leaf paths, shapes, dtypes, per-leaf checksums) detects
    partial/corrupt checkpoints, which restore() skips automatically;
  * storage layout is mesh-independent (plain host numpy per leaf), so a
    restart may use a different device count / mesh shape — the restore
    path re-shards onto whatever shardings the new run provides
    (elastic restart after node loss);
  * keep-last-k garbage collection.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree: Any):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest = {}
    for key, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        logical = str(arr.dtype)
        store = arr
        if arr.dtype.kind == "V" or logical == "bfloat16":
            # numpy cannot round-trip ml_dtypes (bfloat16 etc.) natively;
            # store the raw bits and record the logical dtype
            store = arr.view(np.uint16 if arr.dtype.itemsize == 2
                             else np.uint8)
            logical = "bfloat16" if arr.dtype.itemsize == 2 else logical
        fn = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
        np.save(os.path.join(tmp, fn), store)
        manifest[key] = {
            "file": fn, "shape": list(arr.shape), "dtype": logical,
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _is_valid(path: str) -> bool:
    mf = os.path.join(path, "manifest.json")
    if not os.path.exists(mf):
        return False
    try:
        manifest = json.load(open(mf))
    except Exception:
        return False
    for key, meta in manifest["leaves"].items():
        f = os.path.join(path, meta["file"])
        if not os.path.exists(f):
            return False
    return True


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in reversed(steps):  # newest valid one wins
        if _is_valid(os.path.join(ckpt_dir, d)):
            return int(d.split("_")[1])
    return None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None, verify: bool = False) -> Any:
    """Load into the structure of ``like``; optionally device_put with
    ``shardings`` (resharding onto a different mesh is free here)."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    leaves = manifest["leaves"]
    keys = [k for k, _ in _leaf_paths(like)]
    arrays = []
    for key in keys:
        meta = leaves[key]
        arr = np.load(os.path.join(path, meta["file"]))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if verify:
            assert hashlib.sha1(arr.tobytes()).hexdigest() == meta["sha1"], \
                f"checksum mismatch for {key}"
        arrays.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.device_put, tree)
    return tree
