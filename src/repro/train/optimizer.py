"""AdamW with mesh-aware (ZeRO-1 style) optimizer-state sharding.

Moments are stored fp32 and sharded like their parameters, with the first
still-unsharded dimension additionally sharded over the DP axes — the
optimizer-state memory then scales 1/(TP * DP) like ZeRO-1, at the cost of
one all-gather per step that XLA overlaps with the optimizer math.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params: Any) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(f32, params),
                      jax.tree.map(f32, params))


def apply_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: AdamWState) -> Tuple[Any, AdamWState]:
    # global-norm clip (fp32)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu)


def zero1_specs(param_specs: Any, params: Any, mesh: Mesh) -> Any:
    """Moment specs: parameter spec + DP sharding on the first free dim."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def one(spec: P, leaf) -> P:
        if not dp or leaf.ndim == 0:
            return spec
        entries = list(tuple(spec) + (None,) * (leaf.ndim - len(spec)))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                used.add(a)
        if any(a in used for a in dp):
            return spec  # a DP axis already shards this leaf
        for i, e in enumerate(entries):
            if e is None and leaf.shape[i] % dp_size == 0 \
                    and leaf.shape[i] >= dp_size:
                entries[i] = dp if len(dp) > 1 else dp[0]
                break
        return P(*entries)

    return jax.tree.map(one, param_specs, params)


def state_shardings(param_specs: Any, params: Any, mesh: Mesh
                    ) -> AdamWState:
    mspecs = zero1_specs(param_specs, params, mesh)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), mspecs)
    return AdamWState(NamedSharding(mesh, P()), sh, sh)
