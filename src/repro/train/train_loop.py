"""Training loop: jitted train_step (grad-accum, remat'd model, ZeRO
optimizer), auto-resume, fault-tolerant checkpointing.

``make_train_step`` builds the step that the dry-run lowers on the
production mesh; ``train`` is the host loop used by the examples and the
end-to-end driver (checkpoint/restart is exercised in tests by killing
and resuming the loop).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import sharding as shd
from repro.models.model import MeshContext, forward_train, init_params
from repro.train import checkpoint as ckpt_lib
from repro.train import compression
from repro.train.optimizer import (AdamWConfig, AdamWState, apply_update,
                                   init_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1             # grad accumulation
    compress_pod_grads: bool = False  # int8 + error feedback on pod axis
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 10


def make_loss_fn(cfg: ModelConfig, mesh_ctx: Optional[MeshContext] = None):
    def loss_fn(params, batch):
        return forward_train(cfg, params, batch, mesh_ctx)
    return loss_fn


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    mesh_ctx: Optional[MeshContext] = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg, mesh_ctx)

    def split_micro(batch):
        def sp(x):
            B = x.shape[0]
            mb = tc.microbatches
            return x.reshape((mb, B // mb) + x.shape[1:])
        return jax.tree.map(sp, batch)

    def train_step(params, opt_state: AdamWState, batch):
        if tc.microbatches > 1:
            micro = split_micro(batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc, (zero, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            loss = loss / tc.microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state = apply_update(tc.opt, params, grads,
                                             opt_state)
        metrics = {"loss": loss,
                   "step": new_state.step}
        return new_params, new_state, metrics

    return train_step


def setup_sharded(cfg: ModelConfig, mesh: Mesh, tc: TrainConfig,
                  key: Optional[jax.Array] = None):
    """Shard-initialised params + optimizer state + jitted step on mesh."""
    from repro.train.optimizer import state_shardings
    key = jax.random.key(0) if key is None else key
    pshape = jax.eval_shape(partial(init_params, cfg), key)
    pshard = shd.param_shardings(pshape, mesh)
    init_jit = jax.jit(partial(init_params, cfg), out_shardings=pshard)
    params = init_jit(key)
    specs = shd.valid_param_specs(pshape, mesh)
    oshard = state_shardings(specs, pshape, mesh)
    opt_state = jax.jit(init_state, out_shardings=oshard)(params)
    dp = shd.data_axes(mesh)
    mesh_ctx = MeshContext(mesh, dp, ("model",))
    step = make_train_step(cfg, tc, mesh_ctx)
    bspec = NamedSharding(mesh, P(dp))
    step_jit = jax.jit(step,
                       in_shardings=(pshard, oshard, bspec),
                       out_shardings=(pshard, oshard, None),
                       donate_argnums=(0, 1))
    return params, opt_state, step_jit, mesh_ctx


def train(cfg: ModelConfig, tc: TrainConfig, data_iter, num_steps: int,
          mesh: Optional[Mesh] = None, log: Callable = print
          ) -> Dict[str, Any]:
    """Host loop with auto-resume from the newest valid checkpoint."""
    if mesh is not None:
        params, opt_state, step_fn, _ = setup_sharded(cfg, mesh, tc)
    else:
        params = init_params(cfg, jax.random.key(0))
        opt_state = init_state(params)
        step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))

    start = 0
    if tc.ckpt_dir:
        latest = ckpt_lib.latest_step(tc.ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(tc.ckpt_dir, latest,
                                     {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
            log(f"[train] resumed from step {latest}")

    losses = []
    t0 = time.time()
    for i in range(start, num_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % tc.log_every == 0 or i == num_steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            log(f"[train] step {i + 1} loss {loss:.4f} "
                f"({(time.time() - t0) / max(i + 1 - start, 1):.3f}s/step)")
        if tc.ckpt_dir and ((i + 1) % tc.ckpt_every == 0
                            or i == num_steps - 1):
            ckpt_lib.save(tc.ckpt_dir, i + 1,
                          {"params": params, "opt": opt_state})
    return {"params": params, "opt_state": opt_state, "losses": losses}
