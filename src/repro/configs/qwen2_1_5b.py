"""qwen2-1.5b — dense GQA with QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, d_ff=8960, vocab_size=151936,
    attn_bias=True, rope_theta=1_000_000.0)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    attn_bias=True)

register("qwen2-1.5b", CONFIG, SMOKE, "arXiv:2407.10671 Table 1 / hf")
