"""llama4-maverick-400b-a17b — GQA kv=8, MoE 128e top-1 + shared expert
[hf:meta-llama/Llama-4 family; unverified].  Early-fusion multimodality is
out of backbone scope (spec: frontend stubs are for [vlm]/[audio] only)."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
    d_model=5120, num_heads=40, num_kv_heads=8, d_ff=16384,
    vocab_size=202048, moe_d_ff=8192, num_experts=128,
    num_experts_per_tok=1, num_shared_experts=1, first_dense_layers=0,
    moe_every=2, rope_theta=500_000.0)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=512,
    moe_d_ff=64, num_experts=4, num_experts_per_tok=1,
    num_shared_experts=1, moe_every=2)

register("llama4-maverick-400b-a17b", CONFIG, SMOKE,
         "hf:meta-llama/Llama-4-Scout/Maverick cards")
