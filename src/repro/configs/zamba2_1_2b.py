"""zamba2-1.2b — Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, shared_attn_every=6,
    subquadratic=True)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=16, shared_attn_every=2,
    subquadratic=True)

register("zamba2-1.2b", CONFIG, SMOKE, "arXiv:2411.15242 / hf:Zyphra")
