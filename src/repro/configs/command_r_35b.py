"""command-r-35b — dense GQA kv=8, no bias, parallel attn+FFN block
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="command-r-35b", family="dense", num_layers=40, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=22528, vocab_size=256000,
    parallel_block=True, rope_theta=8_000_000.0)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=512,
    parallel_block=True)

register("command-r-35b", CONFIG, SMOKE, "hf:CohereForAI/c4ai-command-r-v01")
