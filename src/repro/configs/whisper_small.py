"""whisper-small — enc-dec, conv frontend STUB (precomputed frame
embeddings) [arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="whisper-small", family="audio", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
    encoder_layers=12, cross_attention=True, frontend="audio",
    frontend_len=1500)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    encoder_layers=2, cross_attention=True, frontend="audio",
    frontend_len=16)

register("whisper-small", CONFIG, SMOKE, "arXiv:2212.04356 Table 1")
