"""deepseek-7b — llama-architecture dense, GQA kv=32 (MHA) [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense", num_layers=30, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=102400)

SMOKE = ModelConfig(
    name="deepseek-7b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=160, vocab_size=512)

register("deepseek-7b", CONFIG, SMOKE, "arXiv:2401.02954 / hf")
