"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=24, num_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, subquadratic=True)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=2, num_kv_heads=0, d_ff=0, vocab_size=512,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=16, subquadratic=True)

register("mamba2-130m", CONFIG, SMOKE, "arXiv:2405.21060")
