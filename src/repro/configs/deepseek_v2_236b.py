"""deepseek-v2-236b — MLA (kv_lora=512) + MoE 160 routed top-6, 2 shared
[arXiv:2405.04434; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
    num_heads=128, num_kv_heads=128, d_ff=12288, vocab_size=102400,
    head_dim=128, kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
    num_experts=160, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1536, first_dense_layers=1)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512, head_dim=16,
    kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
    num_experts=8, num_experts_per_tok=2, num_shared_experts=1,
    moe_d_ff=32, first_dense_layers=1)

register("deepseek-v2-236b", CONFIG, SMOKE, "arXiv:2405.04434 §2")
