"""internvl2-76b — InternViT frontend (STUB) + llama3-70B-class backbone
[arXiv:2404.16821; unverified].  Patch embeddings arrive precomputed."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=28672, vocab_size=128256,
    rope_theta=500_000.0, frontend="patch", frontend_len=256)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    frontend="patch", frontend_len=8)

register("internvl2-76b", CONFIG, SMOKE, "arXiv:2404.16821")
