"""Model configuration system + architecture registry.

One config file per assigned architecture lives next to this module; each
exposes ``CONFIG`` (the exact published dims) and registers itself.  Every
config provides ``smoke()`` — a reduced same-family variant for CPU smoke
tests (the full dims are exercised only through the AOT dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | ssm | moe | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # attention flavour
    attn_bias: bool = False           # qwen2: QKV bias
    parallel_block: bool = False      # command-r: parallel attn+FFN
    rope_theta: float = 10_000.0
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0             # 0 -> standard GQA
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0       # leading dense layers in MoE stacks
    moe_every: int = 1                # llama4: MoE every 2nd layer
    moe_impl: str = "gshard"          # gshard | dispatch (paper routed a2a)
    moe_dispatch: str = "direct"      # direct | grid (Section VI-A schedule)
    capacity_factor: float = 1.25
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    shared_attn_every: int = 0        # zamba2: shared attn block period
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub
    frontend: str = "none"            # none | patch | audio
    frontend_len: int = 0             # patches / frames occupying the prefix
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    scan_unroll: bool = False         # probes: unroll layer scans so XLA
    # cost analysis sees every layer (scan bodies are counted once)
    attn_impl: str = "naive"          # naive | blockwise (flash-style
    # online softmax over KV chunks; §Perf optimization)
    attn_block: int = 512             # KV chunk for blockwise attention
    remat_policy: str = "none"        # none | dots — jax.checkpoint policy
    cache_shard: str = "feature"      # feature | sequence — decode cache
    # partitioning over the model axis (§Perf: flash-decoding style
    # length-split when KV heads don't divide the TP degree)
    shard_logits: bool = False        # keep decode logits vocab-sharded
    kv_cache_dtype: str = "model"     # model | int8 (quantised KV cache)
    mla_absorb: bool = False          # MLA decode: absorb wkv_b into the
    # query/output (attention in latent space — no per-step re-expansion
    # of the cached latents; §Perf deepseek-v2 decode)
    # which attention kind: "full" archs skip long_500k (DESIGN.md)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count (reported in DESIGN/EXPERIMENTS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.hd
        total = V * D  # embedding (tied head adds V*D if untied; we untie)
        total += V * D
        att = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.kv_lora_rank:
            q_in = self.q_lora_rank or D
            att = (D * self.q_lora_rank if self.q_lora_rank else 0)
            att += q_in * H * (hd + self.rope_head_dim)
            att += D * (self.kv_lora_rank + self.rope_head_dim)
            att += self.kv_lora_rank * H * (hd + hd)
            att += H * hd * D
        ffn_dense = 3 * D * F
        if self.family in ("ssm", "hybrid"):
            inner = self.num_heads * self.ssm_head_dim
            ssm = D * (2 * inner + 2 * self.ssm_state + self.num_heads)
            ssm += inner * D + self.conv_width * (inner + 2 * self.ssm_state)
            total += L * ssm
            if self.family == "hybrid":
                total += att + ffn_dense  # one shared attention block
            return total
        per_layer = att + ffn_dense
        if self.is_moe:
            moe = 3 * D * self.moe_d_ff * (self.num_experts
                                           + self.num_shared_experts)
            moe += D * self.num_experts  # router
            n_rest = L - self.first_dense_layers
            n_moe = n_rest // self.moe_every
            n_dense = self.first_dense_layers + (n_rest - n_moe)
            per_layer = att
            total += n_dense * ffn_dense + n_moe * moe
        total += L * per_layer
        if self.encoder_layers:
            total += self.encoder_layers * (att + ffn_dense)
        return total

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        k = self.num_experts_per_tok + self.num_shared_experts
        D = self.d_model
        act_moe = 3 * D * self.moe_d_ff * k
        full_moe = 3 * D * self.moe_d_ff * (self.num_experts
                                            + self.num_shared_experts)
        n_moe = (self.num_layers - self.first_dense_layers) // self.moe_every
        return self.param_count() - n_moe * (full_moe - act_moe)


_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ModelConfig
    smoke: ModelConfig
    source: str  # provenance note


def register(name: str, config: ModelConfig, smoke: ModelConfig,
             source: str) -> None:
    _REGISTRY[name] = ArchEntry(config, smoke, source)


ARCH_IDS = [
    "qwen2-1.5b", "deepseek-7b", "command-r-35b", "llama3.2-3b",
    "mamba2-130m", "internvl2-76b", "deepseek-v2-236b",
    "llama4-maverick-400b-a17b", "zamba2-1.2b", "whisper-small",
]

_MODULES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-7b": "deepseek_7b",
    "command-r-35b": "command_r_35b",
    "llama3.2-3b": "llama3_2_3b",
    "mamba2-130m": "mamba2_130m",
    "internvl2-76b": "internvl2_76b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-small": "whisper_small",
}


def get_arch(name: str) -> ArchEntry:
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchEntry]:
    for name in ARCH_IDS:
        get_arch(name)
    return dict(_REGISTRY)
