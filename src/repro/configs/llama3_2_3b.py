"""llama3.2-3b — small llama3: GQA kv=8 [hf:meta-llama/Llama-3.2; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense", num_layers=28, d_model=3072,
    num_heads=24, num_kv_heads=8, d_ff=8192, vocab_size=128256,
    rope_theta=500_000.0)

SMOKE = ModelConfig(
    name="llama3.2-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512)

register("llama3.2-3b", CONFIG, SMOKE, "hf:meta-llama/Llama-3.2-1B family")
