"""Fault-injection harness + self-verifying serving (ISSUE 7).

In-process: the deterministic fault selector, FaultPlan validation and
the inject() lifecycle, loud ``CapacityError`` on every fixed-capacity
builder, and ``validate_graph`` admission control — including a
hypothesis-driven adversarial generator (NaN/inf weights, out-of-range
vertex ids, self-loops, duplicate edges) asserting the gateway's
admission verdict matches the ground-truth predicate.

Subprocess (8 virtual devices): fault classes through the planned
engine (clip raises under strict replay; corruption is attributed in
``CommStats.injected``; the on-device verifier rejects doctored
forests and passes fault-free runs), and the hardened gateway — typed
admission rejections, per-request deadlines, the ``max_retries=0``
regression (star-measured plan + path traffic rejects instead of
looping), the replan circuit breaker, and faulty traffic through a
``verify=True`` gateway never serving a silently wrong forest."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.comm import faults
from repro.core.graph import CapacityError, from_numpy, partition_edges
from repro.serve.msf_gateway import AdmissionError, validate_graph
from tests.helpers.hypothesis_compat import (HAVE_HYPOTHESIS, given,
                                             settings, st)
from tests.helpers.subproc import run_multidevice


# -- the deterministic fault selector (in-process, 1 device) ---------------

def _run_select(seed, site, fraction, m=64):
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    fn = compat.shard_map(
        lambda: faults._select(seed, site, 3, (m,), fraction, ("x",)),
        mesh=mesh, in_specs=(), out_specs=P("x"))
    return np.asarray(jax.jit(fn)())


def test_select_deterministic_and_seeded():
    a = _run_select(0, "minedges", 0.25)
    assert np.array_equal(a, _run_select(0, "minedges", 0.25))
    # seed, site and fraction all move the selection
    assert not np.array_equal(a, _run_select(1, "minedges", 0.25))
    assert not np.array_equal(a, _run_select(0, "contract", 0.25))
    assert 0 < int(a.sum()) < 64          # a fraction, not all-or-nothing
    assert _run_select(0, "minedges", 1.0).all()
    assert not _run_select(0, "minedges", 0.0).any()


def test_flip_bit_is_an_involution():
    import jax.numpy as jnp
    x = jnp.asarray([1.5, -3.25, 1e-6, 7e8], jnp.float32)
    sel = jnp.asarray([True, True, False, True])
    y = faults._flip_bit(x, sel, 12)
    assert not np.array_equal(np.asarray(x), np.asarray(y))
    assert float(y[2]) == float(x[2])              # unselected untouched
    assert np.array_equal(np.asarray(faults._flip_bit(y, sel, 12)),
                          np.asarray(x))
    # non-float32 payloads pass through unchanged
    i = jnp.arange(4, dtype=jnp.int32)
    assert np.array_equal(np.asarray(faults._flip_bit(i, sel, 12)),
                          np.arange(4))


def test_fault_plan_validation_and_lifecycle():
    with pytest.raises(ValueError, match="kind"):
        faults.FaultPlan(specs=(faults.FaultSpec(kind="nope"),)).validate()
    with pytest.raises(ValueError, match="fraction"):
        faults.FaultPlan(specs=(
            faults.FaultSpec(kind="drop", fraction=1.5),)).validate()
    with pytest.raises(ValueError, match="cap_frac"):
        faults.FaultPlan(specs=(
            faults.FaultSpec(kind="clip", cap_frac=0.0),)).validate()
    ok = faults.FaultPlan(seed=7, specs=(
        faults.FaultSpec(kind="drop", site="push"),))
    assert faults.active() is None
    with faults.inject(ok):
        assert faults.active() is ok
        with pytest.raises(RuntimeError, match="already active"):
            with faults.inject(ok):
                pass
    assert faults.active() is None


def test_abort_spec_validation_and_round_counter():
    # unknown sites and malformed round selectors are rejected loudly —
    # a typo'd abort would otherwise inject nothing and "pass"
    with pytest.raises(ValueError, match="site"):
        faults.FaultPlan(specs=(
            faults.FaultSpec(kind="abort", site="minedgez"),)).validate()
    with pytest.raises(ValueError, match="rounds"):
        faults.FaultPlan(specs=(
            faults.FaultSpec(kind="abort", rounds=(0,)),)).validate()
    with pytest.raises(ValueError, match="rounds"):
        faults.FaultPlan(specs=(
            faults.FaultSpec(kind="abort", rounds=(1.5,)),)).validate()
    # the round-selected abort fires exactly on its rounds, at its site
    plan = faults.FaultPlan(specs=(
        faults.FaultSpec(kind="abort", site="minedges", rounds=(2,),
                         shard=3),))
    with faults.inject(plan):
        faults.set_round(1)
        faults._maybe_abort(faults.specs_for("minedges"), "minedges")
        faults.set_round(2)
        assert faults.current_round() == 2
        faults._maybe_abort(faults.specs_for("contract"), "contract")
        with pytest.raises(faults.ShardAbort) as ei:
            faults._maybe_abort(faults.specs_for("minedges"), "minedges")
        assert "minedges" in str(ei.value) and "round 2" in str(ei.value)
        assert "shard 3" in str(ei.value)
        assert isinstance(ei.value, RuntimeError)    # ladder-compatible
    # rounds=() is a blanket abort: any published round dies
    blanket = faults.FaultPlan(specs=(
        faults.FaultSpec(kind="abort", site="minedges"),))
    with faults.inject(blanket):
        faults.set_round(7)
        with pytest.raises(faults.ShardAbort):
            faults._maybe_abort(faults.specs_for("minedges"), "minedges")
    # inactive -> specs_for is empty -> the hook is dead code
    faults._maybe_abort(faults.specs_for("minedges"), "minedges")


def test_specs_for_site_matching():
    blanket = faults.FaultSpec(kind="drop")          # site="" wildcard
    aimed = faults.FaultSpec(kind="stall", site="minedges")
    plan = faults.FaultPlan(specs=(blanket, aimed))
    with faults.inject(plan):
        assert faults.specs_for("minedges") == (blanket, aimed)
        assert faults.specs_for("contract") == (blanket,)
        # the verifier's own exchange is exempt from blanket plans —
        # a faultable verifier could never classify a chaos outcome
        assert faults.specs_for("verify") == ()
        with_v = faults.FaultSpec(kind="drop", site="verify")
        assert with_v.matches("verify")              # explicit only
    assert faults.specs_for("minedges") == ()        # inactive -> no-op


# -- loud capacity errors (in-process) -------------------------------------

def test_capacity_errors_are_loud():
    u = np.arange(10, dtype=np.int32)
    v = (u + 1) % 12
    w = np.ones(10, np.float32)
    with pytest.raises(CapacityError) as ei:
        from_numpy(u, v, w, 12, pad_to=6)
    assert ei.value.dropped == 4
    assert isinstance(ei.value, ValueError)          # old handlers hold
    with pytest.raises(CapacityError) as ei:
        partition_edges(u, v, w, 12, 4, cap=2)
    assert ei.value.dropped == 2
    from_numpy(u, v, w, 12, pad_to=10)               # exact fit is fine
    partition_edges(u, v, w, 12, 4, cap=3)


# -- admission control (in-process) ----------------------------------------

def test_validate_graph_rejects_hostile_inputs():
    ok_u = np.asarray([0, 1], np.int32)
    ok_v = np.asarray([1, 2], np.int32)
    ok_w = np.asarray([1.0, 2.0], np.float32)
    validate_graph(ok_u, ok_v, ok_w, 3)
    with pytest.raises(AdmissionError, match="n must be"):
        validate_graph(ok_u, ok_v, ok_w, 0)
    with pytest.raises(AdmissionError, match="length"):
        validate_graph(ok_u, ok_v[:1], ok_w, 3)
    with pytest.raises(AdmissionError, match="NaN"):
        validate_graph(ok_u, ok_v, np.asarray([1.0, np.nan], np.float32), 3)
    with pytest.raises(AdmissionError, match="NaN"):
        validate_graph(ok_u, ok_v, np.asarray([np.inf, 1.0], np.float32), 3)
    with pytest.raises(AdmissionError, match="outside"):
        validate_graph(ok_u, np.asarray([1, 3], np.int32), ok_w, 3)
    with pytest.raises(AdmissionError, match="outside"):
        validate_graph(np.asarray([-1, 1], np.int32), ok_v, ok_w, 3)
    with pytest.raises(AdmissionError, match="max_edges"):
        validate_graph(ok_u, ok_v, ok_w, 3, max_edges=1)
    with pytest.raises(AdmissionError, match="integer"):
        validate_graph(ok_u.astype(np.float32), ok_v, ok_w, 3)
    # tolerated shapes: self-loops and duplicate edges are engine-legal
    validate_graph(np.asarray([0, 0], np.int32),
                   np.asarray([0, 1], np.int32), ok_w, 3)
    validate_graph(np.asarray([0, 0], np.int32),
                   np.asarray([1, 1], np.int32), ok_w, 3)
    validate_graph(np.asarray([], np.int64), np.asarray([], np.int64),
                   np.asarray([], np.float32), 1)
    # AdmissionError is a ValueError: pre-hardening catches still work
    with pytest.raises(ValueError):
        validate_graph(ok_u, ok_v, ok_w, 0)


if HAVE_HYPOTHESIS:
    _vids = st.integers(min_value=-2, max_value=9)
    _weights = st.sampled_from(
        [1.0, 2.5, 0.0, -1.0, float("nan"), float("inf"), float("-inf")])
    _edges = st.lists(st.tuples(_vids, _vids, _weights), min_size=0,
                      max_size=12)
else:                                                # pragma: no cover
    _edges = None


@settings(max_examples=200, deadline=None)
@given(edges=_edges, n=st.integers(min_value=1, max_value=8))
def test_validate_graph_matches_ground_truth(edges, n):
    """Admission accepts a graph iff every id is in range and every
    weight finite — independent of self-loops / duplicates — and an
    accepted graph is always solvable by the Kruskal oracle."""
    u = np.asarray([e[0] for e in edges], np.int64)
    v = np.asarray([e[1] for e in edges], np.int64)
    w = np.asarray([e[2] for e in edges], np.float32)
    clean = bool(np.isfinite(w).all()
                 and ((u >= 0) & (u < n) & (v >= 0) & (v < n)).all())
    if clean:
        validate_graph(u, v, w, n)
        from repro.core import oracle
        mask, weight = oracle.kruskal(u, v, w, n)
        assert int(mask.sum()) <= n - 1
        assert np.isfinite(weight)
    else:
        with pytest.raises(AdmissionError):
            validate_graph(u, v, w, n)


# -- fault classes through the planned engine (subprocess) -----------------

FAULTS_ENGINE = """
from jax.sharding import Mesh
from repro.comm import faults
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import execute_plan, plan_sharded_msf
from repro.core.verify import VerifyFailure, verify_forest
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("gnm", 256, avg_degree=8.0, seed=0)
g = build_dist_graph(u, v, w, n, p)[0]
km, kw = oracle.kruskal(u, v, w, n)
plan = plan_sharded_msf(g, n, mesh)

# fault-free: strict replay fits, verify=True passes, oracle-identical
out = execute_plan(g, n, mesh, plan, replan=False, verify=True)
base = np.asarray(out[0])
assert np.array_equal(np.unique(np.asarray(g.eid)[base]),
                      np.flatnonzero(km))
assert float(out[5].injected) == 0.0

# clip at MINEDGES forces overflow: strict replay raises, never silent
clip = faults.FaultPlan(seed=0, specs=(
    faults.FaultSpec(kind="clip", site="minedges", cap_frac=0.125),))
try:
    with faults.inject(clip):
        execute_plan(g, n, mesh, plan, replan=False)
    raise SystemExit("clip fault was silent")
except RuntimeError as e:
    assert not isinstance(e, SystemExit)

# corruption is attributed: the injected counter moves, and the result
# is either bit-identical (tolerated) or detected by the oracle-armed
# verifier — the chaos invariant at test scale
corrupt = faults.FaultPlan(seed=0, specs=(
    faults.FaultSpec(kind="corrupt", site="minedges", fraction=0.25,
                     bit=26),))
detected = False
try:
    with faults.inject(corrupt):
        out_c = execute_plan(g, n, mesh, plan, replan=False)
        assert float(out_c[5].injected) > 0, "corruption not attributed"
except RuntimeError:
    detected = True
if not detected and not np.array_equal(np.asarray(out_c[0]), base):
    rep = verify_forest(g, n, mesh, out_c[0], out_c[3],
                        expected_weight=kw, expected_count=int(km.sum()),
                        raise_on_fail=False)
    assert not rep.ok, "corrupted forest passed oracle verification"

# injection must not perturb the fault-free path (caches were cleared)
out2 = execute_plan(g, n, mesh, plan, replan=False, verify=True)
assert np.array_equal(np.asarray(out2[0]), base)

# the verifier rejects doctored forests with the right reason
drop_one = base.copy()
drop_one[np.flatnonzero(drop_one)[0]] = False        # lose one edge
try:
    verify_forest(g, n, mesh, jnp.asarray(drop_one), out[3],
                  expected_weight=kw, expected_count=int(km.sum()))
    raise SystemExit("doctored mask passed verification")
except VerifyFailure as e:
    assert "count" in str(e), e
lab_bad = np.asarray(out[3]).copy()
lab_bad[0] = n + 5                                    # out-of-range label
try:
    verify_forest(g, n, mesh, out[0], jnp.asarray(lab_bad),
                  expected_weight=kw, expected_count=int(km.sum()))
    raise SystemExit("doctored labels passed verification")
except VerifyFailure as e:
    assert "outside" in str(e) or "fixpoint" in str(e), e
print("OK")
"""


@pytest.mark.slow
def test_fault_injection_engine_multidevice():
    assert run_multidevice(FAULTS_ENGINE, ndev=8).strip().endswith("OK")


# -- the ISSUE 8 kernel path is never silent either (subprocess) -----------

FAULTS_PALLAS = """
from jax.sharding import Mesh
from repro.comm import faults
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import execute_plan, plan_sharded_msf
from repro.core.verify import verify_forest
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("gnm", 256, avg_degree=8.0, seed=0)
g = build_dist_graph(u, v, w, n, p)[0]
km, kw = oracle.kruskal(u, v, w, n)
plan = plan_sharded_msf(g, n, mesh, pallas_minedges=True)
assert plan.pallas_minedges

# fault-free baseline through the fused kernel: verified, oracle-exact
out = execute_plan(g, n, mesh, plan, replan=False, verify=True)
base = np.asarray(out[0])
assert np.array_equal(np.unique(np.asarray(g.eid)[base]),
                      np.flatnonzero(km))

# corrupt at the minedges site with the kernel in the loop: injection is
# attributed, and the outcome is detect-or-tolerate — the PR 7 verifier
# must see through the kernel path, never a silently wrong forest
corrupt = faults.FaultPlan(seed=0, specs=(
    faults.FaultSpec(kind="corrupt", site="minedges", fraction=0.25,
                     bit=26),))
detected = False
try:
    with faults.inject(corrupt):
        out_c = execute_plan(g, n, mesh, plan, replan=False)
        assert float(out_c[5].injected) > 0, "corruption not attributed"
except RuntimeError:
    detected = True
if not detected and not np.array_equal(np.asarray(out_c[0]), base):
    rep = verify_forest(g, n, mesh, out_c[0], out_c[3],
                        expected_weight=kw, expected_count=int(km.sum()),
                        raise_on_fail=False)
    assert not rep.ok, "corrupted kernel-path forest passed verification"

# fault-free again after injection: kernel path unperturbed
out2 = execute_plan(g, n, mesh, plan, replan=False, verify=True)
assert np.array_equal(np.asarray(out2[0]), base)
print("OK")
"""


@pytest.mark.slow
def test_fault_injection_pallas_minedges_multidevice():
    assert run_multidevice(FAULTS_PALLAS, ndev=8).strip().endswith("OK")


# -- chaos determinism (subprocess) ----------------------------------------

CHAOS_DETERMINISM = """
from repro.launch.chaos import run_matrix, run_recovery_cells

# same FaultPlan seed -> identical cell outcomes, run to run: the
# selector is a hash of (seed, site, round, lane), never RNG state
a = run_matrix(("gnm",), 256, seed=4, batched=False, verbose=False)
b = run_matrix(("gnm",), 256, seed=4, batched=False, verbose=False)
assert a and len(a) == len(b)
key = lambda c: (c["fault"], c["family"], c["path"])
va = {key(c): (c["verdict"], c["injected_items"]) for c in a}
vb = {key(c): (c["verdict"], c["injected_items"]) for c in b}
assert va == vb, (va, vb)
assert not any(c["verdict"] == "SILENT" for c in a)

# the recovery cells are deterministic end to end too: checkpoint
# round, re-executed rounds and both verdict bits replay exactly
r1 = run_recovery_cells(("gnm",), 256, seed=4, verbose=False)
r2 = run_recovery_cells(("gnm",), 256, seed=4, verbose=False)
assert r1 == r2, (r1, r2)
assert {c["cell"] for c in r1} == {"resume", "elastic"}
print("OK")
"""


@pytest.mark.slow
def test_chaos_matrix_is_deterministic_multidevice():
    assert run_multidevice(CHAOS_DETERMINISM, ndev=8,
                           timeout=900).strip().endswith("OK")


# -- the hardened gateway (subprocess) -------------------------------------

GATEWAY_HARDENED = """
import time
from jax.sharding import Mesh
from repro.comm import faults
from repro.core import oracle
from repro.launch.serve_msf import make_traffic
from repro.serve.msf_gateway import (AdmissionError, MSFGateway,
                                     MSFRequest)

p = 8
n = 256
mesh = Mesh(np.array(jax.devices()), ("data",))

def star(seed, rid):
    rng = np.random.default_rng(seed)
    return MSFRequest(rid=rid, family="syn", u=np.zeros(n - 1, np.int32),
                      v=np.arange(1, n, dtype=np.int32),
                      w=rng.uniform(1, 10, n - 1).astype(np.float32), n=n)

def path(seed, rid):
    rng = np.random.default_rng(seed)
    return MSFRequest(rid=rid, family="syn",
                      u=np.arange(0, n - 1, dtype=np.int32),
                      v=np.arange(1, n, dtype=np.int32),
                      w=rng.uniform(1, 10, n - 1).astype(np.float32), n=n)

# (1) typed admission rejections, counted and marked on the request
gw = MSFGateway(mesh, max_edges=4096)
bad_w = star(0, 0)
bad_w.w[3] = np.nan
bad_ids = star(0, 1)
bad_ids.v[0] = n + 7
huge = MSFRequest(rid=2, family="syn", u=np.zeros(5000, np.int32),
                  v=np.ones(5000, np.int32),
                  w=np.ones(5000, np.float32), n=n)
for req, frag in ((bad_w, "NaN"), (bad_ids, "outside"),
                  (huge, "max_edges")):
    try:
        gw.submit(req)
        raise SystemExit(f"hostile request {req.rid} admitted")
    except AdmissionError as e:
        assert frag in str(e), (frag, e)
    assert req.served_via == "rejected" and frag in req.error
assert gw.stats.rejected == 3 and not gw.queue
ok = star(1, 3)
gw.submit(ok)
gw.run()
assert ok.done and ok.served_via == "batched"
assert gw.stats.served == 1 and gw.stats.rejected == 3

# (2) deadlines: a request queued past its deadline rejects, never
# serves late
gw2 = MSFGateway(mesh)
late = star(2, 0); late.deadline = 1e-6
fine = star(3, 1); fine.deadline = 300.0
gw2.submit(late); gw2.submit(fine)
time.sleep(0.01)
gw2.run()
assert late.done and late.served_via == "rejected", vars(late)
assert "deadline" in late.error
assert fine.done and fine.served_via == "batched"
assert gw2.stats.deadline_missed == 1 and gw2.stats.rejected == 1

# (3) max_retries_per_request=0 regression: a star-measured plan with
# hostile same-key path traffic REJECTS instead of replanning (and can
# never loop run()) — with breaker_threshold high so only the retry
# budget acts
gw3 = MSFGateway(mesh, cache_size=4, batch_slots=4,
                 max_retries_per_request=0, breaker_threshold=99,
                 min_samples=99)
s0 = star(4, 0)
gw3.submit(s0); gw3.run()
assert s0.served_via == "batched"
paths = [path(100 + i, 1 + i) for i in range(4)]
for r in paths:
    gw3.submit(r)
gw3.run()
assert not gw3.queue, "rejected requests must not requeue"
for r in paths:
    assert r.done and r.served_via == "rejected", vars(r)
    assert "retry budget" in r.error, r.error
assert gw3.stats.rejected == 4 and gw3.stats.retried == 4
assert gw3.stats.replans == 0 and gw3.stats.breaker_trips == 0

# (4) circuit breaker: consecutive failing steps trip the entry out of
# the LRU and quarantine the poisoners; fresh traffic re-measures
gw4 = MSFGateway(mesh, batch_slots=1, max_retries_per_request=0,
                 breaker_threshold=3, min_samples=99)
s1 = star(5, 0)
gw4.submit(s1); gw4.run()
key = gw4._key(s1)
assert key in gw4.cache
poison = [path(200 + i, 1 + i) for i in range(3)]
for r in poison:
    gw4.submit(r)
gw4.run()
assert all(r.served_via == "rejected" for r in poison)
assert gw4.stats.breaker_trips == 1, vars(gw4.stats)
assert key not in gw4.cache          # quarantined
fresh = path(300, 9)
gw4.submit(fresh); gw4.run()
assert fresh.served_via == "batched"          # fresh measurement fits
km, kw = oracle.kruskal(fresh.u, fresh.v, fresh.w, n)
assert np.array_equal(fresh.edges, np.flatnonzero(km))

# (5) self-verifying serving under injected faults: a verify=True
# gateway facing capacity-starved exchanges either serves the exact
# forest or rejects — never a silently wrong result — and run()
# terminates.  (clip is detected at the transport layer by
# construction: the batched replay flags it per-request in defer mode
# and the replan rung's measured pass reports nonzero overflow too.)
gw5 = MSFGateway(mesh, verify=True, max_retries_per_request=1,
                 breaker_threshold=5, backoff_base=0.01)
warm = make_traffic(("gnm",), (n,), 1, seed=7)
gw5.submit(warm[0])
gw5.run()
assert warm[0].served_via == "batched" and warm[0].done
reqs = make_traffic(("gnm",), (n,), 2, seed=8)
for r in reqs:
    gw5.submit(r)
clip = faults.FaultPlan(seed=3, specs=(
    faults.FaultSpec(kind="clip", site="minedges", cap_frac=0.125),))
with faults.inject(clip):
    gw5.run(max_steps=50)
for r in reqs:
    assert r.done, vars(gw5.stats)
    if r.served_via != "rejected":
        km, kw = oracle.kruskal(r.u, r.v, r.w, r.n)
        assert np.array_equal(r.edges, np.flatnonzero(km)), \
            (r.rid, r.served_via, "silently wrong forest served")
assert gw5.stats.retried >= 1, vars(gw5.stats)
# the fault-free path is unperturbed afterwards: the same key keeps
# serving exact forests
clean = make_traffic(("gnm",), (n,), 2, seed=17)
for r in clean:
    gw5.submit(r)
gw5.run()
for r in clean:
    km, kw = oracle.kruskal(r.u, r.v, r.w, r.n)
    assert r.served_via in ("batched", "replanned"), vars(r)
    assert np.array_equal(r.edges, np.flatnonzero(km))
print("OK")
"""


@pytest.mark.slow
def test_gateway_hardening_multidevice():
    assert run_multidevice(GATEWAY_HARDENED, ndev=8,
                           timeout=900).strip().endswith("OK")
