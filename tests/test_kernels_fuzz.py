"""Property-test wall for the fused MINEDGES scatter-min kernel (ISSUE 8).

Three implementations of the (w, eid)-lexicographic scatter-min with
payload-at-winner carry must stay bit-for-bit identical on adversarial
inputs:

  * ``segmin.owner_scatter_min`` — the fused Pallas kernel (grid-swept
    one-hot min-semiring accumulation, interpret mode on CPU);
  * ``ref.owner_scatter_min_ref`` — the sequential lax.scan oracle, one
    candidate at a time, no reliance on scatter/reduction order;
  * the jnp ``.at[].min/.max`` scatter construction the engine used
    before the kernel (mirrored here verbatim from
    ``core/distributed_sharded._owner_scatter_min``).

A wrong tie-break here silently corrupts the MSF — on most random
graphs a bad (w, eid) order still yields a spanning tree of the right
weight — so the wall pins exact int equality on the eid/payload tables,
not just weights, across duplicate-(idx, w) tie storms, all-dead
segments, single-candidate and empty arrays, block-boundary and
non-dividing lengths, and +inf (INVALID_W) weight tails.

Also pins the ``run_metadata`` L==0 / L==1 guard (satellite: the fused
combine calls it on possibly-empty per-shard slices) and the per-run
combine identity the engine's src-only MINEDGES relies on: with the
run-constant ``ru`` payload, max-over-winners (the kernel's channel 2)
equals the jnp path's max-over-alive.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.segmin.ops import run_metadata, scatter_min_tables
from repro.kernels.segmin.ref import (EID_SENTINEL, owner_scatter_min_ref,
                                      segmin_candidates_ref)
from repro.kernels.segmin.segmin import owner_scatter_min
from tests.helpers.hypothesis_compat import given, settings, st

# at least 3 block geometries, including blocks that do not divide the
# candidate length and out-tiles that do not divide the table size
BLOCKS = [(8, 8), (16, 32), (128, 64), (512, 256)]


def _jnp_scatter_tables(idx, w, eid, pay1, pay2, ok, size):
    """The pre-kernel engine construction, mirrored bit-for-bit
    (``_owner_scatter_min``'s jnp branch with a second payload)."""
    idx = jnp.asarray(idx)
    w = jnp.asarray(w, jnp.float32)
    eid = jnp.asarray(eid)
    ok = jnp.asarray(ok)
    off = jnp.where(ok, idx, size)  # size = drop row
    wmin = jnp.full((size + 1,), jnp.inf, jnp.float32).at[off].min(
        jnp.where(ok, w, jnp.inf))
    at_min = ok & (w == wmin[off])
    emin = jnp.full((size + 1,), EID_SENTINEL, jnp.int32).at[off].min(
        jnp.where(at_min, eid, EID_SENTINEL))
    is_win = at_min & (eid == emin[off])
    p1 = jnp.full((size + 1,), -1, jnp.int32).at[off].max(
        jnp.where(is_win, jnp.asarray(pay1), -1))
    p2 = jnp.full((size + 1,), -1, jnp.int32).at[off].max(
        jnp.where(is_win, jnp.asarray(pay2), -1))
    return wmin[:size], emin[:size], p1[:size], p2[:size]


def _assert_tables_equal(got, exp, ctx):
    gw, ge, g1, g2 = (np.asarray(x) for x in got)
    ew, ee, e1, e2 = (np.asarray(x) for x in exp)
    # weights compared with array_equal: inf defaults must match exactly
    np.testing.assert_array_equal(gw, ew, err_msg=f"{ctx}: wmin")
    np.testing.assert_array_equal(ge, ee, err_msg=f"{ctx}: emin")
    np.testing.assert_array_equal(g1, e1, err_msg=f"{ctx}: pay1")
    np.testing.assert_array_equal(g2, e2, err_msg=f"{ctx}: pay2")


def _check_three_way(idx, w, eid, pay1, pay2, ok, size, block, out_block,
                     ctx):
    args = (jnp.asarray(idx), jnp.asarray(w, jnp.float32),
            jnp.asarray(eid), jnp.asarray(pay1), jnp.asarray(pay2),
            jnp.asarray(ok))
    kern = owner_scatter_min(*args, size, block=block,
                             out_block=out_block, interpret=True)
    ref = owner_scatter_min_ref(*args, size)
    mirror = _jnp_scatter_tables(idx, w, eid, pay1, pay2, ok, size)
    _assert_tables_equal(kern, ref, f"{ctx}: kernel vs sequential ref")
    _assert_tables_equal(kern, mirror, f"{ctx}: kernel vs jnp scatter")


def _random_candidates(rng, L, size, tie_heavy, inf_tail):
    idx = rng.integers(0, size, L).astype(np.int32)
    if tie_heavy:
        # duplicate (idx, w) pairs force the eid tie-break to decide
        w = rng.integers(1, 4, L).astype(np.float32)
    else:
        w = rng.uniform(1, 255, L).astype(np.float32)
    if inf_tail and L:
        # INVALID_W padding tails: +inf candidates may still carry
        # ok=True (the engine masks them by aliveness, the kernel must
        # order them after every finite weight and tie-break exactly)
        k = rng.integers(0, L + 1)
        w[L - k:] = np.inf
    eid = rng.integers(0, 2 ** 20, L).astype(np.int32)
    pay1 = rng.integers(0, 1000, L).astype(np.int32)
    pay2 = rng.integers(0, 1000, L).astype(np.int32)
    ok = rng.random(L) < 0.8
    return idx, w, eid, pay1, pay2, ok


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 300), st.integers(1, 64),
       st.integers(0, 2 ** 31 - 1), st.sampled_from(BLOCKS),
       st.booleans(), st.booleans())
def test_scatter_min_parity_fuzz(L, size, seed, blocks, tie_heavy,
                                 inf_tail):
    block, out_block = blocks
    rng = np.random.default_rng(seed)
    cand = _random_candidates(rng, L, size, tie_heavy, inf_tail)
    _check_three_way(*cand, size, block, out_block,
                     (L, size, seed, blocks, tie_heavy, inf_tail))


@pytest.mark.parametrize("block,out_block", BLOCKS)
@pytest.mark.parametrize("seed", range(8))
def test_scatter_min_parity_sweep(seed, block, out_block):
    """Deterministic random sweep — the hypothesis wall's coverage floor
    when hypothesis is not installed (the shim skips the @given test)."""
    rng = np.random.default_rng(seed * 1000 + block)
    L = int(rng.integers(0, 300))
    size = int(rng.integers(1, 64))
    cand = _random_candidates(rng, L, size, tie_heavy=bool(seed % 2),
                              inf_tail=bool(seed % 3 == 0))
    _check_three_way(*cand, size, block, out_block,
                     (seed, L, size, block, out_block))


@pytest.mark.parametrize("block,out_block", BLOCKS)
def test_scatter_min_adversarial_cases(block, out_block):
    rng = np.random.default_rng(7)
    cases = {
        "empty_shard": (0, 8),
        "single_candidate": (1, 4),
        "single_slot_table": (37, 1),
        "block_exact": (block, out_block),       # capacity boundary
        "block_plus_one": (block + 1, out_block),
        "block_minus_one": (max(block - 1, 1), out_block),
    }
    for name, (L, size) in cases.items():
        cand = _random_candidates(rng, L, size, tie_heavy=True,
                                  inf_tail=True)
        _check_three_way(*cand, size, block, out_block, name)
    # all-dead segments: every candidate masked out -> pure defaults
    L, size = 50, 16
    idx, w, eid, p1, p2, _ = _random_candidates(rng, L, size, False, False)
    _check_three_way(idx, w, eid, p1, p2, np.zeros(L, bool), size,
                     block, out_block, "all_dead")
    got = owner_scatter_min(jnp.asarray(idx), jnp.asarray(w),
                            jnp.asarray(eid), jnp.asarray(p1),
                            jnp.asarray(p2), jnp.zeros(L, bool), size,
                            block=block, out_block=out_block,
                            interpret=True)
    assert np.all(np.isinf(np.asarray(got[0])))
    assert np.all(np.asarray(got[1]) == int(EID_SENTINEL))
    assert np.all(np.asarray(got[2]) == -1)
    assert np.all(np.asarray(got[3]) == -1)


def test_scatter_min_exact_tie_storm():
    """Every candidate identical (idx, w) — winner is pure eid order,
    and equal-eid duplicates resolve payloads by the max rule in all
    three implementations."""
    L, size = 96, 4
    idx = np.full(L, 2, np.int32)
    w = np.full(L, 5.0, np.float32)
    eid = np.concatenate([np.full(L // 2, 11, np.int32),
                          np.arange(L // 2, dtype=np.int32) + 11])
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, 100, L).astype(np.int32)
    p2 = rng.integers(0, 100, L).astype(np.int32)
    ok = np.ones(L, bool)
    for block, out_block in BLOCKS:
        _check_three_way(idx, w, eid, p1, p2, ok, size, block, out_block,
                         ("tie_storm", block, out_block))


def test_scatter_min_dispatcher_routes_both_paths():
    rng = np.random.default_rng(3)
    cand = _random_candidates(rng, 130, 12, True, True)
    args = tuple(jnp.asarray(x) for x in cand)
    via_kernel = scatter_min_tables(*args, 12, block=16, out_block=8,
                                    interpret=True, use_pallas=True)
    via_ref = scatter_min_tables(*args, 12, use_pallas=False)
    _assert_tables_equal(via_kernel, via_ref, "dispatcher")


def test_combine_site_matches_segmin_ref_per_run():
    """The engine's pre-routing combine keyed by run_id must agree with
    the phase-1 segmented-scan reference: for run-sorted candidates the
    kernel's (wmin, emin) table entries at each run id equal the
    boundary candidates ``segmin_candidates_ref`` emits for that run,
    and the run-constant channel-2 payload (``ru``) recovered at the
    winner equals the jnp path's max-over-alive."""
    rng = np.random.default_rng(11)
    L = 257  # non-dividing on every block size above
    u = np.sort(rng.integers(0, 40, L)).astype(np.int32)
    w = rng.integers(1, 5, L).astype(np.float32)  # heavy ties
    eid = rng.permutation(L).astype(np.int32)
    alive = rng.random(L) < 0.7
    rv = rng.integers(0, 40, L).astype(np.int32)
    ru = u * 3 + 1  # any run-constant function of u
    head, head_idx, run_id = (np.asarray(x) for x in run_metadata(
        jnp.asarray(u)))

    wt, et, p1, p2 = owner_scatter_min(
        jnp.asarray(run_id), jnp.asarray(w), jnp.asarray(eid),
        jnp.asarray(rv), jnp.asarray(ru), jnp.asarray(alive), L,
        block=64, out_block=32, interpret=True)
    wt, et, p1, p2 = (np.asarray(x) for x in (wt, et, p1, p2))

    cw, ce = (np.asarray(x) for x in segmin_candidates_ref(
        jnp.asarray(run_id), jnp.asarray(w), jnp.asarray(eid),
        jnp.asarray(alive)))
    # boundary candidates live at each run's last slot; its run id keys
    # the kernel table
    last = np.concatenate([run_id[1:] != run_id[:-1], [True]])
    np.testing.assert_array_equal(wt[run_id[last]], cw[last])
    np.testing.assert_array_equal(et[run_id[last]], ce[last])
    # run-constant payload: winner-carry == max over the run's alive
    # slots (the identity that lets the kernel replace the crun scatter)
    crun = np.full(L, -1, np.int64)
    np.maximum.at(crun, run_id, np.where(alive, ru, -1))
    np.testing.assert_array_equal(p2, crun.astype(np.int32))


# --------------------------------------------------------------------------
# run_metadata degenerate shapes (satellite: the fused combine calls it
# on possibly-empty per-shard slices)
# --------------------------------------------------------------------------

def test_run_metadata_empty():
    head, head_idx, run_id = run_metadata(jnp.zeros((0,), jnp.int32))
    assert head.shape == head_idx.shape == run_id.shape == (0,)
    assert head.dtype == np.dtype(bool)
    assert np.asarray(head_idx).dtype == np.int32


def test_run_metadata_single():
    head, head_idx, run_id = run_metadata(jnp.asarray([42], jnp.int32))
    np.testing.assert_array_equal(np.asarray(head), [True])
    np.testing.assert_array_equal(np.asarray(head_idx), [0])
    np.testing.assert_array_equal(np.asarray(run_id), [0])


def test_run_metadata_empty_with_perm():
    head, head_idx, run_id = run_metadata(
        jnp.zeros((0,), jnp.int32), perm=jnp.zeros((0,), jnp.int32))
    assert head.shape == (0,)
    assert run_id.shape == (0,)


def test_scatter_min_empty_and_zero_size():
    z = jnp.zeros((0,), jnp.int32)
    zw = jnp.zeros((0,), jnp.float32)
    zb = jnp.zeros((0,), bool)
    wt, et, p1, p2 = owner_scatter_min(z, zw, z, z, z, zb, 5,
                                       interpret=True)
    assert wt.shape == (5,) and np.all(np.isinf(np.asarray(wt)))
    assert np.all(np.asarray(et) == int(EID_SENTINEL))
    wt, et, p1, p2 = owner_scatter_min(
        jnp.asarray([0], jnp.int32), jnp.asarray([1.0], jnp.float32),
        jnp.asarray([3], jnp.int32), jnp.asarray([7], jnp.int32),
        jnp.asarray([9], jnp.int32), jnp.asarray([True]), 0,
        interpret=True)
    assert wt.shape == (0,) and et.shape == (0,)
