"""Blockwise (flash-style) attention == naive attention, GQA and MLA."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.layers import gqa_attention, mla_attention
from repro.models.model import forward_train, init_params


def _x(B, S, D, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, S, D)) * 0.3, dtype)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("block", [4, 16, 64])
def test_gqa_blockwise_matches_naive(causal, block):
    cfg = get_arch("llama3.2-3b").smoke
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    lp = jax.tree.map(lambda a: a[0], params["blocks"]["attn"])
    x = _x(2, 24, cfg.d_model)
    pos = jnp.arange(24)[None, :]
    naive, _ = gqa_attention(cfg, lp, x, pos, causal=causal)
    cfg_b = dataclasses.replace(cfg, attn_impl="blockwise",
                                attn_block=block)
    blk, _ = gqa_attention(cfg_b, lp, x, pos, causal=causal)
    np.testing.assert_allclose(np.asarray(blk, np.float32),
                               np.asarray(naive, np.float32),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("block", [8, 32])
def test_mla_blockwise_matches_naive(block):
    cfg = get_arch("deepseek-v2-236b").smoke
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.key(1))
    lp = jax.tree.map(lambda a: a[0], params["moe_blocks"]["attn"])
    x = _x(2, 24, cfg.d_model, seed=3)
    pos = jnp.arange(24)[None, :]
    naive, _ = mla_attention(cfg, lp, x, pos)
    cfg_b = dataclasses.replace(cfg, attn_impl="blockwise",
                                attn_block=block)
    blk, _ = mla_attention(cfg_b, lp, x, pos)
    np.testing.assert_allclose(np.asarray(blk, np.float32),
                               np.asarray(naive, np.float32),
                               atol=2e-5, rtol=2e-4)


def test_blockwise_full_model_loss_matches():
    cfg = get_arch("qwen2-1.5b").smoke
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg32, jax.random.key(2))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    l_naive = forward_train(cfg32, params, batch)
    cfg_b = dataclasses.replace(cfg32, attn_impl="blockwise", attn_block=8)
    l_blk = forward_train(cfg_b, params, batch)
    assert float(l_naive) == pytest.approx(float(l_blk), rel=1e-4)
    # gradients agree too (bwd through the online-softmax scan)
    g1 = jax.grad(lambda p: forward_train(cfg32, p, batch))(params)
    g2 = jax.grad(lambda p: forward_train(cfg_b, p, batch))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)
