"""repro.compat: the JAX 0.4.x / >=0.6 bridge must expose one working
surface on whichever generation is installed (EXPERIMENTS.md §Compat)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from tests.helpers.subproc import run_multidevice


def test_exports_present():
    for name in ("shard_map", "pvary", "vma_of", "vary", "psum_scatter",
                 "axis_size", "HAS_VMA", "HAS_NATIVE_SHARD_MAP"):
        assert hasattr(compat, name), name
    assert isinstance(compat.HAS_VMA, bool)
    assert isinstance(compat.HAS_NATIVE_SHARD_MAP, bool)
    # flags must reflect the installed generation, not hardcode one
    assert compat.HAS_NATIVE_SHARD_MAP == hasattr(jax, "shard_map")
    assert compat.HAS_VMA == (hasattr(jax.lax, "pvary")
                              and hasattr(jax, "typeof"))


def test_pvary_vary_outside_shard_map():
    x = jnp.arange(4.0)
    # with no vma system, pvary/vary must be exact identities
    if not compat.HAS_VMA:
        assert compat.pvary(x, ("a", "b")) is x
        assert compat.vary(x, ("a",)) is x
    # empty axis tuple is an identity on every generation
    assert compat.vary(x, ()) is x
    assert compat.vma_of(x) == frozenset()


def test_shard_map_single_device_in_process():
    """The bridge runs in the main test process (1 device, 1-shard mesh)."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    P = jax.sharding.PartitionSpec

    def body(a):
        s = jax.lax.psum(jnp.sum(a), ("x",))
        return compat.vary(jnp.full((2,), s), ("x",)) + compat.axis_size("x")

    f = compat.shard_map(body, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    out = np.asarray(f(jnp.arange(2.0)))
    np.testing.assert_allclose(out, [2.0, 2.0])  # sum 1 + axis_size 1


MULTI = """
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat

mesh = Mesh(np.array(jax.devices()), ("x",))
p = 4

def body(a):
    # axis_size: static int on 0.4.x, usable as a shape/constant
    assert compat.axis_size("x") == p
    a = compat.vary(a, ("x",))
    # psum_scatter over equal slices == slice of psum
    full = jax.lax.psum(a, ("x",))
    scat = compat.psum_scatter(a, "x", scatter_dimension=0, tiled=True)
    i = jax.lax.axis_index("x")
    want = jax.lax.dynamic_slice_in_dim(full, i * (a.shape[0] // p),
                                        a.shape[0] // p)
    return jax.lax.pmin(jnp.all(scat == want).astype(jnp.int32), "x")

f = compat.shard_map(body, mesh=mesh, in_specs=P("x"), out_specs=P())
x = jnp.arange(p * 8, dtype=jnp.float32)
assert int(f(x)) == 1
print("OK")
"""


def test_shard_map_multidevice_semantics():
    out = run_multidevice(MULTI, ndev=4)
    assert "OK" in out
