"""Docs integrity — mirrors the CI docs step: the top-level docs must
exist and every intra-repo link in them must resolve
(tools/check_links.py, ISSUE 3 satellite)."""
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_intra_repo_links():
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_links.py"),
         "README.md", "docs/ARCHITECTURE.md", "EXPERIMENTS.md",
         "ROADMAP.md"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
