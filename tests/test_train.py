"""Training substrate: loss goes down, grad-accum equivalence, checkpoint
restart, gradient compression, optimizer math."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.model import init_params
from repro.train import checkpoint as ckpt
from repro.train import compression
from repro.train.optimizer import AdamWConfig, apply_update, init_state
from repro.train.train_loop import TrainConfig, make_train_step, train


def _data_iter(cfg, B=8, S=32, seed=0):
    rng = np.random.default_rng(seed)
    # a learnable synthetic task: token t+1 = (t * 3 + 1) % V on half the
    # stream, random elsewhere — loss must drop markedly within ~60 steps
    V = cfg.vocab_size
    while True:
        t0 = rng.integers(0, V, (B, 1))
        seq = [t0]
        for _ in range(S):
            seq.append((seq[-1] * 3 + 1) % V)
        arr = np.concatenate(seq, axis=1)
        yield {"tokens": jnp.asarray(arr[:, :S], jnp.int32),
               "labels": jnp.asarray(arr[:, 1:S + 1], jnp.int32)}


def test_loss_decreases():
    cfg = get_arch("llama3.2-3b").smoke
    tc = TrainConfig(opt=AdamWConfig(lr=1e-2, warmup_steps=5,
                                     total_steps=80))
    res = train(cfg, tc, _data_iter(cfg), num_steps=60,
                log=lambda *_: None)
    assert res["losses"][-1] < res["losses"][0] * 0.7, res["losses"]


def test_grad_accum_equivalence():
    cfg = get_arch("qwen2-1.5b").smoke
    data = _data_iter(cfg, B=8)
    batch = next(data)
    tc1 = TrainConfig(opt=AdamWConfig(lr=1e-3), microbatches=1)
    tc4 = TrainConfig(opt=AdamWConfig(lr=1e-3), microbatches=4)
    params = init_params(cfg, jax.random.key(0))
    s1 = init_state(params)
    p1, _, m1 = jax.jit(make_train_step(cfg, tc1))(params, s1, batch)
    params2 = init_params(cfg, jax.random.key(0))
    s2 = init_state(params2)
    p4, _, m4 = jax.jit(make_train_step(cfg, tc4))(params2, s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    # parameters after one step agree to bf16-accumulation tolerance
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-2, d


def test_checkpoint_restart(tmp_path):
    cfg = get_arch("llama3.2-3b").smoke
    ckdir = str(tmp_path / "ck")
    tc = TrainConfig(opt=AdamWConfig(lr=5e-3), ckpt_dir=ckdir, ckpt_every=5,
                     log_every=100)
    r1 = train(cfg, tc, _data_iter(cfg), num_steps=10, log=lambda *_: None)
    # "crash" and resume: the loop must pick up at step 10 and produce
    # the same params as an uninterrupted 20-step run
    r2 = train(cfg, tc, _data_iter(cfg), num_steps=20, log=lambda *_: None)
    tc_clean = TrainConfig(opt=AdamWConfig(lr=5e-3),
                           ckpt_dir=str(tmp_path / "clean"), ckpt_every=50,
                           log_every=100)
    r3 = train(cfg, tc_clean, _data_iter(cfg), num_steps=20,
               log=lambda *_: None)
    # data stream is deterministic and restarts from its beginning in run
    # 2, so exact equality is not expected — but shapes/val sanity are:
    for a, b in zip(jax.tree.leaves(r2["params"]),
                    jax.tree.leaves(r3["params"])):
        assert a.shape == b.shape
    assert np.isfinite(r2["losses"][-1])


def test_checkpoint_corruption_detected(tmp_path):
    cfg = get_arch("qwen2-1.5b").smoke
    params = init_params(cfg, jax.random.key(0))
    tree = {"params": params}
    ckpt.save(str(tmp_path), 5, tree)
    ckpt.save(str(tmp_path), 10, tree)
    assert ckpt.latest_step(str(tmp_path)) == 10
    # corrupt the newest: delete a leaf file -> restore must fall back
    d = os.path.join(str(tmp_path), "step_0000000010")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    os.remove(os.path.join(d, victim))
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored = ckpt.restore(str(tmp_path), 5, tree, verify=True)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(333,)).astype(np.float32))}
    r = compression.init_residual(g)
    total = np.zeros(333, np.float32)
    sent_total = np.zeros(333, np.float32)
    for _ in range(50):
        sent, r = compression.compress_with_feedback(g, r)
        total += np.asarray(g["w"])
        sent_total += np.asarray(sent["w"])
    # error feedback: long-run average of sent gradients converges to the
    # true gradient (residual stays bounded)
    np.testing.assert_allclose(sent_total / 50, total / 50, atol=1e-2)
    assert float(jnp.max(jnp.abs(r["w"]))) < 0.1


def test_adamw_direction():
    params = {"w": jnp.asarray([1.0, -1.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.5, -0.5], jnp.float32)}
    st = init_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=10)
    p2, st2 = apply_update(cfg, params, grads, st)
    # moves against the gradient
    assert float(p2["w"][0]) < 1.0 and float(p2["w"][1]) > -1.0
    assert int(st2.step) == 1
