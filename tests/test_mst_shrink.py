"""Geometric-shrink distributed Borůvka (§Perf variant) vs oracle."""
import pytest

from tests.helpers.subproc import run_multidevice

BODY = """
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph, distributed_msf
from repro.core import oracle
from repro.data import generators

mesh = Mesh(np.array(jax.devices()), ("data",))
for fam, n in [("gnm", 512), ("grid2d", 1024), ("rmat", 512)]:
    u, v, w, nn = generators.generate(fam, n, avg_degree=8.0, seed=11)
    g, cap = build_dist_graph(u, v, w, nn, 8)
    _, expect = oracle.kruskal(u, v, w, nn)
    ncomp = len(np.unique(oracle.component_labels(u, v, nn)))
    for pre in (True, False):
        mask, wt, cnt, labels, stats = distributed_msf(
            g, nn, mesh, algorithm="boruvka_shrink", axis_names=("data",),
            local_preprocessing=pre)
        assert abs(float(wt) - expect) < 1e-3 * max(1.0, expect), (
            fam, pre, float(wt), expect)
        assert int(cnt) == nn - ncomp, (fam, pre, int(cnt), nn - ncomp)
        mk = np.asarray(mask)
        assert oracle.is_forest(np.asarray(g.u)[mk], np.asarray(g.v)[mk],
                                nn)
# ties too
rng = np.random.default_rng(1)
u = rng.integers(0, 200, 1500).astype(np.int32)
v = rng.integers(0, 200, 1500).astype(np.int32)
keep = u != v
w = rng.integers(1, 5, keep.sum()).astype(np.float32)
g, cap = build_dist_graph(u[keep], v[keep], w, 200, 8)
_, expect = oracle.kruskal(u[keep], v[keep], w, 200)
mask, wt, cnt, _, _ = distributed_msf(g, 200, mesh,
                                      algorithm="boruvka_shrink",
                                      axis_names=("data",))
assert abs(float(wt) - expect) < 1e-3 * expect, (float(wt), expect)

# degenerate sizes: the shrink ladder's first rung must never exceed the
# n-sized slot buffers (n=1 regressed once when the ladder was clamped
# to a minimum of 2)
for nn in (1, 2):
    g, cap = build_dist_graph(np.zeros(0, np.int32), np.zeros(0, np.int32),
                              np.zeros(0, np.float32), nn, 8)
    for algo in ("boruvka_shrink", "boruvka_shrink_srconly"):
        out = distributed_msf(g, nn, mesh, algorithm=algo,
                              axis_names=("data",))
        assert float(out[1]) == 0.0 and int(out[2]) == 0, (nn, algo)
print("OK")
"""


def test_shrink_variant_correct():
    out = run_multidevice(BODY, ndev=8, timeout=900)
    assert "OK" in out
