"""Elastic restart: a checkpoint written under one device topology is
restored, resharded, onto a different mesh (the node-failure /
shrink-the-job recovery path from DESIGN.md §5)."""
import os

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models.model import init_params
from repro.train import checkpoint as ckpt
from tests.helpers.subproc import run_multidevice


def test_restore_onto_bigger_mesh(tmp_path):
    # save on the single-device main process
    cfg = get_arch("llama3.2-3b").smoke
    params = init_params(cfg, jax.random.key(0))
    ckpt.save(str(tmp_path), 7, {"params": params})
    ref = float(np.sum(np.asarray(jax.tree.leaves(params)[0],
                                  np.float32)))

    body = f"""
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch
from repro.models.model import init_params
from repro.models.sharding import param_shardings
from repro.train import checkpoint as ckpt

cfg = get_arch("llama3.2-3b").smoke
like = {{"params": jax.eval_shape(lambda: init_params(cfg,
                                                      jax.random.key(0)))}}
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
sh = {{"params": param_shardings(like["params"], mesh)}}
assert ckpt.latest_step({str(tmp_path)!r}) == 7
tree = ckpt.restore({str(tmp_path)!r}, 7, like, shardings=sh, verify=True)
leaf = jax.tree.leaves(tree["params"])[0]
# placed on the 8-device mesh with the rule-derived sharding
assert len(leaf.sharding.device_set) in (1, 2, 4, 8), leaf.sharding
total = float(jnp.sum(leaf.astype(jnp.float32)))
assert abs(total - {ref!r}) < 1e-2 * max(abs({ref!r}), 1.0), total
print("OK")
"""
    out = run_multidevice(body, ndev=8)
    assert "OK" in out
