"""Dry-run machinery: production mesh shapes, one real 512-device cell
compile (subprocess), HLO collective parser unit behaviour."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.roofline import (RooflineTerms, collective_bytes_from_hlo,
                                   model_flops)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_roofline_terms_math():
    t = RooflineTerms(flops=197e12, bytes_accessed=819e9,
                      collective_bytes=50e9, chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    t2 = RooflineTerms(flops=1e12, bytes_accessed=819e9,
                       collective_bytes=0, chips=256)
    assert t2.dominant == "memory"
    assert t2.compute_fraction < 0.01


def test_collective_parser_weights_while_loops():
    hlo = """
HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ag = f32[32]{0} all-gather(%a), replica_groups={{0,1,2,3}}, dimensions={0}
  %t0 = (s32[], f32[8]) tuple(%zero, %a)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    res = collective_bytes_from_hlo(hlo)
    # all-reduce: 8 floats * 4B = 32B, x5 trips = 160
    assert res["all-reduce_bytes"] == pytest.approx(160.0)
    assert res["all-reduce_count"] == pytest.approx(5.0)
    # all-gather result 32 floats = 128B; operand = 128/4 = 32
    assert res["all-gather_bytes"] == pytest.approx(32.0)


def test_model_flops_sanity():
    from repro.configs.base import get_arch
    cfg = get_arch("llama3.2-3b").config
    info = {"kind": "train", "seq": 4096, "batch": 256}
    mf = model_flops(cfg, info, backward=True)
    # 6 * 3.6e9 * 1.05e6 tokens ~ 2.3e16, plus attention
    assert 2.0e16 < mf < 4.5e16, mf


@pytest.mark.slow
def test_one_cell_compiles_on_512_devices():
    """The real thing, scoped to one fast cell (mamba2 decode)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = "/tmp/dryrun_pytest.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-130m", "--shape", "decode_32k", "--mesh", "multi",
         "--no-probes", "--out", out],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    rec = json.load(open(out))[0]
    assert rec["status"] == "ok", rec
    assert rec["roofline"]["flops"] > 0
