"""Per-architecture smoke tests: reduced config, one train-forward + one
decode step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models.model import (forward_decode, forward_prefill,
                                forward_train, init_caches, init_params)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)),
            jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_forward(arch):
    cfg = get_arch(arch).smoke
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    loss = jax.jit(lambda p, b: forward_train(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    # a gradient step must also be finite (exercises bwd of every layer)
    g = jax.jit(jax.grad(lambda p, b: forward_train(cfg, p, b)))(params,
                                                                 batch)
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch).smoke
    params = init_params(cfg, jax.random.key(1))
    B, T = 2, 32
    caches = init_caches(cfg, B, T)
    if cfg.family == "audio":
        rng = np.random.default_rng(1)
        caches["enc"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)),
            jnp.bfloat16)
    tokens = jnp.asarray([1, 2], jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)
    step = jax.jit(lambda p, c, t, q: forward_decode(cfg, p, c, t, q))
    logits, caches = step(params, caches, tokens, pos)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # second step at the next position reuses the updated cache
    logits2, caches = step(params, caches, tokens + 1, pos + 1)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-v2-236b",
                                  "mamba2-130m", "whisper-small"])
def test_smoke_prefill(arch):
    cfg = get_arch(arch).smoke
    params = init_params(cfg, jax.random.key(2))
    batch = _batch(cfg, B=2, S=8)
    logits = jax.jit(lambda p, b: forward_prefill(cfg, p, b))(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_decode_matches_prefill_dense():
    """Decoding token-by-token must equal the parallel causal forward."""
    cfg = get_arch("llama3.2-3b").smoke
    params = init_params(cfg, jax.random.key(3))
    B, S = 1, 6
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    # parallel logits
    from repro.models.model import _backbone, _embed
    from repro.models.layers import rmsnorm

    def full_logits(p, b):
        x = _embed(cfg, p, b["tokens"], b)
        x = _backbone(cfg, p, x, jnp.arange(S)[None], None)
        x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
        return jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(x.dtype))

    ref = np.asarray(jax.jit(full_logits)(params, batch), np.float32)
    caches = init_caches(cfg, B, S)
    outs = []
    for t in range(S):
        logits, caches = jax.jit(
            lambda p, c, tk, q: forward_decode(cfg, p, c, tk, q))(
                params, caches, jnp.asarray(toks[:, t]),
                jnp.full((B,), t, jnp.int32))
        outs.append(np.asarray(logits, np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, ref, atol=0.75, rtol=0.15)
    # ranking agreement at the last step (bf16 tolerance-robust check)
    assert got[0, -1].argmax() == ref[0, -1].argmax()


def test_param_counts_match_published_class():
    """Full configs must land in the published parameter-count class."""
    expect = {
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "deepseek-7b": (6e9, 8e9),
        "command-r-35b": (30e9, 40e9),
        "llama3.2-3b": (2.5e9, 4e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "internvl2-76b": (65e9, 85e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "llama4-maverick-400b-a17b": (330e9, 440e9),
        # zamba2: we model the shared block without its per-site LoRA
        # adapters and with expand=1 per the assigned 32H spec, so the
        # band is wider on the low side (see DESIGN.md)
        "zamba2-1.2b": (0.5e9, 1.6e9),
        # whisper-small publishes 244M with tied embeddings; we untie
        "whisper-small": (0.15e9, 0.35e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_arch(arch).config.param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)


def test_moe_local_vs_dispatch_semantics():
    """moe_local == moe_dispatch on a trivial 1-device mesh context."""
    import dataclasses
    from repro.models import moe as moe_lib
    cfg = get_arch("llama4-maverick-400b-a17b").smoke
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, jax.random.key(0))
    lp = jax.tree.map(lambda a: a[0], params["moe_blocks"]["moe"])
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y = moe_lib.moe_local(cfg, lp, x)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    # every token got k experts' worth of output (no silent zeros with
    # ample capacity): compare against explicit dense evaluation
    gates, experts = moe_lib.router_topk(
        x.reshape(-1, cfg.d_model), lp["router"], cfg.num_experts_per_tok)
    dense = np.zeros((16, cfg.d_model), np.float32)
    xe = np.asarray(x.reshape(-1, cfg.d_model), np.float32)
    wg = np.asarray(lp["wg"], np.float32)
    wu = np.asarray(lp["wu"], np.float32)
    wd = np.asarray(lp["wd"], np.float32)
    for t in range(16):
        for j in range(cfg.num_experts_per_tok):
            e = int(experts[t, j])
            g = xe[t] @ wg[e]
            u = xe[t] @ wu[e]
            h = (g / (1 + np.exp(-g))) * u
            dense[t] += float(gates[t, j]) * (h @ wd[e])
    np.testing.assert_allclose(np.asarray(y).reshape(16, -1), dense,
                               atol=2e-2, rtol=2e-2)
