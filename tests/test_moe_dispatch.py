"""Expert-parallel MoE dispatch == local MoE (8 virtual devices)."""
import pytest

from tests.helpers.subproc import run_multidevice

BODY = """
import dataclasses
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch
from repro.models import moe as moe_lib
from repro.models.model import init_params

cfg = get_arch("deepseek-v2-236b").smoke
# ample capacity so dispatch and local see no drops; dispatch path on
cfg = dataclasses.replace(cfg, capacity_factor=16.0, moe_impl="dispatch")

params = init_params(cfg, jax.random.key(0))
lp = jax.tree.map(lambda a: a[0], params["moe_blocks"]["moe"])

rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)), jnp.float32)

y_local = moe_lib.moe_local(cfg, lp, x)

for mesh_shape, axes, dp, ep in [
    ((4, 2), ("data", "model"), ("data",), ("model",)),
    ((2, 2, 2), ("pod", "data", "model"), ("pod", "data"), ("model",)),
]:
    mesh = Mesh(np.array(jax.devices()).reshape(mesh_shape), axes)
    y_disp = moe_lib.moe_dispatch(cfg, lp, x, mesh, dp, ep)
    d = float(jnp.max(jnp.abs(y_local.astype(jnp.float32)
                              - y_disp.astype(jnp.float32))))
    assert d < 5e-4, (mesh_shape, d)
    print("mesh", mesh_shape, "max-diff", d)

# grid schedule over a 2-axis expert-parallel split
cfg2 = dataclasses.replace(cfg, moe_dispatch="grid")
mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
            ("data", "em", "en"))
y_grid = moe_lib.moe_dispatch(cfg2, lp, x, mesh, ("data",), ("em", "en"))
d = float(jnp.max(jnp.abs(y_local.astype(jnp.float32)
                          - y_grid.astype(jnp.float32))))
assert d < 5e-4, ("grid", d)
print("grid 2-axis EP max-diff", d)
print("OK")
"""


def test_moe_dispatch_matches_local():
    out = run_multidevice(BODY, ndev=8, timeout=600)
    assert "OK" in out
