"""Plan/execute split (ISSUE 5): RoundPlan structure + serialization
(in-process), and the planned executor's replay contract on 8 virtual
devices (subprocess) — bit-identity of the AOT-replayed plan against
the host-interleaved shrinking driver and the Kruskal oracle at
overflow 0, padded replay on a second same-shape graph, and the
never-silent replan fallback for undersized plans."""
import math

import numpy as np
import pytest

from repro.core.distributed import shrink_schedule
from repro.core.plan import GhostPlan, RoundPlan, RoundSpec, synthetic_plan
from tests.helpers.subproc import run_multidevice


def _toy_plan(ghost=True, levels=1, rounds_per_level=3):
    specs = tuple(
        RoundSpec(level=lvl, cap_edge=32 >> r, cap_lookup=16,
                  cap_contract=8, cap_relabel=64, cap_push=4,
                  ghost=ghost, sentinel=(r == rounds_per_level - 1))
        for lvl in range(levels) for r in range(rounds_per_level))
    bounds = [(-math.inf, math.inf)]
    if levels > 1:
        cuts = [float(i) for i in range(1, levels)]
        bounds = list(zip([-math.inf] + cuts, cuts + [math.inf]))
    return RoundPlan(
        n=512, num_shards=8, cap_per_shard=64, algorithm="boruvka",
        schedule="grid", local_preprocessing=True, coalesce=True,
        src_only=True, adaptive_doubling=True, relabel_skip=True,
        vsorted_index=True, cap_prep=64, edge_capacity_full=64,
        label_capacity_full=64, lookup_capacity_full=64,
        ghost=GhostPlan(40, 40, 16, 16, 32) if ghost else None,
        level_bounds=tuple(bounds), rounds=specs)


def test_plan_json_roundtrip():
    for plan in (_toy_plan(), _toy_plan(ghost=False),
                 _toy_plan(levels=3)):
        plan.validate()
        back = RoundPlan.from_json(plan.to_json())
        assert back == plan
        # ±inf weight windows survive strict JSON (encoded as strings)
        import json
        json.loads(plan.to_json())  # must be parseable standard JSON
    with pytest.raises(ValueError):
        RoundPlan.from_json('{"version": 7}')


def test_plan_validate_rejects_broken_plans():
    plan = _toy_plan(levels=2)
    # a level with zero rounds (e.g. hand-truncated JSON)
    with pytest.raises(ValueError, match="level"):
        plan._replace(rounds=tuple(r for r in plan.rounds
                                   if r.level == 0)).validate()
    with pytest.raises(ValueError, match="cap_edge"):
        plan._replace(rounds=(plan.rounds[0]._replace(cap_edge=0),)
                      + plan.rounds[1:]).validate()
    with pytest.raises(ValueError, match="grouped"):
        plan._replace(rounds=plan.rounds[::-1]).validate()


def test_plan_pad_monotone_on_ladder():
    plan = _toy_plan()
    padded = plan.pad(0.5)
    assert padded.num_rounds == plan.num_rounds
    assert padded.level_bounds == plan.level_bounds
    fulls = {"cap_edge": plan.edge_capacity_full,
             "cap_lookup": plan.lookup_capacity_full,
             "cap_contract": plan.label_capacity_full,
             "cap_relabel": plan.label_capacity_full,
             "cap_push": plan.label_capacity_full}
    for r0, r1 in zip(plan.rounds, padded.rounds):
        for f, full in fulls.items():
            a, b = getattr(r0, f), getattr(r1, f)
            # padding only grows, never past the flat full, and stays
            # on the shared ladder so compiled programs are reused
            assert a <= b <= full, (f, a, b)
            assert b in shrink_schedule(full), (f, b)
    assert plan.pad(0.0).ghost == plan.ghost
    with pytest.raises(ValueError):
        plan.pad(-0.1)


def test_synthetic_plan_structure():
    sp = synthetic_plan(1 << 12, 8 * 4096, 8)
    sp.validate()
    assert sp.num_rounds == math.ceil(math.log2(1 << 12)) + 1
    caps = [r.cap_edge for r in sp.rounds]
    assert caps[0] == 4096 and all(a >= b for a, b in zip(caps, caps[1:]))
    # durable like any measured plan
    assert RoundPlan.from_json(sp.to_json()) == sp


def test_make_sharded_mst_step_flat_fallback_is_loud():
    """ISSUE 5 satellite: the shrink_capacities caveat is enforced, not
    a docstring footnote — explicit True errors, the default warns."""
    import jax
    from jax.sharding import Mesh
    from repro.core.distributed_sharded import make_sharded_mst_step
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="plan"):
        make_sharded_mst_step(256, 512, mesh, shrink_capacities=True)
    with pytest.warns(UserWarning, match="flat-capacity"):
        make_sharded_mst_step(256, 512, mesh)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # explicit opt-out stays silent
        make_sharded_mst_step(256, 512, mesh, shrink_capacities=False)
    # a plan for the wrong shape is rejected up front
    with pytest.raises(ValueError, match="shape"):
        make_sharded_mst_step(256, 512, mesh, plan=_toy_plan())


PLAN_REPLAY = """
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (distributed_sharded_msf,
                                            execute_plan,
                                            make_sharded_mst_step,
                                            plan_sharded_msf)
from repro.core.plan import RoundPlan
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
sh = NamedSharding(mesh, P("data"))

# (1) the gnm/rgg2d equivalence matrix at overflow 0: serialize ->
# deserialize -> execute, strict mode (replan=False proves the plan
# genuinely fits), against both the host-driven shrinking driver and
# the Kruskal oracle, for both algorithms
for fam in ("gnm", "rgg2d"):
    u, v, w, n = generators.generate(fam, 512, avg_degree=8.0, seed=7)
    g, cap = build_dist_graph(u, v, w, n, p)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    ksel = np.nonzero(kmask)[0]
    for algo in ("boruvka", "filter_boruvka"):
        host = distributed_sharded_msf(g, n, mesh, algorithm=algo,
                                       axis_names=("data",))
        assert int(host[4]) == 0
        plan = plan_sharded_msf(g, n, mesh, algorithm=algo,
                                axis_names=("data",))
        plan = RoundPlan.from_json(plan.to_json())   # the durable form
        res = execute_plan(g, n, mesh, plan, replan=False)
        assert int(res[4]) == 0, (fam, algo, int(res[4]))
        assert np.array_equal(np.asarray(res[0]), np.asarray(host[0])), (
            fam, algo, "planned mask != host-driven mask")
        sel = np.unique(np.asarray(g.eid)[np.asarray(res[0])])
        assert np.array_equal(sel, ksel), (fam, algo, "!= oracle")
        assert abs(float(res[1]) - kweight) < 1e-3 * max(1.0, kweight)

# (2) AOT: the planned step lowers + compiles WHOLE (no host loop) and
# the compiled artifact's execution is bit-identical too
u, v, w, n = generators.generate("rgg2d", 512, avg_degree=8.0, seed=7)
g, cap = build_dist_graph(u, v, w, n, p)
host = distributed_sharded_msf(g, n, mesh, axis_names=("data",))
plan = plan_sharded_msf(g, n, mesh, axis_names=("data",))
step, specs = make_sharded_mst_step(n, g.cap_total, mesh, plan=plan)
compiled = jax.jit(step, in_shardings=(sh,) * 4).lower(*specs).compile()
out = compiled(g.u, g.v, g.w, g.eid)
assert len(out) == 6  # engine arity: residual folds into overflow
assert int(out[4]) == 0
assert np.array_equal(np.asarray(out[0]), np.asarray(host[0]))

# (3) replay on a SECOND same-shape graph (same structure, reshuffled
# weights -> different MSF, different merge trajectory): the padded
# plan must either fit (overflow 0) or replan — never a wrong result
kold = np.asarray(host[0])
rng = np.random.default_rng(1)
w2 = np.asarray(w).copy()
rng.shuffle(w2)
g2, _ = build_dist_graph(u, v, w2, n, p)
assert g2.cap_total == g.cap_total
k2, kw2 = oracle.kruskal(u, v, w2, n)
res2 = execute_plan(g2, n, mesh, plan.pad(0.5), replan=True)
assert int(res2[4]) == 0
sel2 = np.unique(np.asarray(g2.eid)[np.asarray(res2[0])])
assert np.array_equal(sel2, np.nonzero(k2)[0]), "replay != oracle"
assert abs(float(res2[1]) - kw2) < 1e-3 * max(1.0, kw2)

# (4) undersized plans are never silent: too few rounds -> residual
# flag -> strict mode raises, replan mode returns the exact result
short = plan._replace(rounds=plan.rounds[:2]).validate()
try:
    execute_plan(g, n, mesh, short, replan=False)
    raise AssertionError("undersized plan must raise in strict mode")
except RuntimeError as e:
    assert "residual" in str(e), e
res4 = execute_plan(g, n, mesh, short, replan=True)
assert int(res4[4]) == 0
assert np.array_equal(np.asarray(res4[0]), kold)

# ... and undersized capacities -> overflow -> same contract
tiny = plan._replace(rounds=tuple(r._replace(cap_edge=1)
                                  for r in plan.rounds))
try:
    execute_plan(g, n, mesh, tiny, replan=False)
    raise AssertionError("overflowing plan must raise in strict mode")
except RuntimeError as e:
    assert "overflow" in str(e), e
res5 = execute_plan(g, n, mesh, tiny, replan=True)
assert int(res5[4]) == 0
assert np.array_equal(np.asarray(res5[0]), kold)

# (5) the AOT path cannot replan: the residual signal must fold into
# the overflow output so a served step is never silently unreliable
sstep, sspecs = make_sharded_mst_step(n, g.cap_total, mesh, plan=short)
sout = jax.jit(sstep, in_shardings=(sh,) * 4)(g.u, g.v, g.w, g.eid)
assert int(sout[4]) > 0, "AOT residual must surface through overflow"
print("OK")
"""


def test_plan_replay_multidevice():
    out = run_multidevice(PLAN_REPLAY, ndev=8, timeout=1800)
    assert "OK" in out
