"""Unit tests for the sharded-label engine's internals (subprocess,
8 virtual devices): owner-routing round trip, shared-vertex root masks,
overflow accounting on undersized exchange capacities (including the
new smaller coalesced-lookup default), the comm counters that make the
ISSUE 2 optimizations measurable, and the ISSUE 3 additions — the
shrinking capacity schedule (bit-identity, decaying per-round
capacities, exact host bounds) and the bucketed O(edges/shard)
preprocessing (equivalence against the dense reference core, no [n]
transient in the compiled program)."""
import pytest

from repro.core.distributed import quantize_capacity, shrink_schedule
from tests.helpers.subproc import run_multidevice


def test_shrink_schedule_ladder():
    # geometric halving down to the floor, matching the engines' round
    # bound for full >= 2
    assert shrink_schedule(8) == (8, 4, 2, 1)
    assert shrink_schedule(7) == (7, 4, 2, 1)
    assert shrink_schedule(1) == (1,)
    assert shrink_schedule(5, floor=2) == (5, 3, 2)
    import math
    for full in (2, 3, 13, 64, 1000):
        assert len(shrink_schedule(full)) == math.ceil(math.log2(full)) + 1


def test_quantize_capacity_properties():
    for full in (1, 7, 512, 4096):
        for bound in (0, 1, 2, 3, full // 3 + 1, full, full + 5):
            q = quantize_capacity(bound, full)
            # never exceeds full (an explicit undersized user capacity
            # must stay undersized so overflow is *reported*) ...
            assert q <= max(full, 1), (bound, full, q)
            # ... and covers the bound whenever the ladder can
            if bound <= full:
                assert q >= max(bound, 1), (bound, full, q)
            # rungs come from the shared ladder
            assert q in shrink_schedule(full), (bound, full, q)

LOOKUP_ROUNDTRIP = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.distributed_sharded import _sharded_lookup

p, vps, L = 8, 16, 96
mesh = Mesh(np.array(jax.devices()), ("data",))
# global table[vid] = 7 * vid + 3, 1D-sharded by vid
table = (7 * np.arange(p * vps, dtype=np.int32) + 3)
rng = np.random.default_rng(0)
vids = rng.integers(0, p * vps, (p * L,)).astype(np.int32)
valid = rng.random(p * L) < 0.9

def body(tab, vq, va):
    out, ok, ovf = _sharded_lookup(tab, vq, va, vps, L, ("data",))
    return out, ok, ovf

f = shard_map(body, mesh=mesh,
              in_specs=(P("data"), P("data"), P("data")),
              out_specs=(P("data"), P("data"), P()))
out, ok, ovf = f(jnp.asarray(table), jnp.asarray(vids), jnp.asarray(valid))
out, ok = np.asarray(out), np.asarray(ok)
# capacity == L can never overflow; every valid request is answered with
# the owner's value, i.e. the round trip is the identity on the table
assert int(ovf) == 0, int(ovf)
assert np.array_equal(ok, valid)
assert np.array_equal(out[valid], table[vids[valid]])
print("OK")
"""


ROOT_MASK = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.distributed import build_dist_graph, _shared_vertex_root_mask
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("grid2d", 1024, seed=2)
g, cap = build_dist_graph(u, v, w, n, p)

def body(uu, ww):
    valid = jnp.isfinite(ww)
    mask, firsts, lasts = _shared_vertex_root_mask(uu, valid, n, ("data",))
    return mask, firsts, lasts

f = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
              out_specs=(P(), P(), P()))
mask, firsts, lasts = f(g.u, g.w)
mask = np.asarray(mask)

# host-side expectation: the sorted directed edge list is cut into p
# contiguous slices; a vertex is shared iff its edge run straddles a
# shard boundary, i.e. shard s's last source == shard s+1's first source
gu = np.asarray(g.u); gw = np.asarray(g.w)
expect = np.zeros(n, bool)
bounds = []
for s in range(p):
    sl = slice(s * cap, (s + 1) * cap)
    vv = np.isfinite(gw[sl])
    if vv.any():
        bounds.append((gu[sl][vv][0], gu[sl][vv][-1]))
    else:
        bounds.append((-1, -2))
for s in range(p - 1):
    if bounds[s][1] == bounds[s + 1][0] and bounds[s][1] >= 0:
        expect[bounds[s][1]] = True
assert np.array_equal(mask, expect), (np.nonzero(mask)[0],
                                      np.nonzero(expect)[0])
# a 64x64 grid over 8 shards must actually have shared vertices
assert expect.sum() > 0
print("OK")
"""


OVERFLOW = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (_sharded_lookup,
                                            distributed_sharded_msf)
from repro.core import oracle
from repro.data import generators

p, vps, L = 8, 16, 24
mesh = Mesh(np.array(jax.devices()), ("data",))

# (1) primitive level: every shard fires L valid requests at vertex 0's
# owner with capacity 1 -> exactly L-1 drops per shard, all reported
table = np.arange(p * vps, dtype=np.int32)
vids = np.zeros(p * L, np.int32)

def body(tab, vq):
    va = jnp.ones(vq.shape, bool)
    out, ok, ovf = _sharded_lookup(tab, vq, va, vps, 1, ("data",))
    return out, ok, ovf

f = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
              out_specs=(P("data"), P("data"), P()))
out, ok, ovf = f(jnp.asarray(table), jnp.asarray(vids))
assert int(ovf) == p * (L - 1), (int(ovf), p * (L - 1))
ok = np.asarray(ok)
assert ok.sum() == p  # one winner per source shard
assert np.all(np.asarray(out)[ok] == 0)

# (2) engine level: undersized edge_capacity must be *reported*, never
# silently produce a confident wrong answer
u, v, w, n = generators.generate("gnm", 256, avg_degree=8.0, seed=5)
g, cap = build_dist_graph(u, v, w, n, p)
mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
    g, n, mesh, axis_names=("data",), edge_capacity=1)
assert int(ovf) > 0, "undersized capacity must report overflow"

# (3) default capacities on the same graph: exact, zero overflow — and
# the coalesced lookup default capacity is genuinely smaller than the
# full edges/shard buffer of PR 1 while staying overflow-free
from repro.core.distributed_sharded import default_lookup_capacity
lk = default_lookup_capacity(g, p, n)
assert lk < cap, (lk, cap)
mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
    g, n, mesh, axis_names=("data",))
_, expect = oracle.kruskal(u, v, w, n)
assert int(ovf) == 0
assert abs(float(wt) - expect) < 1e-3 * max(1.0, expect)

# (4) an undersized *lookup* capacity must also be reported, not silent
mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
    g, n, mesh, axis_names=("data",), lookup_capacity=1)
assert int(ovf) > 0, "undersized lookup capacity must report overflow"
print("OK")
"""


COMM_COUNTERS = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import distributed_sharded_msf
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("rgg2d", 512, avg_degree=8.0, seed=7)
g, cap = build_dist_graph(u, v, w, n, p)
kmask, kweight = oracle.kruskal(u, v, w, n)
ksel = np.nonzero(kmask)[0]

recs = {}
for name, flags in (
    ("baseline", dict(local_preprocessing=False, coalesce=False,
                      src_only=False, adaptive_doubling=False)),
    ("optimized", {}),
):
    mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
        g, n, mesh, axis_names=("data",), **flags)
    # every variant stays exact at overflow 0 ...
    assert int(ovf) == 0, (name, int(ovf))
    sel = np.unique(np.asarray(g.eid)[np.asarray(mask)])
    assert np.array_equal(sel, ksel), (name, "edge set differs from oracle")
    recs[name] = (int(st.calls), float(st.items), float(st.bytes),
                  int(st.rounds))
    assert recs[name][3] > 0

# ... and the optimization flags must strictly cut both a2a invocations
# and routed item volume (the honest metric; 2x/4x floors are asserted
# at benchmark scale by benchmarks/sharded_scaling.py --smoke in CI)
base, opt = recs["baseline"], recs["optimized"]
assert opt[0] < base[0], (base, opt)
assert opt[1] < base[1], (base, opt)
print("OK")
"""


SHRINKING = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (distributed_sharded_msf,
                                            minedges_buffer_bytes)
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
for fam in ("gnm", "rgg2d"):
    u, v, w, n = generators.generate(fam, 512, avg_degree=8.0, seed=7)
    g, cap = build_dist_graph(u, v, w, n, p)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    ksel = np.nonzero(kmask)[0]
    flat = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                                   shrink_capacities=False)
    trace = []
    shr = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                                  shrink_capacities=True,
                                  round_trace=trace)
    for name, res in (("flat", flat), ("shrink", shr)):
        assert int(res[4]) == 0, (fam, name, int(res[4]))
        sel = np.unique(np.asarray(g.eid)[np.asarray(res[0])])
        assert np.array_equal(sel, ksel), (fam, name, "edge set != oracle")
    # bit-identical slot masks, weights, counts between the two paths
    assert np.array_equal(np.asarray(flat[0]), np.asarray(shr[0])), fam
    assert abs(float(flat[1]) - float(shr[1])) < 1e-3 * max(
        1.0, float(flat[1]))
    assert int(flat[2]) == int(shr[2])
    # the schedule must be populated, below the flat worst case, and
    # must cut the capacity-padded buffer bytes (the honest metric)
    caps = [t["cap_edge"] for t in trace]
    assert caps and len(caps) == int(shr[5].rounds), (fam, caps)
    assert max(caps) < cap, (fam, caps, cap)
    assert float(shr[5].bytes) < float(flat[5].bytes), fam
    # trace bookkeeping matches the engine totals
    assert sum(t["a2a_calls"] for t in trace) <= int(shr[5].calls)
    assert sum(t["minedges_buffer_bytes"] for t in trace) < \
        int(shr[5].rounds) * minedges_buffer_bytes(p, cap, 1, True), fam

# undersized explicit capacities must still *report* under the schedule
u, v, w, n = generators.generate("gnm", 256, avg_degree=8.0, seed=5)
g, cap = build_dist_graph(u, v, w, n, p)
res = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                              edge_capacity=1, shrink_capacities=True)
assert int(res[4]) > 0, "undersized edge capacity must report overflow"
res = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                              lookup_capacity=1, shrink_capacities=True)
assert int(res[4]) > 0, "undersized lookup capacity must report overflow"
print("OK")
"""


PREPROCESS_BUCKETED = """
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from repro.comm.exchange import ExchangeStats
from repro.core.distributed import build_dist_graph, _local_preprocessing_core
from repro.core.distributed_sharded import (_sharded_preprocess,
                                            vertices_per_shard)
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
# rgg2d: high locality => real contraction happens; grid2d: shared
# boundary vertices on nearly every shard edge
for fam in ("rgg2d", "grid2d"):
    u, v, w, n = generators.generate(fam, 1024, avg_degree=8.0, seed=2)
    g, cap = build_dist_graph(u, v, w, n, p)
    vps = vertices_per_shard(n, p)

    def bucketed(uu, vv, ww, ee):
        valid = jnp.isfinite(ww)
        lab, pre, dead0, ovf, st = _sharded_preprocess(
            uu, vv, ww, ee, valid, n, vps, vps, ("data",), "grid",
            ExchangeStats.zeros())
        return lab, pre, dead0, ovf

    fb = shard_map(bucketed, mesh=mesh,
                   in_specs=(P("data"),) * 4,
                   out_specs=(P("data"), P("data"), P("data"), P()))
    lab_b, pre_b, dead_b, ovf = fb(g.u, g.v, g.w, g.eid)
    assert int(ovf) == 0

    # dense reference: the replicated engine's per-shard contribution
    # core, combined on the host exactly like _local_preprocessing's
    # psum (each vertex is contracted on at most one shard)
    def dense(uu, ww, ee, vv):
        valid = jnp.isfinite(ww)
        labs, mst = _local_preprocessing_core(uu, vv, ww, ee, valid, n,
                                              ("data",))
        return labs, mst

    fd = shard_map(dense, mesh=mesh, in_specs=(P("data"),) * 4,
                   out_specs=(P("data"), P("data")))
    labs_all, pre_d = fd(g.u, g.w, g.eid, g.v)
    labs_all = np.asarray(labs_all).reshape(p, n)
    iota = np.arange(n)
    comb = iota.copy()
    for s in range(p):
        ch = labs_all[s] != iota
        comb[ch] = labs_all[s][ch]
    # identical contracted slots ...
    assert np.array_equal(np.asarray(pre_b), np.asarray(pre_d)), fam
    # ... identical owner-side label vector ...
    lab_ref = np.arange(p * vps)
    lab_ref[:n] = comb
    assert np.array_equal(np.asarray(lab_b), lab_ref), fam
    # ... identical initial dead mask (locally-internal edges)
    uh, vh = np.asarray(g.u), np.asarray(g.v)
    dead_ref = comb[uh] == comb[vh]
    assert np.array_equal(np.asarray(dead_b), dead_ref), fam
print("OK")
"""


PREPROCESS_PEAK_MEMORY = """
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (_build_sharded_prep_fn,
                                            vertices_per_shard)
from repro.data import generators

# tiny edge set over a HUGE vertex-id space: the bucketed preprocessing
# must compile to O(edges/shard + n/p) per-device temps, not O(n) — the
# dense [n] scratch of the PR 2 version would show up as ~4n temp bytes
p = 8
n = 1 << 20
mesh = Mesh(np.array(jax.devices()), ("data",))
rng = np.random.default_rng(0)
m = 512
u = rng.integers(0, n, m).astype(np.int32)
v = rng.integers(0, n, m).astype(np.int32)
keep = u != v
w = rng.uniform(1.0, 9.0, keep.sum()).astype(np.float32)
g, cap = build_dist_graph(u[keep], v[keep], w, n, p)
vps = vertices_per_shard(n, p)
prep = _build_sharded_prep_fn(n, vps, mesh, ("data",), vps, "grid")
specs = [jax.ShapeDtypeStruct((g.cap_total,), d)
         for d in (jnp.int32, jnp.int32, jnp.float32, jnp.int32)]
compiled = prep.lower(*specs).compile()
try:
    temp = compiled.memory_analysis().temp_size_in_bytes
except Exception as e:  # backend without memory analysis: inconclusive
    print("SKIP memory_analysis:", e)
    print("OK")
else:
    # per-device budget: the carried [vps] label slice + [p, vps] label
    # exchange buffers + O(cap) run-rank scratch; a dense [n] transient
    # alone would cost 4n = 4 MiB per device
    budget = p * (60 * cap + 40 * vps + 8 * p * vps)
    assert temp < budget, (temp, budget)
    assert temp < 4 * n, (temp, 4 * n)  # the smoking gun: sub-[n] temps
    print("temp_bytes", temp, "budget", budget)
    print("OK")
"""


GHOST_CACHE = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import distributed_sharded_msf
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("rgg2d", 512, avg_degree=8.0, seed=7)
g, cap = build_dist_graph(u, v, w, n, p)
kmask, kweight = oracle.kruskal(u, v, w, n)
ksel = np.nonzero(kmask)[0]

def check(res, ctx):
    assert int(res[4]) == 0, (ctx, int(res[4]))
    sel = np.unique(np.asarray(g.eid)[np.asarray(res[0])])
    assert np.array_equal(sel, ksel), (ctx, "edge set differs from oracle")

# (1) ghost on vs off: bit-identical results, and the cache must
# actually work — hits and pushes > 0, routed endpoint-lookup items
# (misses + pushed) strictly below the coalesced-only run's misses
trace = []
gres = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                               round_trace=trace)
cres = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                               ghost_cache=False)
check(gres, "ghost")
check(cres, "coalesce")
assert np.array_equal(np.asarray(gres[0]), np.asarray(cres[0]))
gst, cst = gres[5], cres[5]
assert float(gst.hits) > 0 and float(gst.pushed) > 0, (
    float(gst.hits), float(gst.pushed))
assert float(cst.hits) == 0 and float(cst.pushed) == 0
g_lookup = float(gst.misses) + float(gst.pushed)
assert g_lookup < float(cst.misses), (g_lookup, float(cst.misses))

# (2) per-round trace carries the ghost columns; the dirty push decays
# with the alive-component count
assert all("cache_hits" in t and "pushed_items" in t and "cap_push" in t
           for t in trace), trace[0].keys()
assert all(t["ghost"] for t in trace)
pushes = [t["pushed_items"] for t in trace]
assert pushes[-1] < pushes[0], pushes

# (2b) settled-vertex skip satellite: on a graph where most components
# finish early the host bound drops the RELABEL capacity below vps.
# A 10-vertex path strided across the id space (~1 vertex per shard)
# keeps the solve alive; every other vertex pairs into a single-edge
# component whose members settle right after round 1 (their component
# chose nothing), so round 2's unsettled set is ~1 vertex per shard.
# (On a giant-component graph like rgg2d nothing settles until the
# end, so the capacity legitimately stays at vps there.)
ns = 212
path_ids = np.arange(10, dtype=np.int32) * 21
rest = np.setdiff1d(np.arange(ns, dtype=np.int32), path_ids)
m2 = len(rest) // 2 * 2
su = np.concatenate([path_ids[:-1], rest[:m2:2]]).astype(np.int32)
sv = np.concatenate([path_ids[1:], rest[1:m2:2]]).astype(np.int32)
rng = np.random.default_rng(0)
sw = rng.uniform(1, 9, len(su)).astype(np.float32)
gs, _ = build_dist_graph(su, sv, sw, ns, p)
strace = []
sres = distributed_sharded_msf(gs, ns, mesh, axis_names=("data",),
                               round_trace=strace)
assert int(sres[4]) == 0
skmask, _ = oracle.kruskal(su, sv, sw, ns)
ssel = np.unique(np.asarray(gs.eid)[np.asarray(sres[0])])
assert np.array_equal(ssel, np.nonzero(skmask)[0])
svps = -(-ns // p)
caps_rel = [t["cap_relabel"] for t in strace]
assert len(caps_rel) >= 2 and caps_rel[-1] < svps, caps_rel

# (3) fused engine, push pinned to 1: overflow is REPORTED, not silent
res = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                              shrink_capacities=False, push_capacity=1)
assert int(res[4]) > 0, "undersized push capacity must report overflow"

# (4) shrinking driver, push pinned to 1: graceful exact fallback —
# the driver abandons the cache instead of risking stale ghosts, so the
# result stays exact at overflow 0 and the trace shows the switch
trace = []
res = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                              push_capacity=1, round_trace=trace)
check(res, "fallback")
assert np.array_equal(np.asarray(res[0]), np.asarray(cres[0]))
assert not any(t["ghost"] for t in trace), [t["ghost"] for t in trace]

# (5) undersized lookup capacity also starves the ghost *fills*:
# reported through the same overflow contract
res = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                              shrink_capacities=False, lookup_capacity=1)
assert int(res[4]) > 0, "undersized fill capacity must report overflow"
print("OK")
"""


GHOST_LIMIT = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import distributed_sharded_msf
from repro.data import generators

# ISSUE 5 satellite: the scatter_updates subscriber bitmask caps the
# ghost cache at MAX_GHOST_SHARDS = 31; beyond that the engine must
# auto-fall back to coalesced lookups.  32 virtual devices are too
# heavy for CI, so the forced-width knob `ghost_shard_limit` simulates
# the p > limit condition on the 8-device mesh: with limit=4 (< p=8)
# the engine must behave exactly like ghost_cache=False — same exact
# result, zero ghost counters — on both the shrinking driver and the
# fused path.  (The bit arithmetic of the mask itself is unit-tested
# to width 31 in tests/test_comm.py.)
p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("rgg2d", 512, avg_degree=8.0, seed=7)
g, cap = build_dist_graph(u, v, w, n, p)
kmask, _ = oracle.kruskal(u, v, w, n)
ksel = np.nonzero(kmask)[0]

for flags in (dict(), dict(shrink_capacities=False)):
    ref = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                                  ghost_cache=False, **flags)
    lim = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                                  ghost_shard_limit=4, **flags)
    for name, res in (("no_ghost", ref), ("limited", lim)):
        assert int(res[4]) == 0, (flags, name, int(res[4]))
        sel = np.unique(np.asarray(g.eid)[np.asarray(res[0])])
        assert np.array_equal(sel, ksel), (flags, name, "!= oracle")
    assert np.array_equal(np.asarray(lim[0]), np.asarray(ref[0])), flags
    # the fallback genuinely disabled the cache: no hits, no pushes,
    # and the routed lookup volume matches the coalesced engine's
    assert float(lim[5].hits) == 0 and float(lim[5].pushed) == 0, flags
    assert float(lim[5].misses) == float(ref[5].misses), flags
# a limit at/above p leaves the cache on
on = distributed_sharded_msf(g, n, mesh, axis_names=("data",),
                             ghost_shard_limit=8)
assert float(on[5].hits) > 0
print("OK")
"""


@pytest.mark.parametrize("name,script", [
    ("lookup_roundtrip", LOOKUP_ROUNDTRIP),
    ("root_mask", ROOT_MASK),
    ("overflow", OVERFLOW),
    ("comm_counters", COMM_COUNTERS),
    ("shrinking_schedule", SHRINKING),
    ("preprocess_bucketed", PREPROCESS_BUCKETED),
    ("preprocess_peak_memory", PREPROCESS_PEAK_MEMORY),
    ("ghost_cache", GHOST_CACHE),
    ("ghost_limit_fallback", GHOST_LIMIT)])
def test_sharded_internals(name, script):
    out = run_multidevice(script, ndev=8, timeout=900)
    assert "OK" in out
