"""Unit tests for the sharded-label engine's internals (subprocess,
8 virtual devices): owner-routing round trip, shared-vertex root masks,
overflow accounting on undersized exchange capacities (including the
new smaller coalesced-lookup default), and the comm counters that make
the ISSUE 2 optimizations measurable."""
import pytest

from tests.helpers.subproc import run_multidevice

LOOKUP_ROUNDTRIP = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.distributed_sharded import _sharded_lookup

p, vps, L = 8, 16, 96
mesh = Mesh(np.array(jax.devices()), ("data",))
# global table[vid] = 7 * vid + 3, 1D-sharded by vid
table = (7 * np.arange(p * vps, dtype=np.int32) + 3)
rng = np.random.default_rng(0)
vids = rng.integers(0, p * vps, (p * L,)).astype(np.int32)
valid = rng.random(p * L) < 0.9

def body(tab, vq, va):
    out, ok, ovf = _sharded_lookup(tab, vq, va, vps, L, ("data",))
    return out, ok, ovf

f = shard_map(body, mesh=mesh,
              in_specs=(P("data"), P("data"), P("data")),
              out_specs=(P("data"), P("data"), P()))
out, ok, ovf = f(jnp.asarray(table), jnp.asarray(vids), jnp.asarray(valid))
out, ok = np.asarray(out), np.asarray(ok)
# capacity == L can never overflow; every valid request is answered with
# the owner's value, i.e. the round trip is the identity on the table
assert int(ovf) == 0, int(ovf)
assert np.array_equal(ok, valid)
assert np.array_equal(out[valid], table[vids[valid]])
print("OK")
"""


ROOT_MASK = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.distributed import build_dist_graph, _shared_vertex_root_mask
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("grid2d", 1024, seed=2)
g, cap = build_dist_graph(u, v, w, n, p)

def body(uu, ww):
    valid = jnp.isfinite(ww)
    mask, firsts, lasts = _shared_vertex_root_mask(uu, valid, n, ("data",))
    return mask, firsts, lasts

f = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
              out_specs=(P(), P(), P()))
mask, firsts, lasts = f(g.u, g.w)
mask = np.asarray(mask)

# host-side expectation: the sorted directed edge list is cut into p
# contiguous slices; a vertex is shared iff its edge run straddles a
# shard boundary, i.e. shard s's last source == shard s+1's first source
gu = np.asarray(g.u); gw = np.asarray(g.w)
expect = np.zeros(n, bool)
bounds = []
for s in range(p):
    sl = slice(s * cap, (s + 1) * cap)
    vv = np.isfinite(gw[sl])
    if vv.any():
        bounds.append((gu[sl][vv][0], gu[sl][vv][-1]))
    else:
        bounds.append((-1, -2))
for s in range(p - 1):
    if bounds[s][1] == bounds[s + 1][0] and bounds[s][1] >= 0:
        expect[bounds[s][1]] = True
assert np.array_equal(mask, expect), (np.nonzero(mask)[0],
                                      np.nonzero(expect)[0])
# a 64x64 grid over 8 shards must actually have shared vertices
assert expect.sum() > 0
print("OK")
"""


OVERFLOW = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (_sharded_lookup,
                                            distributed_sharded_msf)
from repro.core import oracle
from repro.data import generators

p, vps, L = 8, 16, 24
mesh = Mesh(np.array(jax.devices()), ("data",))

# (1) primitive level: every shard fires L valid requests at vertex 0's
# owner with capacity 1 -> exactly L-1 drops per shard, all reported
table = np.arange(p * vps, dtype=np.int32)
vids = np.zeros(p * L, np.int32)

def body(tab, vq):
    va = jnp.ones(vq.shape, bool)
    out, ok, ovf = _sharded_lookup(tab, vq, va, vps, 1, ("data",))
    return out, ok, ovf

f = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
              out_specs=(P("data"), P("data"), P()))
out, ok, ovf = f(jnp.asarray(table), jnp.asarray(vids))
assert int(ovf) == p * (L - 1), (int(ovf), p * (L - 1))
ok = np.asarray(ok)
assert ok.sum() == p  # one winner per source shard
assert np.all(np.asarray(out)[ok] == 0)

# (2) engine level: undersized edge_capacity must be *reported*, never
# silently produce a confident wrong answer
u, v, w, n = generators.generate("gnm", 256, avg_degree=8.0, seed=5)
g, cap = build_dist_graph(u, v, w, n, p)
mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
    g, n, mesh, axis_names=("data",), edge_capacity=1)
assert int(ovf) > 0, "undersized capacity must report overflow"

# (3) default capacities on the same graph: exact, zero overflow — and
# the coalesced lookup default capacity is genuinely smaller than the
# full edges/shard buffer of PR 1 while staying overflow-free
from repro.core.distributed_sharded import default_lookup_capacity
lk = default_lookup_capacity(g, p, n)
assert lk < cap, (lk, cap)
mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
    g, n, mesh, axis_names=("data",))
_, expect = oracle.kruskal(u, v, w, n)
assert int(ovf) == 0
assert abs(float(wt) - expect) < 1e-3 * max(1.0, expect)

# (4) an undersized *lookup* capacity must also be reported, not silent
mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
    g, n, mesh, axis_names=("data",), lookup_capacity=1)
assert int(ovf) > 0, "undersized lookup capacity must report overflow"
print("OK")
"""


COMM_COUNTERS = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import distributed_sharded_msf
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("rgg2d", 512, avg_degree=8.0, seed=7)
g, cap = build_dist_graph(u, v, w, n, p)
kmask, kweight = oracle.kruskal(u, v, w, n)
ksel = np.nonzero(kmask)[0]

recs = {}
for name, flags in (
    ("baseline", dict(local_preprocessing=False, coalesce=False,
                      src_only=False, adaptive_doubling=False)),
    ("optimized", {}),
):
    mask, wt, cnt, lab, ovf, st = distributed_sharded_msf(
        g, n, mesh, axis_names=("data",), **flags)
    # every variant stays exact at overflow 0 ...
    assert int(ovf) == 0, (name, int(ovf))
    sel = np.unique(np.asarray(g.eid)[np.asarray(mask)])
    assert np.array_equal(sel, ksel), (name, "edge set differs from oracle")
    recs[name] = (int(st.calls), float(st.items), float(st.bytes),
                  int(st.rounds))
    assert recs[name][3] > 0

# ... and the optimization flags must strictly cut both a2a invocations
# and routed item volume (the honest metric; 2x/4x floors are asserted
# at benchmark scale by benchmarks/sharded_scaling.py --smoke in CI)
base, opt = recs["baseline"], recs["optimized"]
assert opt[0] < base[0], (base, opt)
assert opt[1] < base[1], (base, opt)
print("OK")
"""


@pytest.mark.parametrize("name,script", [
    ("lookup_roundtrip", LOOKUP_ROUNDTRIP),
    ("root_mask", ROOT_MASK),
    ("overflow", OVERFLOW),
    ("comm_counters", COMM_COUNTERS)])
def test_sharded_internals(name, script):
    out = run_multidevice(script, ndev=8, timeout=900)
    assert "OK" in out
