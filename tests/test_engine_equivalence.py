"""Cross-engine oracle matrix: every MSF engine must produce the *unique*
(w, eid)-order MSF of the Kruskal oracle — same weight, same edge set.

Engines: static boruvka / filter_boruvka, dynamic boruvka /
filter_boruvka (in-process), distributed (replicated labels) and
distributed_sharded (1D-sharded labels + routed exchange) on 8 virtual
devices through the public ``minimum_spanning_forest`` dispatch
(subprocess; main process keeps 1 device).

Graph families (tests/helpers/graph_families.py, shared verbatim with
the subprocess): uniform random, clustered (RMAT), duplicate weights
(heavy ties — exercises the eid tie-break), disconnected (forest, not
tree), and self-loops lighter than every real edge (must never be
chosen).  Randomised over seeds; a hypothesis fuzz pass runs on top
when hypothesis is installed.
"""
import inspect

import numpy as np
import pytest

from repro.core import oracle
from repro.core.boruvka import boruvka_msf
from repro.core.filter_boruvka import (boruvka_dynamic,
                                       filter_boruvka_dynamic,
                                       filter_boruvka_msf)
from tests.helpers import graph_families
from tests.helpers.graph_families import FAMILIES
from tests.helpers.hypothesis_compat import given, settings, st
from tests.helpers.subproc import run_multidevice


ENGINES = {
    "boruvka_msf": lambda u, v, w, n: boruvka_msf(u, v, w, n)[0],
    "filter_boruvka_msf":
        lambda u, v, w, n: filter_boruvka_msf(u, v, w, n, num_buckets=4)[0],
    "boruvka_dynamic": lambda u, v, w, n: boruvka_dynamic(u, v, w, n)[0],
    "filter_boruvka_dynamic":
        lambda u, v, w, n: filter_boruvka_dynamic(u, v, w, n)[0],
}


def _assert_matches_oracle(mask, u, v, w, n, ctx):
    kmask, kweight = oracle.kruskal(u, v, w, n)
    mask = np.asarray(mask)
    assert np.array_equal(np.nonzero(mask)[0], np.nonzero(kmask)[0]), (
        ctx, "edge set differs from the (w, eid) oracle MSF")
    got = float(np.sum(w[mask]))
    assert abs(got - kweight) < 1e-3 * max(1.0, kweight), (ctx, got, kweight)


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("engine", sorted(ENGINES))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_local_engines_match_oracle(family, engine, seed):
    u, v, w, n = FAMILIES[family](seed)
    mask = ENGINES[engine](u, v, w, n)
    _assert_matches_oracle(mask, u, v, w, n, (family, engine, seed))


# --------------------------------------------------------------------------
# distributed engines (8 virtual devices >= 4 shards, subprocess)
# --------------------------------------------------------------------------

# the exact same family builders, injected as source so the two matrices
# cannot drift apart
DISTRIBUTED = inspect.getsource(graph_families) + """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.graph import from_numpy
from repro.core.mst import minimum_spanning_forest

mesh = Mesh(np.array(jax.devices()), ("data",))

for fam, make in sorted(FAMILIES.items()):
    u, v, w, n = make(0)
    edges = from_numpy(u, v, w, n)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    for engine in ("distributed", "distributed_sharded"):
        for algo in ("boruvka", "filter_boruvka"):
            mask, wt = minimum_spanning_forest(
                edges, algorithm=algo, engine=engine, mesh=mesh)
            mk = np.asarray(mask)
            assert np.array_equal(np.nonzero(mk)[0], np.nonzero(kmask)[0]), (
                fam, engine, algo, "edge set differs from oracle")
            assert abs(float(wt) - kweight) < 1e-3 * max(1.0, kweight), (
                fam, engine, algo, float(wt), kweight)
print("OK")
"""


def test_distributed_engines_match_oracle():
    out = run_multidevice(DISTRIBUTED, ndev=8, timeout=1800)
    assert "OK" in out


# the sharded engine's ISSUE 2 communication levers, each toggled alone
# plus all together, must keep the MSF edge set bit-identical to the
# oracle on the adversarial families (heavy ties exercise the (w, eid)
# tie-break through the src-only owner-side marking; disconnected
# exercises the dead-edge retirement's termination)
SHARDED_FLAGS = inspect.getsource(graph_families) + """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.graph import from_numpy
from repro.core.mst import minimum_spanning_forest

mesh = Mesh(np.array(jax.devices()), ("data",))
OFF = dict(local_preprocessing=False, coalesce=False, src_only=False,
           adaptive_doubling=False, shrink_capacities=False,
           ghost_cache=False, relabel_skip=False)
COMBOS = [
    dict(OFF),                                           # the PR 1 baseline
    dict(OFF, local_preprocessing=True),
    dict(OFF, coalesce=True),            # incl. the v-sorted index
    dict(OFF, coalesce=True, vsorted_index=False),  # PR 3 slot-order v
    dict(OFF, src_only=True),
    dict(OFF, adaptive_doubling=True),
    dict(OFF, shrink_capacities=True),   # shrinking schedule alone
    dict(OFF, relabel_skip=True),        # settled-vertex RELABEL skip
    # the ISSUE 4 ghost_cache x coalesce x shrink_capacities sub-matrix
    # (the cache replaces the endpoint lookups, so each pairing takes a
    # genuinely different code path through _round_body)
    dict(OFF, ghost_cache=True),
    dict(OFF, ghost_cache=True, coalesce=True),
    dict(OFF, ghost_cache=True, shrink_capacities=True),
    dict(OFF, ghost_cache=True, coalesce=True, shrink_capacities=True),
    dict(ghost_cache=False, vsorted_index=False),  # the PR 3 optimized
    dict(ghost_cache=False),             # all levers minus the cache
    dict(shrink_capacities=False),       # all levers, flat capacities
    dict(),                              # everything incl. the schedule
    # the ISSUE 8 pallas_minedges lever: the fused kernel must be
    # bit-identical through every MINEDGES code path — the 2-exchange
    # baseline, the src-only per-run combine, ghost/vsorted reads, the
    # shrinking schedule, and the all-on engine
    dict(OFF, pallas_minedges=True),                     # 2-exchange kernel
    dict(OFF, src_only=True, pallas_minedges=True),      # fused combine
    dict(OFF, ghost_cache=True, coalesce=True, pallas_minedges=True),
    dict(shrink_capacities=False, pallas_minedges=True),  # flat + kernel
    dict(ghost_cache=False, vsorted_index=False, pallas_minedges=True),
    dict(pallas_minedges=True),          # everything through the kernel
]

for fam in ("random", "clustered", "dup_weights", "disconnected"):
    u, v, w, n = FAMILIES[fam](0)
    edges = from_numpy(u, v, w, n)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    for combo in COMBOS:
        mask, wt = minimum_spanning_forest(
            edges, algorithm="boruvka", engine="distributed_sharded",
            mesh=mesh, **combo)
        mk = np.asarray(mask)
        assert np.array_equal(np.nonzero(mk)[0], np.nonzero(kmask)[0]), (
            fam, combo, "edge set differs from oracle")
        assert abs(float(wt) - kweight) < 1e-3 * max(1.0, kweight), (
            fam, combo, float(wt), kweight)
print("OK")
"""


def test_sharded_optimization_flags_match_oracle():
    out = run_multidevice(SHARDED_FLAGS, ndev=8, timeout=1800)
    assert "OK" in out


# plan measured with the kernel lever, replayed strictly (replan=False)
# through the Python-unrolled executor with the ISSUE 7 self-verifier on:
# pins (a) the lever survives the RoundPlan round-trip, (b) replay is
# bit-identical to the oracle through the kernel path, (c) verify=True
# accepts the kernel-path forest
SHARDED_PALLAS_PLAN = inspect.getsource(graph_families) + """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (execute_plan,
                                            plan_sharded_msf)
from repro.core.plan import RoundPlan

mesh = Mesh(np.array(jax.devices()), ("data",))
for fam in ("dup_weights", "disconnected"):
    u, v, w, n = FAMILIES[fam](0)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    g, cap = build_dist_graph(u, v, w, n, 8)
    plan = plan_sharded_msf(g, n, mesh, pallas_minedges=True)
    assert plan.pallas_minedges
    plan = RoundPlan.from_json(plan.to_json())  # lever round-trips
    assert plan.pallas_minedges
    mask, wt, cnt, lab, ovf, comm = execute_plan(
        g, n, mesh, plan, replan=False, verify=True)
    assert int(ovf) == 0, (fam, int(ovf))
    got = sorted(set(int(e) for e in np.asarray(g.eid)[np.asarray(mask)]))
    assert got == sorted(np.nonzero(kmask)[0].tolist()), (
        fam, "edge set differs from oracle through the kernel plan path")
    assert abs(float(wt) - kweight) < 1e-3 * max(1.0, kweight)
print("OK")
"""


def test_sharded_pallas_plan_replay_verified():
    out = run_multidevice(SHARDED_PALLAS_PLAN, ndev=8, timeout=1800)
    assert "OK" in out


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_property_random_graphs_match_oracle(data):
    n = data.draw(st.integers(2, 40), label="n")
    m = data.draw(st.integers(0, 120), label="m")
    seed = data.draw(st.integers(0, 2 ** 31 - 1), label="seed")
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    # intentionally keep self-loops and parallel edges
    w = rng.integers(1, 8, m).astype(np.float32)
    for engine, fn in sorted(ENGINES.items()):
        mask = fn(u, v, w, n)
        _assert_matches_oracle(mask, u, v, w, n, (engine, n, m, seed))
