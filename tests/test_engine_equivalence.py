"""Cross-engine oracle matrix: every MSF engine must produce the *unique*
(w, eid)-order MSF of the Kruskal oracle — same weight, same edge set.

Engines: static boruvka / filter_boruvka, dynamic boruvka /
filter_boruvka (in-process), distributed (replicated labels) and
distributed_sharded (1D-sharded labels + routed exchange) on 8 virtual
devices through the public ``minimum_spanning_forest`` dispatch
(subprocess; main process keeps 1 device).

Graph families (tests/helpers/graph_families.py, shared verbatim with
the subprocess): uniform random, clustered (RMAT), duplicate weights
(heavy ties — exercises the eid tie-break), disconnected (forest, not
tree), and self-loops lighter than every real edge (must never be
chosen).  Randomised over seeds; a hypothesis fuzz pass runs on top
when hypothesis is installed.
"""
import inspect

import numpy as np
import pytest

from repro.core import oracle
from repro.core.boruvka import boruvka_msf
from repro.core.filter_boruvka import (boruvka_dynamic,
                                       filter_boruvka_dynamic,
                                       filter_boruvka_msf)
from tests.helpers import graph_families
from tests.helpers.graph_families import FAMILIES
from tests.helpers.hypothesis_compat import given, settings, st
from tests.helpers.subproc import run_multidevice


ENGINES = {
    "boruvka_msf": lambda u, v, w, n: boruvka_msf(u, v, w, n)[0],
    "filter_boruvka_msf":
        lambda u, v, w, n: filter_boruvka_msf(u, v, w, n, num_buckets=4)[0],
    "boruvka_dynamic": lambda u, v, w, n: boruvka_dynamic(u, v, w, n)[0],
    "filter_boruvka_dynamic":
        lambda u, v, w, n: filter_boruvka_dynamic(u, v, w, n)[0],
}


def _assert_matches_oracle(mask, u, v, w, n, ctx):
    kmask, kweight = oracle.kruskal(u, v, w, n)
    mask = np.asarray(mask)
    assert np.array_equal(np.nonzero(mask)[0], np.nonzero(kmask)[0]), (
        ctx, "edge set differs from the (w, eid) oracle MSF")
    got = float(np.sum(w[mask]))
    assert abs(got - kweight) < 1e-3 * max(1.0, kweight), (ctx, got, kweight)


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("engine", sorted(ENGINES))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_local_engines_match_oracle(family, engine, seed):
    u, v, w, n = FAMILIES[family](seed)
    mask = ENGINES[engine](u, v, w, n)
    _assert_matches_oracle(mask, u, v, w, n, (family, engine, seed))


# --------------------------------------------------------------------------
# distributed engines (8 virtual devices >= 4 shards, subprocess)
# --------------------------------------------------------------------------

# the exact same family builders, injected as source so the two matrices
# cannot drift apart
DISTRIBUTED = inspect.getsource(graph_families) + """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.graph import from_numpy
from repro.core.mst import minimum_spanning_forest

mesh = Mesh(np.array(jax.devices()), ("data",))

for fam, make in sorted(FAMILIES.items()):
    u, v, w, n = make(0)
    edges = from_numpy(u, v, w, n)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    for engine in ("distributed", "distributed_sharded"):
        for algo in ("boruvka", "filter_boruvka"):
            mask, wt = minimum_spanning_forest(
                edges, algorithm=algo, engine=engine, mesh=mesh)
            mk = np.asarray(mask)
            assert np.array_equal(np.nonzero(mk)[0], np.nonzero(kmask)[0]), (
                fam, engine, algo, "edge set differs from oracle")
            assert abs(float(wt) - kweight) < 1e-3 * max(1.0, kweight), (
                fam, engine, algo, float(wt), kweight)
print("OK")
"""


def test_distributed_engines_match_oracle():
    out = run_multidevice(DISTRIBUTED, ndev=8, timeout=1800)
    assert "OK" in out


# the sharded engine's ISSUE 2 communication levers, each toggled alone
# plus all together, must keep the MSF edge set bit-identical to the
# oracle on the adversarial families (heavy ties exercise the (w, eid)
# tie-break through the src-only owner-side marking; disconnected
# exercises the dead-edge retirement's termination)
SHARDED_FLAGS = inspect.getsource(graph_families) + """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.graph import from_numpy
from repro.core.mst import minimum_spanning_forest

mesh = Mesh(np.array(jax.devices()), ("data",))
OFF = dict(local_preprocessing=False, coalesce=False, src_only=False,
           adaptive_doubling=False, shrink_capacities=False,
           ghost_cache=False, relabel_skip=False)
COMBOS = [
    dict(OFF),                                           # the PR 1 baseline
    dict(OFF, local_preprocessing=True),
    dict(OFF, coalesce=True),            # incl. the v-sorted index
    dict(OFF, coalesce=True, vsorted_index=False),  # PR 3 slot-order v
    dict(OFF, src_only=True),
    dict(OFF, adaptive_doubling=True),
    dict(OFF, shrink_capacities=True),   # shrinking schedule alone
    dict(OFF, relabel_skip=True),        # settled-vertex RELABEL skip
    # the ISSUE 4 ghost_cache x coalesce x shrink_capacities sub-matrix
    # (the cache replaces the endpoint lookups, so each pairing takes a
    # genuinely different code path through _round_body)
    dict(OFF, ghost_cache=True),
    dict(OFF, ghost_cache=True, coalesce=True),
    dict(OFF, ghost_cache=True, shrink_capacities=True),
    dict(OFF, ghost_cache=True, coalesce=True, shrink_capacities=True),
    dict(ghost_cache=False, vsorted_index=False),  # the PR 3 optimized
    dict(ghost_cache=False),             # all levers minus the cache
    dict(shrink_capacities=False),       # all levers, flat capacities
    dict(),                              # everything incl. the schedule
    # the ISSUE 8 pallas_minedges lever: the fused kernel must be
    # bit-identical through every MINEDGES code path — the 2-exchange
    # baseline, the src-only per-run combine, ghost/vsorted reads, the
    # shrinking schedule, and the all-on engine
    dict(OFF, pallas_minedges=True),                     # 2-exchange kernel
    dict(OFF, src_only=True, pallas_minedges=True),      # fused combine
    dict(OFF, ghost_cache=True, coalesce=True, pallas_minedges=True),
    dict(shrink_capacities=False, pallas_minedges=True),  # flat + kernel
    dict(ghost_cache=False, vsorted_index=False, pallas_minedges=True),
    dict(pallas_minedges=True),          # everything through the kernel
]

for fam in ("random", "clustered", "dup_weights", "disconnected"):
    u, v, w, n = FAMILIES[fam](0)
    edges = from_numpy(u, v, w, n)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    for combo in COMBOS:
        mask, wt = minimum_spanning_forest(
            edges, algorithm="boruvka", engine="distributed_sharded",
            mesh=mesh, **combo)
        mk = np.asarray(mask)
        assert np.array_equal(np.nonzero(mk)[0], np.nonzero(kmask)[0]), (
            fam, combo, "edge set differs from oracle")
        assert abs(float(wt) - kweight) < 1e-3 * max(1.0, kweight), (
            fam, combo, float(wt), kweight)
print("OK")
"""


def test_sharded_optimization_flags_match_oracle():
    out = run_multidevice(SHARDED_FLAGS, ndev=8, timeout=1800)
    assert "OK" in out


# plan measured with the kernel lever, replayed strictly (replan=False)
# through the Python-unrolled executor with the ISSUE 7 self-verifier on:
# pins (a) the lever survives the RoundPlan round-trip, (b) replay is
# bit-identical to the oracle through the kernel path, (c) verify=True
# accepts the kernel-path forest
SHARDED_PALLAS_PLAN = inspect.getsource(graph_families) + """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (execute_plan,
                                            plan_sharded_msf)
from repro.core.plan import RoundPlan

mesh = Mesh(np.array(jax.devices()), ("data",))
for fam in ("dup_weights", "disconnected"):
    u, v, w, n = FAMILIES[fam](0)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    g, cap = build_dist_graph(u, v, w, n, 8)
    plan = plan_sharded_msf(g, n, mesh, pallas_minedges=True)
    assert plan.pallas_minedges
    plan = RoundPlan.from_json(plan.to_json())  # lever round-trips
    assert plan.pallas_minedges
    mask, wt, cnt, lab, ovf, comm = execute_plan(
        g, n, mesh, plan, replan=False, verify=True)
    assert int(ovf) == 0, (fam, int(ovf))
    got = sorted(set(int(e) for e in np.asarray(g.eid)[np.asarray(mask)]))
    assert got == sorted(np.nonzero(kmask)[0].tolist()), (
        fam, "edge set differs from oracle through the kernel plan path")
    assert abs(float(wt) - kweight) < 1e-3 * max(1.0, kweight)
print("OK")
"""


def test_sharded_pallas_plan_replay_verified():
    out = run_multidevice(SHARDED_PALLAS_PLAN, ndev=8, timeout=1800)
    assert "OK" in out


# ISSUE 10: the two-level grid ghost push.  On a (4, 2) mesh every
# family must be bit-identical across flat push x grid push x the
# public dispatch default, the ghost_shard_limit ladder must step
# grid -> flat -> no-ghost without changing a single mask bit, and the
# grid lever must survive the RoundPlan JSON round-trip, show up in
# plan_cache_key, and replay strictly (replan=False) bit-identical.
SHARDED_GRID_PUSH = inspect.getsource(graph_families) + """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (distributed_sharded_msf,
                                            execute_plan, plan_sharded_msf)
from repro.core.graph import from_numpy
from repro.core.mst import minimum_spanning_forest
from repro.core.plan import RoundPlan

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("row", "col"))
AX = ("row", "col")

for fam in ("random", "dup_weights", "disconnected"):
    u, v, w, n = FAMILIES[fam](0)
    edges = from_numpy(u, v, w, n)
    kmask, kweight = oracle.kruskal(u, v, w, n)
    ref = None
    for push in (None, "flat", "grid"):
        mask, wt = minimum_spanning_forest(
            edges, algorithm="boruvka", engine="distributed_sharded",
            mesh=mesh, axis_names=AX, ghost_push=push)
        mk = np.asarray(mask)
        assert np.array_equal(np.nonzero(mk)[0], np.nonzero(kmask)[0]), (
            fam, push, "edge set differs from oracle")
        assert abs(float(wt) - kweight) < 1e-3 * max(1.0, kweight), (
            fam, push, float(wt), kweight)
        if ref is None:
            ref = mk
        assert np.array_equal(mk, ref), (fam, push, "flat/grid drift")

# ghost_shard_limit fallback ladder on the same 2-axis mesh: a limit
# of 31 fits p=8 in one flat mask (no grid rounds), 7 forces the grid
# rung (4 <= 7 and 2 <= 7 but p=8 > 7), 1 disables the cache entirely
# (rows 4 > 1) — every rung bit-identical, overflow 0
u, v, w, n = FAMILIES["random"](1)
g, cap = build_dist_graph(u, v, w, n, 8)
kmask, _ = oracle.kruskal(u, v, w, n)
ksel = np.nonzero(kmask)[0]
base = None
for lim, expect_hits, expect_grid in ((31, True, False),
                                      (7, True, True),
                                      (1, False, False)):
    tr = []
    res = distributed_sharded_msf(g, n, mesh, axis_names=AX,
                                  ghost_shard_limit=lim, round_trace=tr)
    assert int(res[4]) == 0, (lim, int(res[4]))
    sel = np.unique(np.asarray(g.eid)[np.asarray(res[0])])
    assert np.array_equal(sel, ksel), (lim, "edge set != oracle")
    if base is None:
        base = np.asarray(res[0])
    assert np.array_equal(np.asarray(res[0]), base), (lim, "ladder drift")
    hits = float(res[5].hits)
    assert (hits > 0) == expect_hits, (lim, hits)
    grid_rounds = any(t.get("grid_push") for t in tr)
    assert grid_rounds == expect_grid, (lim, grid_rounds)

# the plan lever: measured grid plan carries per-round deputy
# capacities, round-trips to_json/from_json, keys differently from the
# flat plan, and replays strictly bit-identical (incl. after pad())
plan = plan_sharded_msf(g, n, mesh, axis_names=AX, ghost_push="grid")
assert plan.grid_push
assert any(r.cap_push_col > 0 for r in plan.rounds)
rt = RoundPlan.from_json(plan.to_json())
assert rt == plan, "grid lever lost in the JSON round-trip"
assert plan.cache_key("x") != plan._replace(grid_push=False).cache_key("x")
for p2 in (rt, rt.pad(0.25)):
    res = execute_plan(g, n, mesh, p2, axis_names=AX, replan=False)
    assert int(res[4]) == 0
    assert np.array_equal(np.asarray(res[0]), base), "replay drift"
print("OK")
"""


def test_sharded_grid_push_matrix():
    out = run_multidevice(SHARDED_GRID_PUSH, ndev=8, timeout=1800)
    assert "OK" in out


# p = 32 (8 x 4) — impossible at seed: the flat int32 subscriber mask
# caps the ghost cache at 31 shards, so before ISSUE 10 the cache was
# forced off here.  The auto ladder must now pick the grid push, keep
# the cache live (hits > 0), and stay bit-identical to the oracle.
SHARDED_GRID_P32 = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import distributed_sharded_msf
from repro.data import generators

mesh = Mesh(np.array(jax.devices()).reshape(8, 4), ("row", "col"))
u, v, w, n = generators.generate("rgg2d", 1024, avg_degree=8.0, seed=7)
g, cap = build_dist_graph(u, v, w, n, 32)
kmask, _ = oracle.kruskal(u, v, w, n)
tr = []
res = distributed_sharded_msf(g, n, mesh, axis_names=("row", "col"),
                              round_trace=tr)
assert int(res[4]) == 0, int(res[4])
sel = np.unique(np.asarray(g.eid)[np.asarray(res[0])])
assert np.array_equal(sel, np.nonzero(kmask)[0]), "edge set != oracle"
assert float(res[5].hits) > 0, "cache must be live at p=32"
assert any(t["grid_push"] for t in tr), "auto ladder must pick grid"
print("OK")
"""


def test_sharded_grid_push_p32_oracle():
    out = run_multidevice(SHARDED_GRID_P32, ndev=32, timeout=1800)
    assert "OK" in out


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_property_random_graphs_match_oracle(data):
    n = data.draw(st.integers(2, 40), label="n")
    m = data.draw(st.integers(0, 120), label="m")
    seed = data.draw(st.integers(0, 2 ** 31 - 1), label="seed")
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    # intentionally keep self-loops and parallel edges
    w = rng.integers(1, 8, m).astype(np.float32)
    for engine, fn in sorted(ENGINES.items()):
        mask = fn(u, v, w, n)
        _assert_matches_oracle(mask, u, v, w, n, (engine, n, m, seed))
