"""Per-kernel shape/dtype sweeps: pallas(interpret=True) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.helpers.hypothesis_compat import given, settings, st

from repro.kernels.segmin.ops import min_edges_dense
from repro.kernels.segmin.ref import (dense_min_from_candidates,
                                      segmin_candidates_ref)
from repro.kernels.segmin.segmin import segmin_candidates
from repro.kernels.relabel.ops import relabel_edges
from repro.kernels.relabel.ref import relabel_ref


def _sorted_run_problem(m, n, seed, w_dtype=jnp.float32, tie_heavy=False):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n, m)).astype(np.int32)
    if tie_heavy:
        w = rng.integers(1, 4, m).astype(np.float32)
    else:
        w = rng.uniform(1, 255, m).astype(np.float32)
    eid = rng.permutation(m).astype(np.int32)
    alive = rng.random(m) < 0.8
    return (jnp.asarray(seg), jnp.asarray(w, w_dtype), jnp.asarray(eid),
            jnp.asarray(alive))


@pytest.mark.parametrize("m", [8, 100, 512, 1000, 2048])
@pytest.mark.parametrize("block", [128, 512])
@pytest.mark.parametrize("w_dtype", [jnp.float32, jnp.bfloat16])
def test_segmin_dense_matches_ref(m, block, w_dtype):
    n = max(4, m // 4)
    seg, w, eid, alive = _sorted_run_problem(m, n, seed=m + block, w_dtype=w_dtype)
    got_w, got_e = min_edges_dense(seg, w, eid, alive, n, block=block,
                                   interpret=True, use_pallas=True)
    exp_w, exp_e = min_edges_dense(seg, w, eid, alive, n, block=block,
                                   use_pallas=False)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(exp_w))
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(exp_e))


def test_segmin_tie_breaking_exact():
    seg, w, eid, alive = _sorted_run_problem(777, 50, seed=1, tie_heavy=True)
    got_w, got_e = min_edges_dense(seg, w, eid, alive, 50, block=128,
                                   interpret=True, use_pallas=True)
    exp_w, exp_e = min_edges_dense(seg, w, eid, alive, 50, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(exp_e))


def test_segmin_unsorted_piecewise_runs():
    """seg need not be sorted — only contiguous runs matter."""
    seg = jnp.asarray(np.repeat([5, 2, 9, 2, 0], [7, 3, 11, 4, 6])
                      .astype(np.int32))
    m = seg.shape[0]
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.uniform(1, 9, m).astype(np.float32))
    eid = jnp.asarray(np.arange(m, dtype=np.int32))
    alive = jnp.asarray(np.ones(m, bool))
    got = min_edges_dense(seg, w, eid, alive, 10, block=8, interpret=True)
    exp = min_edges_dense(seg, w, eid, alive, 10, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(exp[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 300), st.integers(1, 40), st.integers(0, 99),
       st.sampled_from([64, 128, 256]))
def test_segmin_property(m, n, seed, block):
    seg, w, eid, alive = _sorted_run_problem(m, n, seed)
    got = min_edges_dense(seg, w, eid, alive, n, block=block, interpret=True)
    exp = min_edges_dense(seg, w, eid, alive, n, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(exp[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))


def test_segmin_all_dead_and_empty_runs():
    m, n = 64, 8
    seg = jnp.asarray(np.sort(np.random.default_rng(0).integers(0, n, m))
                      .astype(np.int32))
    w = jnp.full((m,), 5.0, jnp.float32)
    eid = jnp.arange(m, dtype=jnp.int32)
    alive = jnp.zeros((m,), bool)
    wmin, emin = min_edges_dense(seg, w, eid, alive, n, interpret=True)
    assert not np.isfinite(np.asarray(wmin)).any()


@pytest.mark.parametrize("m,n", [(16, 8), (500, 100), (2048, 35000)])
@pytest.mark.parametrize("block", [128, 1024])
def test_relabel_matches_ref(m, n, block):
    rng = np.random.default_rng(m + block)
    u = jnp.asarray(rng.integers(0, n, m).astype(np.int32))
    v = jnp.asarray(rng.integers(0, n, m).astype(np.int32))
    w = np.where(rng.random(m) < 0.1, np.inf,
                 rng.uniform(1, 255, m)).astype(np.float32)
    w = jnp.asarray(w)
    # labels with contracted structure: pointer-doubled random forest
    lab = rng.integers(0, n, n).astype(np.int32)
    lab = np.minimum(lab, np.arange(n, dtype=np.int32))
    for _ in range(20):
        lab = lab[lab]
    lab = jnp.asarray(lab)
    got = relabel_edges(u, v, w, lab, block=block, interpret=True,
                        use_pallas=True)
    exp = relabel_ref(u, v, w, lab)
    for g, e in zip(got, exp):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


def test_kernels_compose_one_boruvka_selection():
    """relabel -> segmin reproduces the library's min-edge selection."""
    from repro.core.boruvka import min_edge_per_component
    rng = np.random.default_rng(3)
    n, m = 64, 400
    u = np.sort(rng.integers(0, n, m)).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    w = rng.uniform(1, 255, m).astype(np.float32)
    labels = jnp.arange(n, dtype=jnp.int32)
    ru, rv, wp = relabel_edges(jnp.asarray(u), jnp.asarray(v),
                               jnp.asarray(w), labels, interpret=True)
    eid = jnp.arange(m, dtype=jnp.int32)
    alive = jnp.isfinite(wp)
    wmin_k, _ = min_edges_dense(ru, wp, eid, alive, n, interpret=True)
    wmin_l, _ = min_edge_per_component(ru, rv, jnp.asarray(w), n)
    # the kernel reduces the src side only (directed representation);
    # the library reduces both sides of the canonical single-copy form —
    # compare on the src-side projection
    wmin_src = jnp.full((n,), jnp.inf).at[ru].min(
        jnp.where(alive, wp, jnp.inf))
    np.testing.assert_allclose(np.asarray(wmin_k), np.asarray(wmin_src))
