"""int8 KV cache (§Perf command-r iteration 4): decode equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models.model import forward_decode, init_caches, init_params


def _greedy(cfg, params, toks, B, T):
    caches = init_caches(cfg, B, T)
    step = jax.jit(lambda p, c, t, q: forward_decode(cfg, p, c, t, q))
    logits = None
    for t in range(toks.shape[1]):
        logits, caches = step(params, caches, jnp.asarray(toks[:, t]),
                              jnp.full((B,), t, jnp.int32))
    return np.asarray(logits, np.float32)


def test_int8_cache_matches_fp_cache():
    cfg = get_arch("command-r-35b").smoke
    params = init_params(cfg, jax.random.key(0))
    B, T = 2, 24
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, 8)).astype(np.int32)
    lf = _greedy(cfg, params, toks, B, T)
    li = _greedy(dataclasses.replace(cfg, kv_cache_dtype="int8"),
                 params, toks, B, T)
    rel = np.abs(lf - li).max() / max(np.abs(lf).max(), 1e-6)
    assert rel < 0.05, rel
    assert (lf.argmax(-1) == li.argmax(-1)).all()


def test_int8_cache_footprint_halves():
    cfg = get_arch("command-r-35b").smoke
    c8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    fp = init_caches(cfg, 2, 64)
    q8 = init_caches(c8, 2, 64)
    bytes_fp = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(fp))
    bytes_q8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(q8))
    # smoke hd=16: (16*1B + 4B scale) / (16*2B) = 0.625; full hd=128: 0.52
    assert bytes_q8 < 0.65 * bytes_fp, (bytes_q8, bytes_fp)
