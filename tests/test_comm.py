"""Communication primitives: grid all-to-all == direct, routed exchange
conservation, distributed sample sort correctness.  Multi-device via
subprocess (main process keeps 1 device)."""
import pytest

from tests.helpers.subproc import run_multidevice

GRID_EQ = """
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm.grid_alltoall import grid_all_to_all, direct_all_to_all, all_to_all_nd

devices = np.array(jax.devices()).reshape(4, 2)
mesh = Mesh(devices, ("row", "col"))
p = 8

for shape, dtype in [((p * p, 3), jnp.float32), ((p * p, 2, 5), jnp.int32),
                     ((p * p, 1), jnp.bfloat16), ((p * p, 7), jnp.float32)]:
    x = jnp.arange(int(np.prod(shape)), dtype=jnp.float32)
    x = x.reshape(shape).astype(dtype)  # global leading dim = p*p

    def run(fn):
        f = shard_map(fn, mesh=mesh, in_specs=P(("row", "col")),
                      out_specs=P(("row", "col")))
        return f(x)

    a = run(lambda t: grid_all_to_all(t, ("row", "col")))
    b = run(lambda t: direct_all_to_all(t, ("row", "col")))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# 3-axis generalisation
devices3 = np.array(jax.devices()).reshape(2, 2, 2)
mesh3 = Mesh(devices3, ("a", "b", "c"))
x = jnp.arange(8 * 8 * 3, dtype=jnp.float32).reshape(8 * 8, 3)
fa = shard_map(lambda t: all_to_all_nd(t, ("a", "b", "c"), "grid"),
               mesh=mesh3, in_specs=P(("a", "b", "c")),
               out_specs=P(("a", "b", "c")))
fb = shard_map(lambda t: all_to_all_nd(t, ("a", "b", "c"), "direct"),
               mesh=mesh3, in_specs=P(("a", "b", "c")),
               out_specs=P(("a", "b", "c")))
np.testing.assert_array_equal(np.asarray(fa(x)), np.asarray(fb(x)))
print("OK")
"""


EXCHANGE = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm.exchange import routed_exchange, request_reply

devices = np.array(jax.devices()).reshape(4, 2)
mesh = Mesh(devices, ("row", "col"))
p, L, C = 8, 64, 16
rng = np.random.default_rng(0)
payload = rng.integers(0, 1000, (p * L,)).astype(np.int32)
dest = rng.integers(0, p, (p * L,)).astype(np.int32)
valid = rng.random(p * L) < 0.9

def body(pl, d, va):
    ex = routed_exchange(pl, d, va, C, ("row", "col"), schedule="grid")
    import jax.numpy as jnp
    got = jnp.where(ex.recv_ok, ex.recv, 0).sum()
    sent = jnp.where(ex.sent_ok, pl, 0).sum()
    return (jax.lax.psum(got, ("row", "col")),
            jax.lax.psum(sent, ("row", "col")), ex.overflow)

f = shard_map(body, mesh=mesh,
              in_specs=(P(("row", "col")),) * 3,
              out_specs=(P(), P(), P()))
got, sent, overflow = f(jnp.asarray(payload), jnp.asarray(dest),
                        jnp.asarray(valid))
# conservation: everything sent within capacity arrives exactly once
assert int(got) == int(sent), (int(got), int(sent))
# with L=64 requests to p=8 dests and C=16, overflow should be rare but
# whatever it is, sent+dropped must equal all valid items
total_valid = int(valid.sum())
dropped = int(overflow)
arrived = 0
# recompute arrived precisely: count sent_ok
def count(pl, d, va):
    ex = routed_exchange(pl, d, va, C, ("row", "col"))
    return jax.lax.psum(ex.sent_ok.sum(), ("row", "col"))
cf = shard_map(count, mesh=mesh, in_specs=(P(("row", "col")),) * 3,
               out_specs=P())
arrived = int(cf(jnp.asarray(payload), jnp.asarray(dest), jnp.asarray(valid)))
assert arrived + dropped == total_valid, (arrived, dropped, total_valid)

# request/reply round trip: answer = request * 2, every in-capacity item
# gets its own answer back
def rr(pl, d, va):
    def answer(recv, ok):
        return recv * 2
    out, okk, ov = request_reply(pl, d, va, answer, C, ("row", "col"))
    import jax.numpy as jnp
    good = jnp.where(okk, (out == pl * 2), True).all()
    return jax.lax.pmin(good.astype(jnp.int32), ("row", "col"))
rf = shard_map(rr, mesh=mesh, in_specs=(P(("row", "col")),) * 3,
               out_specs=P())
assert int(rf(jnp.asarray(payload), jnp.asarray(dest),
              jnp.asarray(valid))) == 1
print("OK")
"""


SORT = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm.sorting import sample_sort

devices = np.array(jax.devices()).reshape(4, 2)
mesh = Mesh(devices, ("row", "col"))
p, L = 8, 256
rng = np.random.default_rng(1)
keys = rng.uniform(0, 1000, (p * L,)).astype(np.float32)
vals = np.arange(p * L, dtype=np.int32)
valid = rng.random(p * L) < 0.85

def body(k, v, va):
    r = sample_sort(k, (v,), va, ("row", "col"), capacity_factor=3.0)
    return (r.key, r.payload, r.ok, r.overflow)

f = shard_map(body, mesh=mesh, in_specs=(P(("row", "col")),) * 3,
              out_specs=(P(("row", "col")), (P(("row", "col")),),
                         P(("row", "col")), P()))
res = f(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))
rk, (rv,), rok, overflow = res
assert int(overflow) == 0, int(overflow)
rk = np.asarray(rk); rv = np.asarray(rv); rok = np.asarray(rok)
got = np.sort(rk[rok])
exp = np.sort(keys[valid])
np.testing.assert_allclose(got, exp)
# globally sorted across shard boundaries: per-shard slices are sorted and
# shard s max <= shard s+1 min (padding is +inf at each shard's tail)
cap = len(rk) // p
for s in range(p):
    sl = rk[s * cap:(s + 1) * cap]
    fin = sl[np.isfinite(sl)]
    assert (np.diff(fin) >= 0).all()
    # padding (+inf) only at the tail of each shard slice
    assert np.isfinite(sl[:len(fin)]).all()
    if s + 1 < p:
        nxt = rk[(s + 1) * cap:(s + 2) * cap]
        nfin = nxt[np.isfinite(nxt)]
        if len(fin) and len(nfin):
            assert fin[-1] <= nfin[0] + 1e-6
# payload follows its key: the payload IS the original index, so the
# original key at that index must equal the arrived key (robust to
# float32 key collisions), and each valid payload arrives exactly once
arrived = rv[rok]
assert np.array_equal(np.sort(arrived), np.sort(vals[valid]))
for k, x, ok in zip(rk, rv, rok):
    if ok:
        assert keys[int(x)] == k
print("OK")
"""


STATS_CONSERVATION = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm.exchange import (ExchangeStats, reply, routed_exchange,
                                 scatter_updates)

# counter-conservation audit (ISSUE 4 satellite): one logical
# request/reply lookup must book its buffer slots EXACTLY once per leg —
# 2 * p * C total, never more (a double-count would silently inflate the
# capacity-per-call audit of the shrinking schedule) — and calls/items/
# bytes must match the closed-form accounting in the ExchangeStats
# docstring.
devices = np.array(jax.devices())
mesh = Mesh(devices, ("data",))
p, L, C = 8, 64, 16
rng = np.random.default_rng(3)
payload = rng.integers(0, 1000, (p * L,)).astype(np.int32)
dest = rng.integers(0, p, (p * L,)).astype(np.int32)
valid = rng.random(p * L) < 0.8

def lookup(pl, d, va):
    st = ExchangeStats.zeros()
    ex = routed_exchange(pl, d, va, C, ("data",), "grid", stats=st)
    answers = jnp.where(ex.recv_ok, ex.recv * 2, 0)
    out, st = reply(ex, answers, ("data",), "grid", stats=ex.stats)
    delivered = jax.lax.psum(ex.recv_ok.sum(), ("data",))
    sent = jax.lax.psum(ex.sent_ok.sum(), ("data",))
    return (st.calls, st.items, st.bytes, st.slots, st.hits, st.misses,
            st.pushed, ex.overflow, sent, delivered)

f = shard_map(lookup, mesh=mesh, in_specs=(P("data"),) * 3,
              out_specs=(P(),) * 10)
calls, items, by, slots, hits, misses, pushed, ovf, sent, delivered = [
    int(x) if x.dtype != jnp.float32 else float(x)
    for x in f(jnp.asarray(payload), jnp.asarray(dest),
               jnp.asarray(valid))]
# single mesh axis => hops == 1; one i32 payload buffer + the validity
# mask on the way out, one i32 answer buffer on the way back
assert calls == (1 + 1) + 1, calls
# items: requests accepted into send buffers + delivered answer slots
assert items == sent + delivered, (items, sent, delivered)
# conservation: within-capacity items all arrive, drops are counted
assert sent == delivered, (sent, delivered)
assert sent + ovf == int(valid.sum()), (sent, ovf, int(valid.sum()))
# THE audit: exactly 2 * p * C slots for the round trip, not 4 * p * C
assert slots == 2 * p * C, (slots, 2 * p * C)
# bytes: capacity-padded per-device buffers — (i32 + bool mask) out,
# i32 answers back (device-invariant static sizes, not psum'd)
assert by == p * C * (4 + 1) + p * C * 4, by
# the ghost counters belong to the engine's call sites, not the
# primitives: a bare exchange must leave them untouched
assert hits == 0 and misses == 0 and pushed == 0, (hits, misses, pushed)

# scatter_updates (the dirty-label push): multicast conservation — every
# in-capacity (item, destination-bit) copy is delivered exactly once,
# drops are reported, and the slot/byte accounting matches one logical
# exchange of a 1-leaf payload
mask_bits = rng.integers(0, 2 ** p, (p * L,)).astype(np.int32)
pvalid = rng.random(p * L) < 0.7

def push(pl, mk, va):
    upd = scatter_updates(pl, mk, va, C, ("data",), "grid",
                          stats=ExchangeStats.zeros())
    st = upd.stats
    got = jax.lax.psum(jnp.where(upd.recv_ok, upd.recv, 0).sum(), ("data",))
    sent = jax.lax.psum(jnp.where(upd.sent_ok, pl[:, None], 0).sum(),
                        ("data",))
    ndel = jax.lax.psum(upd.recv_ok.sum(), ("data",))
    nsent = jax.lax.psum(upd.sent_ok.sum(), ("data",))
    return (upd.overflow, got, sent, ndel, nsent, st.calls, st.items,
            st.slots)

g = shard_map(push, mesh=mesh, in_specs=(P("data"),) * 3,
              out_specs=(P(),) * 8)
ovf, got, sent, ndel, nsent, calls, items, slots = [
    int(x) if x.dtype != jnp.float32 else float(x)
    for x in g(jnp.asarray(payload), jnp.asarray(mask_bits),
               jnp.asarray(pvalid))]
copies = sum(bin(m).count("1") for m, va in zip(mask_bits, pvalid) if va)
assert nsent + ovf == copies, (nsent, ovf, copies)
assert ndel == nsent and got == sent, (ndel, nsent, got, sent)
assert items == nsent, (items, nsent)
assert calls == 2, calls          # payload + validity mask, 1 hop
assert slots == p * C, slots      # one logical exchange, no reply leg
print("OK")
"""


GRID_SCATTER = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm.exchange import (ExchangeStats, scatter_updates,
                                 scatter_updates_grid)

# two-level grid multicast (ISSUE 10): on a (4, 2) mesh every
# (item, row-bit, col-bit) cross-product copy must be delivered exactly
# once at overflow 0, and the stats must book the two legs distinctly —
# C*cap_row + R*cap_col slots, never the flat p*cap.
devices = np.array(jax.devices())
R, C = 4, 2
mesh = Mesh(devices.reshape(R, C), ("row", "col"))
p, L = R * C, 32
cap_row, cap_col = L * C, L * C * R   # generous: zero overflow expected
rng = np.random.default_rng(11)
payload = rng.integers(0, 1000, (p * L,)).astype(np.int32)
rmask = rng.integers(0, 2 ** R, (p * L,)).astype(np.int32)
cmask = rng.integers(0, 2 ** C, (p * L,)).astype(np.int32)
valid = rng.random(p * L) < 0.7

def push(pl, rm, cm, va):
    upd = scatter_updates_grid(pl, rm, cm, va, cap_row, cap_col,
                               ("row", "col"),
                               stats=ExchangeStats.zeros())
    got = jax.lax.psum(jnp.where(upd.recv_ok, upd.recv, 0).sum(),
                       ("row", "col"))
    ndel = jax.lax.psum(upd.recv_ok.sum(), ("row", "col"))
    return (upd.overflow, got, ndel, upd.stats.calls, upd.stats.items,
            upd.stats.slots)

f = shard_map(push, mesh=mesh, in_specs=(P(("row", "col")),) * 4,
              out_specs=(P(),) * 6)
ovf, got, ndel, calls, items, slots = [
    int(x) if x.dtype != jnp.float32 else float(x)
    for x in f(jnp.asarray(payload), jnp.asarray(rmask),
               jnp.asarray(cmask), jnp.asarray(valid))]
# delivery set = cross product of the two masks
copies = sum(bin(r).count("1") * bin(c).count("1")
             for r, c, va in zip(rmask, cmask, valid) if va)
psum = sum(int(pl) * bin(r).count("1") * bin(c).count("1")
           for pl, r, c, va in zip(payload, rmask, cmask, valid) if va)
assert ovf == 0, ovf
assert ndel == copies, (ndel, copies)
assert got == psum, (got, psum)
# two legs booked distinctly: hop 1 re-admits per column, hop 2 per row
assert slots == C * cap_row + R * cap_col, slots
# hop 1 ships payload + row mask + validity, hop 2 payload + validity
assert calls == 3 + 2, calls
# items counts BOTH legs' admissions (the deputy leg's real traffic):
# hop 1 one copy per subscribed column, hop 2 the full cross product
hop1 = sum(bin(c).count("1") for c, va in zip(cmask, valid) if va)
assert items == hop1 + copies, (items, hop1, copies)

# satellite: the FLAT scatter on the same 2-axis mesh books the grid
# schedule's per-hop re-admission — p * cap * 2 slots, not p * cap
fmask = rng.integers(0, 2 ** p, (p * L,)).astype(np.int32)

def flat(pl, mk, va):
    upd = scatter_updates(pl, mk, va, L, ("row", "col"), "grid",
                          stats=ExchangeStats.zeros())
    return (upd.stats.slots,)

(fslots,) = shard_map(flat, mesh=mesh,
                      in_specs=(P(("row", "col")),) * 3,
                      out_specs=(P(),))(
    jnp.asarray(payload), jnp.asarray(fmask), jnp.asarray(valid))
assert float(fslots) == p * L * 2, float(fslots)
print("OK")
"""


@pytest.mark.parametrize("name,script", [
    ("grid_eq", GRID_EQ), ("exchange", EXCHANGE), ("sort", SORT),
    ("stats_conservation", STATS_CONSERVATION),
    ("grid_scatter", GRID_SCATTER)])
def test_comm(name, script):
    out = run_multidevice(script, ndev=8)
    assert "OK" in out


def test_scatter_mask_width_to_31_shards():
    """ISSUE 5 satellite: unit-level harness for the subscriber-bitmask
    width contract of ``scatter_updates``.  The copy-matrix expansion is
    pure bit arithmetic (no mesh needed), so the full 31-destination
    width — including bit 30, the last usable one before the int32 sign
    bit — is checked directly against a numpy reference; the >31-shard
    engine fallback that this limit forces is exercised end-to-end in
    tests/test_distributed_sharded.py (ghost_limit_fallback).
    """
    import numpy as np

    from repro.comm.exchange import _mask_to_copies

    rng = np.random.default_rng(5)
    L, p = 96, 31
    # dense random masks plus the corner rows: empty, all-31-bits
    # (0x7fffffff, a positive int32), and the single high bit 30
    masks = rng.integers(0, 1 << 31, L, dtype=np.int64)
    masks[0], masks[1], masks[2] = 0, (1 << 31) - 1, 1 << 30
    masks = masks.astype(np.int32)
    valid = rng.random(L) < 0.8
    valid[1] = valid[2] = True
    got = np.asarray(_mask_to_copies(masks, valid, p))
    assert got.shape == (L, p)
    expect = valid[:, None] & (
        ((masks.astype(np.int64)[:, None] >> np.arange(p)) & 1) > 0)
    assert np.array_equal(got, expect)
    # bit 30 reaches destination 30 and nothing else
    assert got[2, 30] and got[2, :30].sum() == 0
    # every destination of the full mask is hit: no sign-extension loss
    assert got[1].all()


def test_axis_masks_to_copies_961_shard_contract():
    """ISSUE 10 satellite: the per-axis sibling of ``_mask_to_copies``.

    Pure bit arithmetic, no mesh: the (row mask, col mask) pair must
    expand to independent per-axis copy matrices whose outer product
    addresses the full 31 x 31 = 961-shard envelope — bit 30 usable on
    *both* axes, empty subscriber sets on either axis killing the cross
    product, and the widths exactly (L, r) / (L, c).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.exchange import _axis_masks_to_copies

    rng = np.random.default_rng(12)
    L, r, c = 64, 31, 31
    rmask = rng.integers(0, 1 << 31, L, dtype=np.int64)
    cmask = rng.integers(0, 1 << 31, L, dtype=np.int64)
    # corner rows: both empty; full x full (the 961-shard envelope);
    # bit 30 on both axes; row-empty with cols set (dead cross product)
    rmask[0], cmask[0] = 0, 0
    rmask[1], cmask[1] = (1 << 31) - 1, (1 << 31) - 1
    rmask[2], cmask[2] = 1 << 30, 1 << 30
    rmask[3], cmask[3] = 0, (1 << 31) - 1
    rmask, cmask = rmask.astype(np.int32), cmask.astype(np.int32)
    valid = rng.random(L) < 0.8
    valid[1] = valid[2] = valid[3] = True
    rc, cc = _axis_masks_to_copies(
        jnp.asarray(rmask), jnp.asarray(cmask), jnp.asarray(valid), r, c)
    rc, cc = np.asarray(rc), np.asarray(cc)
    assert rc.shape == (L, r) and cc.shape == (L, c)
    lanes = np.arange(31)
    exp_r = valid[:, None] & (
        ((rmask.astype(np.int64)[:, None] >> lanes) & 1) > 0)
    exp_c = valid[:, None] & (
        ((cmask.astype(np.int64)[:, None] >> lanes) & 1) > 0)
    assert np.array_equal(rc, exp_r) and np.array_equal(cc, exp_c)
    # the outer product of the full masks covers all 961 shards
    assert int(rc[1].sum()) * int(cc[1].sum()) == 961
    # bit 30 works on both axes: exactly shard (30, 30)
    assert rc[2, 30] and cc[2, 30]
    assert rc[2].sum() == 1 and cc[2].sum() == 1
    # an empty row mask means zero deliveries no matter the col mask
    assert rc[3].sum() == 0 and cc[3].sum() == c
