"""MSF serving gateway (ISSUE 6): plan-cache keying, family-calibrated
synthetic plans (in-process), and the gateway's serving contract on 8
virtual devices (subprocess) — oracle bit-identity of every served
forest, hit/miss/evict accounting, the replan fallback for traffic
whose shapes match a cached plan but whose structure overflows it, and
the drift-triggered plan refresh.  Also the minimal repro for the
historical JAX 0.4.x CPU while_loop/argsort closure miscompile
(xfail on the affected generation; the pinned 0.4.37 passes)."""
import math

import numpy as np
import pytest

from repro.core.distributed import quantize_capacity, shrink_schedule
from repro.core.plan import plan_cache_key, synthetic_plan
from tests.helpers.subproc import run_multidevice


# -- cache keying (in-process) ---------------------------------------------

def test_plan_cache_key_stable_and_discriminating():
    sp = synthetic_plan(256, 8 * 64, 8)
    # the key a gateway computes BEFORE measuring equals the key the
    # measured plan reports — one cache slot per (family, shape, levers)
    # (synthetic plans freeze relabel_skip=False: they cannot model the
    # settled-vertex capacity drop, so the key must say so)
    pre = plan_cache_key("gnm", 256, 8, 64, "boruvka", relabel_skip=False)
    assert sp.cache_key("gnm") == pre
    # pad() buys capacity headroom without changing cache identity
    assert sp.pad(0.5).cache_key("gnm") == pre
    # family / shape / algorithm / levers all discriminate
    kw = dict(relabel_skip=False)
    assert plan_cache_key("rgg2d", 256, 8, 64, **kw) != pre
    assert plan_cache_key("gnm", 512, 8, 64, **kw) != pre
    assert plan_cache_key("gnm", 256, 8, 128, **kw) != pre
    assert plan_cache_key("gnm", 256, 8, 64, "filter_boruvka", **kw) != pre
    assert plan_cache_key("gnm", 256, 8, 64, coalesce=False, **kw) != pre
    assert plan_cache_key("gnm", 256, 8, 64) != pre   # relabel_skip itself


# -- family-calibrated synthetic plans (in-process) ------------------------

def test_synthetic_plan_family_models():
    n, p, cap = 4096, 8, 4096
    vps = 512
    ladder = shrink_schedule(cap)
    # gnm: the MINEDGES exchange is bounded by one candidate per source
    # vertex, so cap_edge plateaus at the vertices-per-shard rung
    sp = synthetic_plan(n, p * cap, p, family="gnm")
    plateau = quantize_capacity(vps, cap)
    assert all(r.cap_edge == plateau for r in sp.rounds)
    # rgg2d: halves from the cap/p rung
    sp = synthetic_plan(n, p * cap, p, family="rgg2d")
    caps = [r.cap_edge for r in sp.rounds]
    start = ladder.index(quantize_capacity(-(-cap // p), cap))
    for r, c in enumerate(caps):
        assert c == ladder[min(start + r, len(ladder) - 1)], (r, c)
    # family=None keeps the generic full-cap halving (backward compat)
    sp = synthetic_plan(n, p * cap, p)
    assert [r.cap_edge for r in sp.rounds][:3] == [4096, 2048, 1024]
    with pytest.raises(ValueError, match="family"):
        synthetic_plan(n, p * cap, p, family="rhg")
    # calibrated plans stay structurally valid + durable
    synthetic_plan(n, p * cap, p, family="gnm").validate()


def test_build_dist_graph_cap_pad():
    from repro.core.distributed import build_dist_graph
    rng = np.random.default_rng(0)
    u = rng.integers(0, 64, 100).astype(np.int32)
    v = (u + 1 + rng.integers(0, 62, 100).astype(np.int32)) % 64
    w = rng.uniform(1, 10, 100).astype(np.float32)
    g0, need = build_dist_graph(u, v, w, 64, 8)
    g1, cap = build_dist_graph(u, v, w, 64, 8, cap=64)
    assert need == 25 and cap == 64
    assert g1.u.shape == (8 * 64,)
    # padding slots are INVALID_W; every real edge copy is preserved
    assert int(np.isfinite(np.asarray(g1.w)).sum()) == 200
    assert np.isclose(np.asarray(g1.w)[np.isfinite(np.asarray(g1.w))].sum(),
                      2 * w.sum())
    with pytest.raises(ValueError, match="cap"):
        build_dist_graph(u, v, w, 64, 8, cap=8)


# -- the serving gateway (subprocess, 8 virtual devices) -------------------

GATEWAY = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.launch.serve_msf import make_traffic
from repro.serve.msf_gateway import MSFGateway, MSFRequest

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))

def check(reqs):
    for r in reqs:
        kmask, kweight = oracle.kruskal(r.u, r.v, r.w, r.n)
        assert np.array_equal(r.edges, np.nonzero(kmask)[0]), (
            r.rid, "served forest != oracle")
        assert abs(r.weight - kweight) < 1e-3 * max(1.0, kweight), r.rid

# (1) hit / miss / evict accounting + oracle bit-identity.  16 requests
# cycling gnm/rgg2d at n=256 -> 2 cache keys, 4 batches of 4.
gw = MSFGateway(mesh, cache_size=2, batch_slots=4)
reqs = make_traffic(("gnm", "rgg2d"), (256,), 16, seed=0)
for r in reqs:
    gw.submit(r)
gw.run()
assert all(r.done for r in reqs)
check(reqs)
s = gw.stats
assert s.served == 16 and s.batches == 4, vars(s)
assert (s.hits, s.misses, s.evictions) == (2, 2, 0), vars(s)
assert len(gw.cache) == 2

# a third key at capacity 2 evicts the least-recently-used entry ...
extra = make_traffic(("gnm",), (384,), 2, seed=50)
for r in extra:
    gw.submit(r)
gw.run()
check(extra)
assert s.misses == 3 and s.evictions == 1 and len(gw.cache) == 2, vars(s)
# ... which was the gnm/256 key (rgg2d/256 was served later), so
# gnm/256 traffic misses again — and evicts the next LRU entry
again = make_traffic(("gnm",), (256,), 2, seed=60)
for r in again:
    gw.submit(r)
gw.run()
check(again)
assert s.misses == 4 and s.hits == 2 and s.evictions == 2, vars(s)

# (2) replan fallback under serving (satellite): traffic whose SHAPE
# matches a cached plan but whose STRUCTURE overflows it.  A star
# graph (hub + n-1 leaves) converges in one Boruvka round, so its
# measured plan has far too few rounds for a path graph of the same
# n and edge count (needs ~log2 n rounds) — same family label, same
# n, same m -> same cache key, guaranteed structural misfit.
n2 = 256
def star(seed):
    rng = np.random.default_rng(seed)
    u = np.zeros(n2 - 1, np.int32)
    v = np.arange(1, n2, dtype=np.int32)
    return u, v, rng.uniform(1, 10, n2 - 1).astype(np.float32)

def path(seed):
    rng = np.random.default_rng(seed)
    u = np.arange(0, n2 - 1, dtype=np.int32)
    v = np.arange(1, n2, dtype=np.int32)
    return u, v, rng.uniform(1, 10, n2 - 1).astype(np.float32)

gw2 = MSFGateway(mesh, cache_size=4, batch_slots=4,
                 replan_threshold=0.34, min_samples=4)
rid = 0
stars = []
for seed in range(4):
    u, v, w = star(seed)
    stars.append(MSFRequest(rid=rid, family="syn", u=u, v=v, w=w, n=n2))
    rid += 1
for r in stars:
    gw2.submit(r)
gw2.run()   # one miss; plan measured on a star graph
check(stars)
assert gw2.stats.misses == 1 and gw2.stats.replans == 0, vars(gw2.stats)
key = gw2._key(stars[0])

# same-key path traffic: every request must replan individually (the
# batchmate isolation is per-request overflow/residual), results stay
# oracle-exact, the replan counter moves, the cache entry survives
paths = []
for seed in range(4):
    u, v, w = path(100 + seed)
    paths.append(MSFRequest(rid=rid, family="syn", u=u, v=v, w=w, n=n2))
    rid += 1
for r in paths:
    gw2.submit(r)
gw2.run()
check(paths)
assert all(r.served_via == "replanned" for r in paths)
assert gw2.stats.hits == 1 and gw2.stats.replans == 4, vars(gw2.stats)
# drift: replan rate 4/8 crossed the threshold -> the entry was
# re-measured off a replanned (path) graph and refreshed in place
assert gw2.stats.refreshes == 1, vars(gw2.stats)
assert key in gw2.cache and len(gw2.cache) == 1
entry = gw2.cache[key]
assert (entry.served, entry.replans) == (0, 0)   # fresh counters

# post-refresh, identical-weights path traffic rides the refreshed
# plan batched — no replans (same trajectory the refresh measured)
paths2 = []
for i in range(4):
    u, v, w = path(103)   # == the graph the refresh measured on
    paths2.append(MSFRequest(rid=rid, family="syn", u=u, v=v, w=w, n=n2))
    rid += 1
for r in paths2:
    gw2.submit(r)
gw2.run()
check(paths2)
assert all(r.served_via == "batched" for r in paths2), \
    [r.served_via for r in paths2]
assert gw2.stats.replans == 4 and gw2.stats.refreshes == 1, vars(gw2.stats)
print("OK")
"""


def test_gateway_multidevice():
    out = run_multidevice(GATEWAY, ndev=8, timeout=1800)
    assert "OK" in out


# -- batchmate failure attribution + rung deadlines (subprocess) -----------

BATCH_ATTRIBUTION = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (execute_plan_batched,
                                            plan_sharded_msf)

p = 8
n = 256
mesh = Mesh(np.array(jax.devices()), ("data",))
rng = np.random.default_rng(0)

# two same-shape batchmates, one good, one "corrupt" for the measured
# plan: a star converges in one round, a path of the same n and m
# needs ~log2 n — the plan strictly fits only the star lane
su = np.zeros(n - 1, np.int32)
sv = np.arange(1, n, dtype=np.int32)
pu = np.arange(0, n - 1, dtype=np.int32)
pv = np.arange(1, n, dtype=np.int32)
w1 = rng.uniform(1, 10, n - 1).astype(np.float32)
w2 = rng.uniform(1, 10, n - 1).astype(np.float32)
cap = max(1, -(-2 * (n - 1) // p))
star = build_dist_graph(su, sv, w1, n, p, cap=cap)[0]
path = build_dist_graph(pu, pv, w2, n, p, cap=cap)[0]
km_s, kw_s = oracle.kruskal(su, sv, w1, n)
km_p, kw_p = oracle.kruskal(pu, pv, w2, n)
plan = plan_sharded_msf(star, n, mesh)

def eids(g, res):
    return np.unique(np.asarray(g.eid)[np.asarray(res[0])])

# defer mode: ONLY the corrupt lane is flagged (None result); the good
# batchmate's forest is untouched — oracle-bit-identical
res, flagged = execute_plan_batched([star, path], n, mesh, plan,
                                    replan="defer", verify=True)
assert flagged == (1,), flagged
assert res[1] is None
assert np.array_equal(eids(star, res[0]), np.flatnonzero(km_s))
assert abs(float(res[0][1]) - kw_s) < 1e-3 * kw_s
assert int(res[0][4]) == 0

# lane order is attribution, not position: swap the batch
res2, flagged2 = execute_plan_batched([path, star], n, mesh, plan,
                                      replan="defer", verify=True)
assert flagged2 == (0,), flagged2
assert res2[0] is None
assert np.array_equal(eids(star, res2[1]), np.flatnonzero(km_s))

# strict mode raises naming exactly the corrupted index
try:
    execute_plan_batched([star, path], n, mesh, plan, replan=False,
                         verify=True)
    raise SystemExit("misfit lane was silent under replan=False")
except RuntimeError as e:
    assert "batch requests [1]" in str(e), e

# serving mode: the corrupt lane is still attributed in ``flagged``
# but comes back re-solved by its own measured pass — both lanes end
# oracle-exact, the good lane from the shared batched dispatch
res3, flagged3 = execute_plan_batched([star, path], n, mesh, plan,
                                      replan=True, verify=True)
assert flagged3 == (1,), flagged3
assert np.array_equal(eids(star, res3[0]), np.flatnonzero(km_s))
assert np.array_equal(eids(path, res3[1]), np.flatnonzero(km_p))
assert abs(float(res3[1][1]) - kw_p) < 1e-3 * kw_p
assert int(res3[1][4]) == 0
print("OK")
"""


@pytest.mark.slow
def test_batchmate_failure_attribution_multidevice():
    assert run_multidevice(BATCH_ATTRIBUTION, ndev=8,
                           timeout=900).strip().endswith("OK")


RUNG_DEADLINE = """
from jax.sharding import Mesh
from repro.core import oracle
from repro.serve.msf_gateway import MSFGateway, MSFRequest

p = 8
n = 256
mesh = Mesh(np.array(jax.devices()), ("data",))

def star(seed, rid, deadline=None):
    rng = np.random.default_rng(seed)
    return MSFRequest(rid=rid, family="syn", u=np.zeros(n - 1, np.int32),
                      v=np.arange(1, n, dtype=np.int32),
                      w=rng.uniform(1, 10, n - 1).astype(np.float32),
                      n=n, deadline=deadline)

def path(seed, rid, deadline=None):
    rng = np.random.default_rng(seed)
    return MSFRequest(rid=rid, family="syn",
                      u=np.arange(0, n - 1, dtype=np.int32),
                      v=np.arange(1, n, dtype=np.int32),
                      w=rng.uniform(1, 10, n - 1).astype(np.float32),
                      n=n, deadline=deadline)

# regression (ISSUE 9 bugfix): the entry sweep runs before the batched
# dispatch, so a request that was inside its deadline at step entry
# can be expired by the time its retry rung dispatches.  Cold gateway:
# the star heads the batch, the plan is measured on it (seconds of
# compile on this backend — far past the path's 1s budget), the path
# lane flags, and the rung's re-check must reject instead of serving
# late.  Pre-fix, the rung dispatched a strict replan and served a
# result past the deadline.
gw = MSFGateway(mesh, batch_slots=4, max_retries_per_request=3,
                breaker_threshold=99, min_samples=99)
s0 = star(0, 0)
p0 = path(1, 1, deadline=1.0)
gw.submit(s0)
gw.submit(p0)
gw.run()
assert s0.done and s0.served_via == "batched"
km, kw = oracle.kruskal(s0.u, s0.v, s0.w, n)
assert np.array_equal(s0.edges, np.flatnonzero(km))
assert p0.done and p0.served_via == "rejected", vars(p0)
assert "before retry dispatch" in p0.error, p0.error
assert gw.stats.deadline_missed == 1 and gw.stats.rejected == 1
assert gw.stats.retried == 1 and not gw.queue
# the rung rejection never consumed a replan or resumed a checkpoint
assert gw.stats.replans == 0 and gw.stats.resumed == 0

# same traffic with budget to spare serves via the ladder as before —
# the re-check only fires for genuinely expired requests
p1 = path(2, 2, deadline=600.0)
gw.submit(p1)
gw.run()
assert p1.done and p1.served_via == "replanned", vars(p1)
km, kw = oracle.kruskal(p1.u, p1.v, p1.w, n)
assert np.array_equal(p1.edges, np.flatnonzero(km))
assert gw.stats.deadline_missed == 1, vars(gw.stats)
print("OK")
"""


@pytest.mark.slow
def test_rung_deadline_recheck_multidevice():
    assert run_multidevice(RUNG_DEADLINE, ndev=8,
                           timeout=900).strip().endswith("OK")


# -- synthetic-plan calibration vs measured plans (subprocess) -------------

CALIBRATION = """
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph, shrink_schedule
from repro.core.distributed_sharded import plan_sharded_msf
from repro.core.plan import synthetic_plan

from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
for fam in ("gnm", "rgg2d"):
    u, v, w, n = generators.generate(fam, 4096, avg_degree=8.0, seed=3)
    g, cap = build_dist_graph(u, v, w, n, p)
    measured = plan_sharded_msf(g, n, mesh, axis_names=("data",))
    synth = synthetic_plan(n, g.cap_total, p, family=fam)
    assert synth.cap_per_shard == measured.cap_per_shard
    ladder = shrink_schedule(cap)
    m_caps = [r.cap_edge for r in measured.rounds if not r.sentinel]
    s_caps = [r.cap_edge for r in synth.rounds if not r.sentinel]
    # the calibrated trajectory tracks the measured plan within one
    # ladder rung, round for round (ISSUE 6 acceptance; the generic
    # halving ladder misses the gnm plateau by 3+ rungs mid-solve)
    for r, (mc, sc) in enumerate(zip(m_caps, s_caps)):
        mi, si = ladder.index(mc), ladder.index(sc)
        assert abs(mi - si) <= 1, (fam, r, mc, sc, m_caps, s_caps)
    print(fam, "measured", m_caps, "synthetic", s_caps[:len(m_caps)])
print("OK")
"""


def test_synthetic_plan_calibration_multidevice():
    out = run_multidevice(CALIBRATION, ndev=8, timeout=1800)
    assert "OK" in out


# -- the historical while_loop/argsort closure miscompile ------------------

MISCOMPILE = """
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

p, L = 8, 64
mesh = Mesh(np.array(jax.devices()), ("data",))
rng = np.random.default_rng(0)
keys = rng.integers(0, 1000, (p, L)).astype(np.int32)
vals = rng.integers(0, 1000, (p, L)).astype(np.int32)

def shard_fn(k, x):
    # the hazard pattern once noted on _vsorted_lookup: an argsort
    # permutation computed OUTSIDE a lax.while_loop, closed over, and
    # consumed by gathers/scatters INSIDE the body, under shard_map
    # with a routed exchange in the loop
    perm = jnp.argsort(k[0], stable=True)
    inv = jnp.zeros(L, jnp.int32).at[perm].set(
        jnp.arange(L, dtype=jnp.int32))
    expect = x[0][perm]

    def body(c):
        i, acc = c
        y = x[0][perm]
        y = lax.all_to_all(y.reshape(p, L // p), "data", 0, 0).reshape(L)
        y = lax.all_to_all(y.reshape(p, L // p), "data", 0, 0).reshape(L)
        z = jnp.zeros(L, jnp.int32).at[perm].add(y[inv][perm])
        return i + 1, acc + y + 0 * z[0]

    _, acc = lax.while_loop(lambda c: c[0] < 3, body,
                            (jnp.int32(0), jnp.zeros(L, jnp.int32)))
    return (acc - 3 * expect)[None]

fn = jax.jit(shard_map(shard_fn, mesh=mesh,
                       in_specs=(P("data"), P("data")),
                       out_specs=P("data")))
diff = int(np.abs(np.asarray(fn(keys, vals))).max())
assert diff == 0, f"closure-permutation gather corrupted {diff}"
print("OK")
"""


def _affected_generation() -> bool:
    import jax
    try:
        ver = tuple(int(x) for x in jax.__version__.split(".")[:3])
    except ValueError:
        return False
    return (0, 4, 0) <= ver < (0, 4, 37)


@pytest.mark.xfail(condition=_affected_generation(), strict=False,
                   reason="JAX 0.4.x CPU before 0.4.37 miscompiled "
                          "closed-over argsort perms gathered inside "
                          "while_loop bodies (historical note on "
                          "_vsorted_lookup); fixed by the pinned 0.4.37")
def test_while_loop_argsort_closure_repro():
    out = run_multidevice(MISCOMPILE, ndev=8, timeout=900)
    assert "OK" in out
