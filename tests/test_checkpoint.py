"""Round-level checkpointing and elastic resume (ISSUE 9).

In-process: the ``MSFCheckpoint`` value itself — per-shard CRC32
integrity (construction roundtrips; a byte flipped at rest is a typed
``CheckpointError`` naming the corrupted shard), the ``validate_for``
shape gate, the pure-numpy ``remap`` semantics (vertex state transfers
verbatim, the MSF mask is re-derived as the canonical ``u < v`` copy
per chosen eid, dead edges become exactly the label-internal slots),
and ``latest_certified``.

Subprocess (8 virtual devices): interrupted-then-resumed equals
uninterrupted, bit for bit — through the host driver (both
algorithms), the segmented planned executor (every cadence cut), and
the batched executor's shared skip-ahead; a ``ShardAbort`` injected
past the cadence recovers from the last certified checkpoint; and a
checkpoint taken on 8 shards restores onto 4- and 2-shard meshes with
the exact same MSF edge set (elastic restore)."""
import numpy as np
import pytest

from repro.core.msf_checkpoint import (CheckpointError, MSFCheckpoint,
                                       latest_certified)
from tests.helpers.subproc import run_multidevice


# -- the checkpoint value (in-process, no devices) --------------------------

def _small_ck(**over):
    """n=4 on p=2 shards (vps=2, cap/shard=3): components {0,1} and
    {2,3}, MSF eids {5, 7} chosen, one dead duplicate + padding."""
    kw = dict(
        n=4, num_shards=2, cap_per_shard=3, algorithm="boruvka",
        round_index=3, level=0, round_in_level=3, plan_pos=None,
        level_bounds=((0.0, 1.0),),
        lab=np.asarray([0, 0, 2, 2], np.int32),
        settled=np.asarray([True, False, False, False]),
        mask=np.asarray([True, False, True, False, False, False]),
        dead=np.asarray([False, True, False, False, True, True]),
        eid=np.asarray([5, 5, 7, 9, 0, 0], np.int32),
        ghost_on=True, stats_acc=np.zeros(8))
    kw.update(over)
    return MSFCheckpoint.create(**kw)


def test_create_roundtrips_and_derives_eids():
    ck = _small_ck()
    assert ck.verify_checksums() is ck
    assert np.array_equal(ck.eids, [5, 7])       # unique ids under mask
    assert ck.mst_count == 2
    assert ck.level_bounds == ((0.0, 1.0),)
    assert ck.checksums.shape == (2,)
    # compact repr, not an array dump
    r = repr(ck)
    assert "round=3" in r and "edges=2" in r and "[" not in r
    # create() copies: mutating the source arrays can't skew the snapshot
    src = np.asarray([0, 0, 2, 2], np.int32)
    ck2 = _small_ck(lab=src)
    src[0] = 99
    assert ck2.lab[0] == 0
    ck2.verify_checksums()


def test_corruption_at_rest_is_typed_and_names_the_shard():
    ck = _small_ck()
    ck.lab[3] ^= 1                    # vid 3 lives on shard 1 (vps=2)
    with pytest.raises(CheckpointError, match=r"\[1\]"):
        ck.verify_checksums()
    ck = _small_ck()
    ck.mask[0] = False                # slot 0 lives on shard 0
    with pytest.raises(CheckpointError, match=r"\[0\]"):
        ck.verify_checksums()
    ck = _small_ck()
    ck.dead[1] = False
    ck.settled[2] = True              # both shards touched
    with pytest.raises(CheckpointError, match=r"\[0, 1\]"):
        ck.verify_checksums()
    # CheckpointError is a RuntimeError: engine-level handlers hold
    assert issubclass(CheckpointError, RuntimeError)


def test_validate_for_shape_gate():
    ck = _small_ck()
    assert ck.validate_for(4, 2, 3) is ck
    for args in ((5, 2, 3), (4, 4, 3), (4, 2, 8)):
        with pytest.raises(CheckpointError, match="remap"):
            ck.validate_for(*args)
    # the gate re-checks content too, not just shapes
    ck.lab[0] ^= 1
    with pytest.raises(CheckpointError, match="checksum"):
        ck.validate_for(4, 2, 3)


def test_remap_rekeys_onto_a_smaller_mesh():
    ck = _small_ck()
    # re-partitioned at p'=1, cap'=6: both directed copies of eid 5 and
    # 9, the canonical copy of 7, and one padding slot (u=v=eid=0)
    u2 = np.asarray([0, 1, 2, 0, 2, 0], np.int32)
    v2 = np.asarray([1, 0, 3, 2, 0, 0], np.int32)
    e2 = np.asarray([5, 5, 7, 9, 9, 0], np.int32)
    rk = ck.remap(1, 6, u2, v2, e2)
    assert (rk.num_shards, rk.cap_per_shard) == (1, 6)
    # vertex state transfers verbatim on [:n]
    assert np.array_equal(rk.lab, [0, 0, 2, 2])
    assert np.array_equal(rk.settled, [True, False, False, False])
    # the MSF mask marks exactly the canonical u < v copy per chosen eid
    assert np.array_equal(rk.mask, [True, False, True, False, False,
                                    False])
    assert np.array_equal(rk.eids, ck.eids)
    # dead = label-internal edges (padding u=v=0 is label-internal too)
    assert np.array_equal(rk.dead, [True, True, True, False, False,
                                    True])
    # position and windows carry over; the new checkpoint is certified
    assert (rk.round_index, rk.level, rk.round_in_level) == (3, 0, 3)
    assert rk.level_bounds == ck.level_bounds
    rk.verify_checksums()
    rk.validate_for(4, 1, 6)


def test_remap_rejects_bad_slots_and_corruption():
    ck = _small_ck()
    u2 = np.zeros(5, np.int32)
    with pytest.raises(CheckpointError, match="slots"):
        ck.remap(1, 6, u2, u2, u2)    # 5 != p' * cap' = 6
    ck.settled[0] = False             # corrupt, then try to remap
    u6 = np.zeros(6, np.int32)
    with pytest.raises(CheckpointError, match="checksum"):
        ck.remap(1, 6, u6, u6, u6)


def test_latest_certified():
    assert latest_certified([]) is None
    a, b = _small_ck(round_index=2), _small_ck(round_index=4)
    assert latest_certified([a, b]) is b


# -- interrupted == uninterrupted, bit for bit (subprocess) -----------------

_GRAPH = """
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph

rng = np.random.default_rng({seed})
n, m = 256, 1024
u = rng.integers(0, n, m).astype(np.int32)
v = rng.integers(0, n, m).astype(np.int32)
keep = u != v
u, v = u[keep], v[keep]
w = rng.random(u.size).astype(np.float32)
mesh = Mesh(np.array(jax.devices()), ("data",))
g, cap = build_dist_graph(u, v, w, n, 8)
"""

CKPT_RESUME = _GRAPH.format(seed=0) + """
from repro.core.distributed_sharded import (
    distributed_sharded_msf, execute_plan, execute_plan_batched,
    plan_sharded_msf)

# host driver: checkpointing changes nothing, resume from every
# checkpoint is bit-identical (mask, weight, count, labels, rounds)
base = distributed_sharded_msf(g, n, mesh)
cks = []
out = distributed_sharded_msf(g, n, mesh, ckpt_every=2, ckpt_out=cks)
assert np.array_equal(np.asarray(out[0]), np.asarray(base[0]))
assert cks, "no certified checkpoints at cadence 2"
for ck in cks:
    res = distributed_sharded_msf(g, n, mesh, resume_from=ck)
    assert np.array_equal(np.asarray(res[0]), np.asarray(base[0])), ck
    assert float(res[1]) == float(base[1])
    assert int(res[2]) == int(base[2])
    assert np.array_equal(np.asarray(res[3]), np.asarray(base[3]))
    assert int(res[5].rounds) == int(base[5].rounds)

# filter_boruvka drives level windows through the checkpoint too
base_f = distributed_sharded_msf(g, n, mesh, algorithm="filter_boruvka")
cks_f = []
distributed_sharded_msf(g, n, mesh, algorithm="filter_boruvka",
                        ckpt_every=2, ckpt_out=cks_f)
assert cks_f
for ck in cks_f:
    res = distributed_sharded_msf(g, n, mesh, algorithm="filter_boruvka",
                                  resume_from=ck)
    assert np.array_equal(np.asarray(res[0]), np.asarray(base_f[0])), ck

# the planned executor segments at cadence cuts; resume skips ahead
plan = plan_sharded_msf(g, n, mesh)
pbase = execute_plan(g, n, mesh, plan, replan=False)
cks_p = []
pout = execute_plan(g, n, mesh, plan, replan=False, ckpt_every=2,
                    ckpt_out=cks_p)
assert np.array_equal(np.asarray(pout[0]), np.asarray(pbase[0]))
assert float(pout[1]) == float(pbase[1])
assert cks_p and all(c.plan_pos is not None for c in cks_p)
for ck in cks_p:
    res = execute_plan(g, n, mesh, plan, replan=False, resume_from=ck)
    assert np.array_equal(np.asarray(res[0]), np.asarray(pbase[0])), ck
    assert float(res[1]) == float(pbase[1])
    assert np.array_equal(np.asarray(res[3]), np.asarray(pbase[3]))

# a driver checkpoint (plan_pos=None) cannot drive plan skip-ahead
try:
    execute_plan(g, n, mesh, plan, replan=False, resume_from=cks[0])
    raise SystemExit("driver checkpoint accepted for plan skip-ahead")
except RuntimeError as e:
    assert "plan" in str(e)

# checkpointing through the non-host paths is a loud ValueError
try:
    distributed_sharded_msf(g, n, mesh, plan=plan, ckpt_every=2,
                            ckpt_out=[])
    raise SystemExit("plan-path checkpointing accepted")
except ValueError as e:
    assert "execute_plan" in str(e)
try:
    distributed_sharded_msf(g, n, mesh, shrink_capacities=False,
                            ckpt_every=2, ckpt_out=[])
    raise SystemExit("fused-path checkpointing accepted")
except ValueError as e:
    assert "shrinking" in str(e)

# batched skip-ahead: both batchmates resume at the shared plan_pos and
# land bit-identical to the full batched run
g2, _ = build_dist_graph(u, v, (w * 1.7 + 0.1).astype(np.float32), n, 8,
                         cap=cap)
full, bad = execute_plan_batched([g, g2], n, mesh, plan, replan=False)
cks_p2 = []
execute_plan(g2, n, mesh, plan, replan=False, ckpt_every=2,
             ckpt_out=cks_p2)
pos = cks_p[0].plan_pos
ck1 = next(c for c in cks_p if c.plan_pos == pos)
ck2 = next(c for c in cks_p2 if c.plan_pos == pos)
res_b, bad_b = execute_plan_batched([g, g2], n, mesh, plan,
                                    replan=False, resume_from=[ck1, ck2])
assert bad_b == bad
for i in range(2):
    assert np.array_equal(np.asarray(res_b[i][0]),
                          np.asarray(full[i][0])), i
    assert float(res_b[i][1]) == float(full[i][1])
print("OK")
"""


@pytest.mark.slow
def test_checkpoint_resume_bit_identity_multidevice():
    assert run_multidevice(CKPT_RESUME, ndev=8,
                           timeout=900).strip().endswith("OK")


ABORT_RESUME = _GRAPH.format(seed=5) + """
from repro.comm import faults
from repro.comm.faults import FaultPlan, FaultSpec, ShardAbort
from repro.core.distributed_sharded import distributed_sharded_msf

base = distributed_sharded_msf(g, n, mesh)

# kill the exchange at round 3 — one round past the cadence, so a
# certified checkpoint exists when the shard dies
plan = FaultPlan(seed=0, specs=(
    FaultSpec(kind="abort", site="minedges", rounds=(3,)),))
cks = []
try:
    with faults.inject(plan):
        distributed_sharded_msf(g, n, mesh, ckpt_every=2, ckpt_out=cks)
    raise SystemExit("abort did not fire")
except ShardAbort as e:
    assert "minedges" in str(e) and "round 3" in str(e), e
assert cks, "no checkpoint certified before the abort"
ck = cks[-1]
assert ck.round_index == 2

# resume outside the injection: bit-identical, and the re-executed
# rounds are bounded by the cadence
res = distributed_sharded_msf(g, n, mesh, resume_from=ck)
assert np.array_equal(np.asarray(res[0]), np.asarray(base[0]))
assert float(res[1]) == float(base[1])
assert int(res[5].rounds) == int(base[5].rounds)
re_exec = 3 - 1 - ck.round_index
assert 0 <= re_exec <= 2, re_exec
print("OK")
"""


@pytest.mark.slow
def test_abort_then_resume_multidevice():
    assert run_multidevice(ABORT_RESUME, ndev=8,
                           timeout=900).strip().endswith("OK")


ELASTIC = _GRAPH.format(seed=3) + """
from repro.core.distributed_sharded import distributed_sharded_msf

g8 = g
base = distributed_sharded_msf(g8, n, mesh)
base_eids = np.unique(np.asarray(g8.eid)[np.asarray(base[0])])
cks = []
distributed_sharded_msf(g8, n, mesh, ckpt_every=2, ckpt_out=cks)
assert cks

# restore every 8-shard checkpoint onto a 4-shard mesh: re-owner-map
# the vertex state, re-partition the edges from the host store — the
# finished forest is the exact same undirected edge set
mesh4 = Mesh(np.array(jax.devices()[:4]), ("data",))
g4, cap4 = build_dist_graph(u, v, w, n, 4)
for ck in cks:
    ck2 = ck.remap(4, cap4, np.asarray(g4.u), np.asarray(g4.v),
                   np.asarray(g4.eid))
    res = distributed_sharded_msf(g4, n, mesh4, resume_from=ck2)
    eids = np.unique(np.asarray(g4.eid)[np.asarray(res[0])])
    assert np.array_equal(eids, base_eids), ck
    assert int(res[4]) == 0

# filter_boruvka's frozen windows survive an 8 -> 2 shrink too
mesh2 = Mesh(np.array(jax.devices()[:2]), ("data",))
basef = distributed_sharded_msf(g8, n, mesh, algorithm="filter_boruvka")
basef_eids = np.unique(np.asarray(g8.eid)[np.asarray(basef[0])])
cksf = []
distributed_sharded_msf(g8, n, mesh, algorithm="filter_boruvka",
                        ckpt_every=2, ckpt_out=cksf)
g2c, cap2c = build_dist_graph(u, v, w, n, 2)
for ck in cksf:
    ck2 = ck.remap(2, cap2c, np.asarray(g2c.u), np.asarray(g2c.v),
                   np.asarray(g2c.eid))
    res = distributed_sharded_msf(g2c, n, mesh2,
                                  algorithm="filter_boruvka",
                                  resume_from=ck2)
    eids = np.unique(np.asarray(g2c.eid)[np.asarray(res[0])])
    assert np.array_equal(eids, basef_eids), ck
print("OK")
"""


@pytest.mark.slow
def test_elastic_restore_multidevice():
    assert run_multidevice(ELASTIC, ndev=8,
                           timeout=900).strip().endswith("OK")
