"""Distributed MSF vs Kruskal oracle on 8 virtual devices (subprocess)."""
import pytest

from tests.helpers.subproc import run_multidevice

BODY = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.distributed import build_dist_graph, distributed_msf
from repro.core import oracle
from repro.data import generators

mesh1d = Mesh(np.array(jax.devices()), ("data",))
mesh2d = Mesh(np.array(jax.devices()).reshape(4, 2), ("row", "col"))

cases = []
for fam, n in [("gnm", 512), ("grid2d", 1024), ("rmat", 512), ("rgg2d", 800)]:
    u, v, w, nn = generators.generate(fam, n, avg_degree=8.0, seed=3)
    cases.append((fam, u, v, w, nn))
# adversarial: heavy ties
rng = np.random.default_rng(0)
u = rng.integers(0, 300, 2000).astype(np.int32)
v = rng.integers(0, 300, 2000).astype(np.int32)
keep = u != v
w = rng.integers(1, 6, keep.sum()).astype(np.float32)
cases.append(("ties", u[keep], v[keep], w, 300))

for mesh, axes, nsh in [(mesh1d, ("data",), 8), (mesh2d, ("row", "col"), 8)]:
    for fam, u, v, w, n in cases:
        g, cap = build_dist_graph(u, v, w, n, nsh)
        _, expect = oracle.kruskal(u, v, w, n)
        ncomp = len(np.unique(oracle.component_labels(u, v, n)))
        for algo in ("boruvka", "filter_boruvka"):
            for pre in (True, False):
                with mesh:
                    mask, wt, cnt, labels, stats = distributed_msf(
                        g, n, mesh, algorithm=algo, axis_names=axes,
                        local_preprocessing=pre)
                assert abs(float(wt) - expect) < 1e-3 * max(1.0, expect), (
                    fam, algo, pre, axes, float(wt), expect)
                assert int(cnt) == n - ncomp, (fam, algo, pre, int(cnt),
                                               n - ncomp)
                # the marked edges must form a forest
                mk = np.asarray(mask)
                gu = np.asarray(g.u)[mk]
                gv = np.asarray(g.v)[mk]
                assert oracle.is_forest(gu, gv, n), (fam, algo, pre)
                # labels are consistent component representatives
                lab = np.asarray(labels)
                ref = oracle.component_labels(u, v, n)
                groups = {}
                for vert in range(n):
                    groups.setdefault(ref[vert], set()).add(lab[vert])
                for k, s in groups.items():
                    assert len(s) == 1, (fam, algo, "labels split a component")
print("OK")
"""

PREPROCESSING_EFFECT = """
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core.distributed import (build_dist_graph, _local_preprocessing)
from repro.data import generators
import jax.numpy as jnp

mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("grid2d", 4096, seed=5)
g, cap = build_dist_graph(u, v, w, n, 8)

def body(uu, vv, ww, ee):
    valid = jnp.isfinite(ww)
    labels, mst = _local_preprocessing(uu, vv, ww, ee, valid, n, ("data",))
    return jax.lax.psum(mst.sum(), ("data",)), labels

f = shard_map(body, mesh=mesh, in_specs=(P("data"),) * 4,
              out_specs=(P(), P()))
contracted, labels = f(g.u, g.v, g.w, g.eid)
# a 64x64 grid split into 8 shards has mostly-local edges: the comm-free
# phase must contract the bulk of the tree (paper: up to 5x fewer rounds)
assert int(contracted) > n // 2, int(contracted)
# local preprocessing must only produce valid MST edges: weight of final
# MSF must match when continuing (covered by BODY test); here check the
# contraction count is sane (< n)
assert int(contracted) < n, int(contracted)
print("OK")
"""


def test_distributed_msf_correctness():
    out = run_multidevice(BODY, ndev=8, timeout=900)
    assert "OK" in out


def test_local_preprocessing_contracts_local_graphs():
    out = run_multidevice(PREPROCESSING_EFFECT, ndev=8)
    assert "OK" in out
