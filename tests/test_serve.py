"""Serving engine: batched decode slots, prompt prefill, refill."""
import collections
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def test_engine_completes_requests():
    cfg = get_arch("qwen2-1.5b").smoke
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new=5)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        assert len(r.out) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_request_pending_is_declared_field():
    """ISSUE 6 regression: ``_pending`` used to be injected onto
    Request instances by ``_fill_slots`` — undeclared, so dataclass
    tooling (replace/asdict/fields) never saw it and a request object
    grew engine-private state only after admission."""
    names = {f.name for f in dataclasses.fields(Request)}
    assert "_pending" in names
    r = Request(rid=0, prompt=[1, 2])
    assert r._pending == []           # present before any engine touch
    assert dataclasses.replace(r, rid=1)._pending == []


def test_engine_rejects_empty_prompt_and_admits_fifo():
    """ISSUE 6 regression: an empty prompt used to IndexError at
    ``req.prompt[-1]`` mid-``step()`` — after admission, killing the
    whole batch; now it is rejected at ``submit``.  Also pins the
    deque-based O(1) FIFO admission."""
    cfg = get_arch("qwen2-1.5b").smoke
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    assert isinstance(eng.queue, collections.deque)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=9, prompt=[]))
    assert not eng.queue              # rejected before queueing
    reqs = [Request(rid=i, prompt=[i + 1], max_new=2) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    assert [r.rid for r in eng.queue] == [0, 1, 2]
    eng.run()
    assert all(r.done and len(r.out) == 2 for r in reqs)


def test_engine_greedy_matches_manual_decode():
    cfg = get_arch("llama3.2-3b").smoke
    params = init_params(cfg, jax.random.key(1))
    prompt = [5, 9, 2]
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    r = Request(rid=0, prompt=list(prompt), max_new=4)
    eng.submit(r)
    eng.run()
    # manual: feed prompt through decode path then greedy-decode 4
    from repro.models.model import forward_decode, init_caches
    import jax.numpy as jnp
    caches = init_caches(cfg, 1, 32)
    step = jax.jit(lambda p, c, t, q: forward_decode(cfg, p, c, t, q))
    pos = 0
    logits = None
    for t in prompt:
        logits, caches = step(params, caches, jnp.asarray([t], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    out = []
    for _ in range(4):
        nxt = int(np.asarray(logits)[0].argmax())
        out.append(nxt)
        logits, caches = step(params, caches,
                              jnp.asarray([nxt], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    assert r.out == out, (r.out, out)
