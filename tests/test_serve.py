"""Serving engine: batched decode slots, prompt prefill, refill."""
import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def test_engine_completes_requests():
    cfg = get_arch("qwen2-1.5b").smoke
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new=5)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        assert len(r.out) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_engine_greedy_matches_manual_decode():
    cfg = get_arch("llama3.2-3b").smoke
    params = init_params(cfg, jax.random.key(1))
    prompt = [5, 9, 2]
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    r = Request(rid=0, prompt=list(prompt), max_new=4)
    eng.submit(r)
    eng.run()
    # manual: feed prompt through decode path then greedy-decode 4
    from repro.models.model import forward_decode, init_caches
    import jax.numpy as jnp
    caches = init_caches(cfg, 1, 32)
    step = jax.jit(lambda p, c, t, q: forward_decode(cfg, p, c, t, q))
    pos = 0
    logits = None
    for t in prompt:
        logits, caches = step(params, caches, jnp.asarray([t], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    out = []
    for _ in range(4):
        nxt = int(np.asarray(logits)[0].argmax())
        out.append(nxt)
        logits, caches = step(params, caches,
                              jnp.asarray([nxt], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    assert r.out == out, (r.out, out)
