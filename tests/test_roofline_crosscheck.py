"""Cross-check the replicated mesh engine's *analytic* comm counters
(CommStats derived from round counts, core/distributed.py) against the
HLO collective-bytes extraction of launch/roofline.py (ROADMAP open
item; ISSUE 3 satellite).

The engine claims its per-round traffic is exactly 3 allreduced
n-vectors (wmin f32, emin i32, other i32) plus the preprocessing label
combine and two tiny boundary all_gathers.  The roofline parser reads
the same program's compiled HLO and weights while-loop bodies by their
trip count, so pinning ``max_rounds`` to the measured round count makes
the two views directly comparable.  Residual skew (the final
weight/count scalar reductions the analytic side deliberately excludes,
and any compiler-materialized masks) is documented in EXPERIMENTS.md
§Roofline cross-check and bounded here.
"""
import pytest

from tests.helpers.subproc import run_multidevice

CROSSCHECK = """
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph, distributed_msf, \
    make_mst_step
from repro.launch.roofline import collective_bytes_from_hlo
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("gnm", 512, avg_degree=8.0, seed=3)
g, cap = build_dist_graph(u, v, w, n, p)

mask, wt, cnt, lab, st = distributed_msf(g, n, mesh, axis_names=("data",))
rounds = int(st.rounds)
analytic_bytes = float(st.bytes)
assert rounds > 0 and analytic_bytes > 0

# AOT-compile the same program pinned to the measured round count so the
# HLO parser's while-loop trip weighting equals the executed rounds
step, specs = make_mst_step(n, g.cap_total, mesh, algorithm="boruvka",
                            axis_names=("data",), max_rounds=rounds)
compiled = jax.jit(step).lower(*specs).compile()
coll = collective_bytes_from_hlo(compiled.as_text())
hlo_bytes = coll["all-reduce_bytes"] + coll["all-gather_bytes"]
ratio = hlo_bytes / analytic_bytes
print("rounds", rounds, "analytic_bytes", analytic_bytes,
      "hlo_bytes", hlo_bytes, "ratio", round(ratio, 4))
print("hlo_counts", {k: v for k, v in coll.items()
                     if k.endswith("_count") and v})
# known skew: the two one-off weight/count scalar reductions (excluded
# from the analytic side by contract) and compiler-materialized booleans
# -- small against the 12n bytes/round term.  A parser or counter
# regression (double counting, wrong trip weighting) lands far outside
# this band.
assert 0.7 < ratio < 1.5, (analytic_bytes, hlo_bytes, ratio)
print("OK")
"""


def test_replicated_analytic_counters_match_hlo():
    out = run_multidevice(CROSSCHECK, ndev=8, timeout=900)
    assert "OK" in out


PLAN_CROSSCHECK = """
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (make_sharded_mst_step,
                                            plan_sharded_msf)
from repro.launch.roofline import collective_bytes_from_hlo, plan_summary
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
sh = NamedSharding(mesh, P("data"))
u, v, w, n = generators.generate("gnm", 512, avg_degree=8.0, seed=3)
g, cap = build_dist_graph(u, v, w, n, p)

# config note: the two data-dependent while loops are avoided so the
# HLO parser's trip weighting is exact — preprocessing off (its
# contraction loop's trip count is data-dependent) and fixed-schedule
# doubling (fori_loop: constant trip = log2(n), executed exactly);
# everything else, ghost cache included, is straight-line in the
# unrolled planned program.
plan = plan_sharded_msf(g, n, mesh, axis_names=("data",),
                        local_preprocessing=False,
                        adaptive_doubling=False)
step, specs = make_sharded_mst_step(n, g.cap_total, mesh, plan=plan)
compiled = jax.jit(step, in_shardings=(sh,) * 4).lower(*specs).compile()
out = compiled(g.u, g.v, g.w, g.eid)
assert int(out[4]) == 0, int(out[4])
kmask, kweight = oracle.kruskal(u, v, w, n)
sel = np.unique(np.asarray(g.eid)[np.asarray(out[0])])
assert np.array_equal(sel, np.nonzero(kmask)[0])
st = out[5]

coll = collective_bytes_from_hlo(compiled.as_text())
# ExchangeStats.bytes books every routed exchange's capacity-padded
# [p, C, ...] buffers (x hop count); the HLO side is the operand bytes
# of the module's all-to-alls, trip-weighted.  Same quantity, measured
# from opposite ends of the compiler.
analytic_bytes = float(st.bytes)
hlo_bytes = coll["all-to-all_bytes"]
ratio = hlo_bytes / analytic_bytes
# ... and ExchangeStats.calls books one invocation per buffer per hop,
# the HLO parser counts trip-weighted all-to-all ops
calls_ratio = coll["all-to-all_count"] / float(int(st.calls))
print("rounds", plan.num_rounds, "analytic_bytes", analytic_bytes,
      "hlo_bytes", hlo_bytes, "ratio", round(ratio, 4),
      "calls", int(st.calls), "hlo_count", coll["all-to-all_count"],
      "calls_ratio", round(calls_ratio, 4))
print("plan_summary", {k: v for k, v in plan_summary(plan).items()
                       if k.endswith(("_sum", "shrink"))})
# same skew tolerance as the replicated-engine crosscheck: residual
# slack comes only from compiler-materialized reshapes, so a counter or
# parser regression (double counting, wrong trip weights, a phase
# booking slots twice) lands far outside this band
assert 0.7 < ratio < 1.5, (analytic_bytes, hlo_bytes, ratio)
assert 0.7 < calls_ratio < 1.5, (int(st.calls), coll["all-to-all_count"])
print("OK")
"""


def test_planned_program_counters_match_hlo():
    """ISSUE 5 satellite: the unrolled plan path's ExchangeStats
    hops/slots accounting vs the HLO collective parser, same tolerance
    as the replicated engine."""
    out = run_multidevice(PLAN_CROSSCHECK, ndev=8, timeout=900)
    assert "OK" in out


PUSH_CROSSCHECK = """
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import oracle
from repro.core.distributed import build_dist_graph
from repro.core.distributed_sharded import (make_sharded_mst_step,
                                            plan_sharded_msf)
from repro.launch.roofline import collective_bytes_from_hlo
from repro.data import generators

# ISSUE 10 satellite: the ghost PUSH path's accounting, both shapes of
# it, against the HLO parser.  Two unrolled planned programs on the
# same (4, 2) mesh — flat push (one [p, cap] multicast) and grid push
# (owner->deputy [C, cap_row] then deputy->rows [R, cap_col]) — each
# must keep ExchangeStats bytes/calls within the standard band of the
# compiled module's trip-weighted all-to-alls.  A deputy leg booked
# zero times (or twice) lands far outside 0.7..1.5.
p = 8
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("row", "col"))
AX = ("row", "col")
sh = NamedSharding(mesh, P(AX))
u, v, w, n = generators.generate("gnm", 512, avg_degree=8.0, seed=3)
g, cap = build_dist_graph(u, v, w, n, p)
kmask, _ = oracle.kruskal(u, v, w, n)

for push in ("flat", "grid"):
    plan = plan_sharded_msf(g, n, mesh, axis_names=AX,
                            local_preprocessing=False,
                            adaptive_doubling=False, ghost_push=push)
    assert plan.ghost is not None, push  # the push path must be live
    assert plan.grid_push == (push == "grid")
    step, specs = make_sharded_mst_step(n, g.cap_total, mesh, plan=plan,
                                        axis_names=AX)
    compiled = jax.jit(step, in_shardings=(sh,) * 4).lower(*specs).compile()
    out = compiled(g.u, g.v, g.w, g.eid)
    assert int(out[4]) == 0, (push, int(out[4]))
    sel = np.unique(np.asarray(g.eid)[np.asarray(out[0])])
    assert np.array_equal(sel, np.nonzero(kmask)[0]), push
    st = out[5]
    coll = collective_bytes_from_hlo(compiled.as_text())
    ratio = coll["all-to-all_bytes"] / float(st.bytes)
    calls_ratio = coll["all-to-all_count"] / float(int(st.calls))
    print(push, "analytic_bytes", float(st.bytes),
          "hlo_bytes", coll["all-to-all_bytes"], "ratio", round(ratio, 4),
          "calls", int(st.calls), "hlo_count", coll["all-to-all_count"],
          "calls_ratio", round(calls_ratio, 4))
    assert 0.7 < ratio < 1.5, (push, float(st.bytes),
                               coll["all-to-all_bytes"], ratio)
    assert 0.7 < calls_ratio < 1.5, (push, int(st.calls),
                                     coll["all-to-all_count"])
print("OK")
"""


def test_push_path_counters_match_hlo():
    """ISSUE 10 satellite: HLO-parsed all-to-all bytes vs the analytic
    counters on the ghost push path, flat and grid."""
    out = run_multidevice(PUSH_CROSSCHECK, ndev=8, timeout=900)
    assert "OK" in out
