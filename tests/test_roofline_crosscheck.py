"""Cross-check the replicated mesh engine's *analytic* comm counters
(CommStats derived from round counts, core/distributed.py) against the
HLO collective-bytes extraction of launch/roofline.py (ROADMAP open
item; ISSUE 3 satellite).

The engine claims its per-round traffic is exactly 3 allreduced
n-vectors (wmin f32, emin i32, other i32) plus the preprocessing label
combine and two tiny boundary all_gathers.  The roofline parser reads
the same program's compiled HLO and weights while-loop bodies by their
trip count, so pinning ``max_rounds`` to the measured round count makes
the two views directly comparable.  Residual skew (the final
weight/count scalar reductions the analytic side deliberately excludes,
and any compiler-materialized masks) is documented in EXPERIMENTS.md
§Roofline cross-check and bounded here.
"""
import pytest

from tests.helpers.subproc import run_multidevice

CROSSCHECK = """
from jax.sharding import Mesh
from repro.core.distributed import build_dist_graph, distributed_msf, \
    make_mst_step
from repro.launch.roofline import collective_bytes_from_hlo
from repro.data import generators

p = 8
mesh = Mesh(np.array(jax.devices()), ("data",))
u, v, w, n = generators.generate("gnm", 512, avg_degree=8.0, seed=3)
g, cap = build_dist_graph(u, v, w, n, p)

mask, wt, cnt, lab, st = distributed_msf(g, n, mesh, axis_names=("data",))
rounds = int(st.rounds)
analytic_bytes = float(st.bytes)
assert rounds > 0 and analytic_bytes > 0

# AOT-compile the same program pinned to the measured round count so the
# HLO parser's while-loop trip weighting equals the executed rounds
step, specs = make_mst_step(n, g.cap_total, mesh, algorithm="boruvka",
                            axis_names=("data",), max_rounds=rounds)
compiled = jax.jit(step).lower(*specs).compile()
coll = collective_bytes_from_hlo(compiled.as_text())
hlo_bytes = coll["all-reduce_bytes"] + coll["all-gather_bytes"]
ratio = hlo_bytes / analytic_bytes
print("rounds", rounds, "analytic_bytes", analytic_bytes,
      "hlo_bytes", hlo_bytes, "ratio", round(ratio, 4))
print("hlo_counts", {k: v for k, v in coll.items()
                     if k.endswith("_count") and v})
# known skew: the two one-off weight/count scalar reductions (excluded
# from the analytic side by contract) and compiler-materialized booleans
# -- small against the 12n bytes/round term.  A parser or counter
# regression (double counting, wrong trip weighting) lands far outside
# this band.
assert 0.7 < ratio < 1.5, (analytic_bytes, hlo_bytes, ratio)
print("OK")
"""


def test_replicated_analytic_counters_match_hlo():
    out = run_multidevice(CROSSCHECK, ndev=8, timeout=900)
    assert "OK" in out
