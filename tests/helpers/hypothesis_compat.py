"""Single-source hypothesis shim for property-based tests.

``from tests.helpers.hypothesis_compat import given, settings, st`` and
decorate unconditionally: with hypothesis installed these are the real
decorators; without it (a dev-only dep, see requirements-dev.txt) the
stand-in ``given`` marks the test skipped with a visible reason and the
plain tests in the module keep running.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed "
                   "(pip install -r requirements-dev.txt)")(f)

    def settings(*a, **k):
        return lambda f: f

    class _St:
        """Strategy namespace stub: every attribute is a no-op factory.

        Only sound for strategies referenced *inside* decorator argument
        lists of skipped tests; anything executed at module import time
        (e.g. ``st.composite`` applied to a function) needs a real guard
        on HAVE_HYPOTHESIS instead.
        """

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()
