"""Run JAX code under N virtual CPU devices in a subprocess.

JAX locks the device count at first backend init, and the spec forbids
forcing a global device count on the main test process (smoke tests must
see 1 device).  Multi-device tests therefore execute in a child process
with XLA_FLAGS set before the jax import.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_multidevice(body: str, ndev: int = 8, timeout: int = 600) -> str:
    """Execute ``body`` (python source) with ``ndev`` virtual devices.

    The body runs after ``import jax`` etc.; raise / assert inside it to
    fail.  Returns captured stdout.  The script must print OK as its last
    action for the caller to assert on.
    """
    prelude = textwrap.dedent(f"""
        import os
        # drop any inherited device-count flag (e.g. CI exports one for
        # directly-run snippets) so this script's count always wins
        inherited = [f for f in os.environ.get("XLA_FLAGS", "").split()
                     if "xla_force_host_platform_device_count" not in f]
        os.environ["XLA_FLAGS"] = " ".join(
            ["--xla_force_host_platform_device_count={ndev}"] + inherited)
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.compat import shard_map  # version-bridged (see repro/compat.py)
        assert jax.device_count() == {ndev}, jax.device_count()
    """)
    script = prelude + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
