"""Adversarial graph families shared by the cross-engine oracle matrix.

Used in-process by tests/test_engine_equivalence.py and injected into
its multi-device subprocess via ``inspect.getsource`` so both matrices
are guaranteed to test the *same* graphs.  Self-contained on purpose:
only numpy at module scope, generators imported lazily (the subprocess
injects this source before its own imports).
"""
import numpy as np


def fam_random(seed, n=256, m=1500):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    keep = u != v
    w = rng.uniform(1.0, 255.0, keep.sum()).astype(np.float32)
    return u[keep], v[keep], w, n


def fam_clustered(seed):
    from repro.data import generators
    return generators.generate("rmat", 256, avg_degree=8.0, seed=seed)


def fam_dup_weights(seed, n=200, m=1600):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    keep = u != v
    w = rng.integers(1, 6, keep.sum()).astype(np.float32)  # heavy ties
    return u[keep], v[keep], w, n


def fam_disconnected(seed, blocks=3, bn=64):
    rng = np.random.default_rng(seed)
    us, vs = [], []
    for b in range(blocks):
        lo = b * bn
        u = rng.integers(lo, lo + bn, 200)
        v = rng.integers(lo, lo + bn, 200)
        keep = u != v
        us.append(u[keep])
        vs.append(v[keep])
    u = np.concatenate(us).astype(np.int32)
    v = np.concatenate(vs).astype(np.int32)
    w = rng.uniform(1.0, 255.0, len(u)).astype(np.float32)
    # + isolated vertices beyond the blocks
    return u, v, w, blocks * bn + 16


def fam_selfloops(seed, n=180, m=1200):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    keep = u != v
    u, v = u[keep], v[keep]
    w = rng.uniform(10.0, 255.0, len(u)).astype(np.float32)
    # self-loops LIGHTER than every real edge: any engine that fails to
    # exclude them would prefer them in the min-reduction
    sl = rng.integers(0, n, 40).astype(np.int32)
    u = np.concatenate([u, sl])
    v = np.concatenate([v, sl])
    w = np.concatenate([w, np.full(40, 0.5, np.float32)])
    return u, v, w, n


FAMILIES = {
    "random": fam_random,
    "clustered": fam_clustered,
    "dup_weights": fam_dup_weights,
    "disconnected": fam_disconnected,
    "selfloops": fam_selfloops,
}
