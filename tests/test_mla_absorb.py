"""Absorbed-weight MLA decode == naive MLA decode (fp32, exact math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models.model import forward_decode, init_caches, init_params


def test_mla_absorbed_decode_matches_naive():
    cfg = dataclasses.replace(get_arch("deepseek-v2-236b").smoke,
                              dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    B, T = 2, 16
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, 6)).astype(np.int32)

    def run(c):
        caches = init_caches(c, B, T)
        step = jax.jit(lambda p, cc, t, q: forward_decode(c, p, cc, t, q))
        logits = None
        for t in range(6):
            logits, caches = step(params, caches,
                                  jnp.asarray(toks[:, t]),
                                  jnp.full((B,), t, jnp.int32))
        return np.asarray(logits, np.float32)

    naive = run(cfg)
    absorbed = run(dataclasses.replace(cfg, mla_absorb=True))
    np.testing.assert_allclose(absorbed, naive, atol=1e-5, rtol=1e-5)
