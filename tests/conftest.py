"""Shared test configuration.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  The
modules that use it guard the import themselves and skip only their
property-based tests when it is absent (plain tests keep running); this
conftest just makes the degraded mode visible in the report header.
"""

def pytest_report_header(config):
    from tests.helpers.hypothesis_compat import HAVE_HYPOTHESIS
    if not HAVE_HYPOTHESIS:
        return ("hypothesis not installed - property-based tests are "
                "skipped (pip install -r requirements-dev.txt)")
    return None
