"""Core MSF correctness: jittable Borůvka + Filter-Borůvka vs Kruskal oracle."""
import numpy as np
import pytest

from tests.helpers.hypothesis_compat import given, settings, st

from repro.core import oracle
from repro.core.boruvka import boruvka_msf
from repro.core.filter_boruvka import (boruvka_dynamic,
                                       filter_boruvka_dynamic,
                                       filter_boruvka_msf)
from repro.core.graph import from_numpy
from repro.core.mst import minimum_spanning_forest
from repro.data import generators


def _random_graph(n, m, seed, int_weights=False):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    keep = u != v
    u, v = u[keep], v[keep]
    if int_weights:  # many ties
        w = rng.integers(1, 8, len(u)).astype(np.float32)
    else:
        w = rng.uniform(1, 255, len(u)).astype(np.float32)
    return u, v, w


def _check(u, v, w, n, mask):
    mask = np.asarray(mask)
    _, expect = oracle.kruskal(u, v, w, n)
    got = float(w[mask].sum())
    assert got == pytest.approx(expect, rel=1e-5), (got, expect)
    # forest invariant
    assert oracle.is_forest(u[mask], v[mask], n)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("algo", ["boruvka", "filter_boruvka"])
def test_static_engine_random(seed, algo):
    u, v, w = _random_graph(200, 800, seed)
    edges = from_numpy(u, v, w, 200)
    mask, wt = minimum_spanning_forest(edges, algorithm=algo, engine="static")
    _check(u, v, w, 200, mask)


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("algo", ["boruvka", "filter_boruvka"])
def test_dynamic_engine_random(seed, algo):
    u, v, w = _random_graph(300, 1500, seed)
    edges = from_numpy(u, v, w, 300)
    mask, wt = minimum_spanning_forest(edges, algorithm=algo, engine="dynamic")
    _check(u, v, w, 300, np.asarray(mask))


@pytest.mark.parametrize("algo", ["boruvka", "filter_boruvka"])
def test_ties(algo):
    """Heavily tied integer weights must still give the oracle weight."""
    u, v, w = _random_graph(100, 600, 7, int_weights=True)
    edges = from_numpy(u, v, w, 100)
    mask, _ = minimum_spanning_forest(edges, algorithm=algo, engine="static")
    _check(u, v, w, 100, mask)


def test_padding_is_ignored():
    u, v, w = _random_graph(50, 200, 3)
    edges = from_numpy(u, v, w, 50, pad_to=512)
    mask, wt = minimum_spanning_forest(edges, engine="static")
    _, expect = oracle.kruskal(u, v, w, 50)
    assert float(wt) == pytest.approx(expect, rel=1e-5)
    assert not np.asarray(mask)[len(u):].any()


def test_disconnected_forest():
    # two cliques, no crossing edges
    rng = np.random.default_rng(0)
    u1, v1 = np.triu_indices(10, 1)
    u2, v2 = u1 + 10, v1 + 10
    u = np.concatenate([u1, u2]).astype(np.int32)
    v = np.concatenate([v1, v2]).astype(np.int32)
    w = rng.uniform(1, 255, len(u)).astype(np.float32)
    edges = from_numpy(u, v, w, 20)
    mask, wt = minimum_spanning_forest(edges, engine="static")
    assert int(np.asarray(mask).sum()) == 18  # (10-1) * 2
    _check(u, v, w, 20, mask)


def test_single_edge_and_empty():
    edges = from_numpy(np.array([0], np.int32), np.array([1], np.int32),
                       np.array([3.0], np.float32), 2)
    mask, wt = minimum_spanning_forest(edges, engine="static")
    assert bool(np.asarray(mask)[0]) and float(wt) == 3.0
    empty = from_numpy(np.zeros(0, np.int32), np.zeros(0, np.int32),
                       np.zeros(0, np.float32), 4, pad_to=8)
    mask, wt = minimum_spanning_forest(empty, engine="static")
    assert float(wt) == 0.0


@pytest.mark.parametrize("family", ["grid2d", "gnm", "rmat", "rgg2d"])
def test_generated_families(family):
    u, v, w, n = generators.generate(family, 1024, avg_degree=8.0, seed=1)
    edges = from_numpy(u, v, w, n)
    for algo in ("boruvka", "filter_boruvka"):
        mask, _ = minimum_spanning_forest(edges, algorithm=algo,
                                          engine="static")
        _check(u, v, w, n, mask)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 60), st.integers(1, 300), st.integers(0, 10_000),
       st.booleans())
def test_property_engines_agree(n, m, seed, ties):
    """Hypothesis: all engines produce the oracle MSF weight."""
    u, v, w = _random_graph(n, m, seed, int_weights=ties)
    if len(u) == 0:
        return
    edges = from_numpy(u, v, w, n)
    _, expect = oracle.kruskal(u, v, w, n)
    for algo in ("boruvka", "filter_boruvka"):
        mask, wt = minimum_spanning_forest(edges, algorithm=algo,
                                           engine="static")
        assert float(wt) == pytest.approx(expect, rel=1e-5)
    mask_d, wt_d = filter_boruvka_dynamic(u, v, w, n, min_edges=16)
    assert wt_d == pytest.approx(expect, rel=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(1, 150), st.integers(0, 10_000))
def test_property_unique_msf_edges_match(n, m, seed):
    """With distinct weights the exact edge set must match the oracle."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    keep = u != v
    u, v = u[keep], v[keep]
    if len(u) == 0:
        return
    w = rng.permutation(len(u)).astype(np.float32) + 1.0  # distinct
    edges = from_numpy(u, v, w, n)
    emask, _ = oracle.kruskal(u, v, w, n)
    # distinct weights => unique MSF => identical masks modulo duplicate
    # (u,v,w) triples; compare weights-sorted multiset instead of indices
    for algo in ("boruvka", "filter_boruvka"):
        mask, _ = minimum_spanning_forest(edges, algorithm=algo,
                                          engine="static")
        got = np.sort(w[np.asarray(mask)])
        exp = np.sort(w[emask])
        assert np.allclose(got, exp)
